"""End-to-end training driver (deliverable b): train a ~100M-param dense
LM for a few hundred steps on CPU with the full production substrate —
resumable data pipeline, AdamW + cosine schedule, atomic checkpoints,
straggler watchdog. Interrupt it and re-run: it resumes from the last
checkpoint with an identical loss trajectory.

    PYTHONPATH=src python examples/train_lm.py [steps]
"""

import dataclasses
import sys
from pathlib import Path

from repro.configs import ARCHS
from repro.runtime import Trainer, TrainerConfig


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200

    # ~100M-param member of the minicpm (llama-like) family
    cfg = dataclasses.replace(
        ARCHS["minicpm-2b"],
        name="minicpm-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=1536,
        vocab=8192,
        dtype="float32",
    )
    n_params = cfg.n_params
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    tcfg = TrainerConfig(seq_len=128, batch=8, lr=3e-4, warmup=20,
                         total_steps=steps, checkpoint_every=50)
    trainer = Trainer(cfg, tcfg, Path("results/ckpt_train_lm"))
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")
    metrics = trainer.run()
    for m in metrics[:: max(len(metrics) // 10, 1)]:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"gnorm {m['gnorm']:.2f} {m['dt']*1e3:.0f}ms")
    print(f"final loss {metrics[-1]['loss']:.4f} "
          f"(start {metrics[0]['loss']:.4f}); "
          f"stragglers observed: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
