"""End-to-end deep-RL data generation through ACS (the paper's headline
workload): run Brax-style physics environments with a linear policy,
collecting a batch of (obs, action, reward-proxy) trajectories — the
simulation stream scheduled by the ACS window, exactly as §VI-A.

    PYTHONPATH=src python examples/physics_rl.py [env] [steps]
"""

import sys
import time

import numpy as np

from repro.core import TaskStream, WaveScheduler
from repro.sim import PhysicsEngine, make_env


def main():
    env = sys.argv[1] if len(sys.argv) > 1 else "cheetah"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 5

    eng = PhysicsEngine(make_env(env), n_envs=16, group_size=4, seed=0)
    sched = WaveScheduler(window_size=32)
    rng = np.random.RandomState(0)

    obs_dim = eng.spec.n_bodies * 6
    w_policy = rng.randn(obs_dim, eng.spec.n_joints).astype(np.float32) * 0.1

    def policy(obs):  # linear policy over engine observations
        return np.tanh(obs @ w_policy)

    trajectory = []
    t0 = time.perf_counter()
    for step in range(steps):
        stream = TaskStream()
        eng.emit_step(stream, policy=policy)
        report = sched.run(stream.tasks)
        snap = eng.state_snapshot()
        reward = -np.linalg.norm(snap[..., :3], axis=-1).mean()  # stay near origin
        trajectory.append(reward)
        print(f"step {step}: kernels={len(stream.tasks)} "
              f"dispatches={report.exec_stats['dispatches']} "
              f"wave_width={report.mean_wave_width:.1f} reward={reward:.3f}")
    dt = time.perf_counter() - t0
    print(f"\n{env}: {steps} steps, {dt:.2f}s wall, "
          f"states finite: {bool(np.all(np.isfinite(eng.state_snapshot())))}")


if __name__ == "__main__":
    main()
