"""End-to-end deep-RL data generation through ACS (the paper's headline
workload): run Brax-style physics environments with a linear policy,
collecting a batch of (obs, action, reward-proxy) trajectories — the
simulation stream scheduled by the ACS window, exactly as §VI-A.

    PYTHONPATH=src python examples/physics_rl.py [env] [steps] [scheduler]

``scheduler`` is one of serial | wave | threaded | frontier | device
(default wave; see ``repro.core.SCHEDULER_NAMES``; ``device`` is the
ACS-HW analogue — the whole step's stream in ONE dispatch through the
slab arena). Each RL step emits a fresh,
input-dependent kernel graph, so this is the frontier scheduler's home
turf: per-kernel compile caches carry across steps while wave-shaped
caches keep missing.
"""

import sys
import time

import numpy as np

from repro.core import TaskStream, make_scheduler
from repro.sim import PhysicsEngine, make_env


def main():
    env = sys.argv[1] if len(sys.argv) > 1 else "cheetah"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 5
    sched_name = sys.argv[3] if len(sys.argv) > 3 else "wave"
    try:
        run = make_scheduler(sched_name)
    except ValueError as exc:
        raise SystemExit(str(exc))

    eng = PhysicsEngine(make_env(env), n_envs=16, group_size=4, seed=0)
    rng = np.random.RandomState(0)

    obs_dim = eng.spec.n_bodies * 6
    w_policy = rng.randn(obs_dim, eng.spec.n_joints).astype(np.float32) * 0.1

    def policy(obs):  # linear policy over engine observations
        return np.tanh(obs @ w_policy)

    trajectory = []
    t0 = time.perf_counter()
    for step in range(steps):
        stream = TaskStream()
        eng.emit_step(stream, policy=policy)
        report = run(stream.tasks)
        snap = eng.state_snapshot()
        reward = -np.linalg.norm(snap[..., :3], axis=-1).mean()  # stay near origin
        stats = report.exec_stats
        extra = ""
        if report.groups:  # frontier: show the async profile
            extra = (f" syncs={stats['blocking_syncs']}"
                     f" inflight={report.max_inflight_groups()}")
        print(f"step {step}: kernels={len(stream.tasks)} "
              f"dispatches={stats['dispatches']} "
              f"wave_width={report.mean_wave_width:.1f} reward={reward:.3f}{extra}")
        trajectory.append(reward)
    dt = time.perf_counter() - t0
    print(f"\n{env} [{sched_name}]: {steps} steps, {dt:.2f}s wall, "
          f"states finite: {bool(np.all(np.isfinite(eng.state_snapshot())))}")


if __name__ == "__main__":
    main()
