"""Quickstart: ACS in 60 seconds.

Build an irregular, input-dependent task stream (a tiny physics step),
run it serially (the single-stream baseline) and through the ACS window,
and watch the dispatch count collapse while results stay identical.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.sim import PhysicsEngine, make_env


def build(seed):
    eng = PhysicsEngine(make_env("ant"), n_envs=16, group_size=4, seed=seed)
    stream = TaskStream()
    eng.emit_step(stream)
    return eng, stream


def main():
    # 1. serial baseline: one dispatch per kernel, program order
    eng_a, stream_a = build(seed=7)
    serial = run_serial(stream_a.tasks)

    # 2. ACS: windowed out-of-order scheduling -> fused waves
    eng_b, stream_b = build(seed=7)
    acs = WaveScheduler(window_size=32).run(stream_b.tasks)

    a, b = eng_a.state_snapshot(), eng_b.state_snapshot()
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
    print(f"kernels launched      : {len(stream_a.tasks)}")
    print(f"serial dispatches     : {serial.exec_stats['dispatches']}")
    print(f"ACS dispatches        : {acs.exec_stats['dispatches']}")
    print(f"ACS mean wave width   : {acs.mean_wave_width:.1f}")
    print(f"max wave width        : {acs.exec_stats['max_wave_width']}")
    print(f"results identical     : True")


if __name__ == "__main__":
    main()
