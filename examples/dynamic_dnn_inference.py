"""Dynamic-DNN inference through ACS (paper §VI-B): classify a stream of
images with an InstaNAS-like instance-aware CNN whose architecture — and
therefore kernel stream — changes per image. The per-input graphs defeat
ahead-of-time DAG frameworks; ACS schedules each one at runtime while its
wave-signature cache keeps compilation amortized across inputs.

    PYTHONPATH=src python examples/dynamic_dnn_inference.py [n_images]
"""

import sys
import time

import numpy as np

from repro.core import TaskStream, WaveScheduler
from repro.dyn import WORKLOADS
from repro.dyn.instanas import controller


def main():
    n_images = int(sys.argv[1]) if len(sys.argv) > 1 else 6
    init_fn, build_fn, _ = WORKLOADS["instanas"]
    params = init_fn(seed=0)
    sched = WaveScheduler(window_size=32)
    rng = np.random.RandomState(0)

    prev_dispatches = 0
    for i in range(n_images):
        x = rng.randn(1, 3, 32, 32).astype(np.float32) * (1 + 0.5 * i)
        active = sum(sum(m) for m in controller(x))
        stream = TaskStream()
        out = build_fn(params, stream, x)
        t0 = time.perf_counter()
        report = sched.run(stream.tasks)
        dt = (time.perf_counter() - t0) * 1e3
        dispatches = report.exec_stats["dispatches"] - prev_dispatches
        prev_dispatches = report.exec_stats["dispatches"]
        pred = int(np.argmax(np.asarray(out.value)))
        print(f"image {i}: {active:2d} blocks active, "
              f"{len(stream.tasks):3d} kernels -> "
              f"{dispatches:3d} dispatches, "
              f"class={pred}, {dt:.0f}ms")

    exec_stats = sched.executor.stats
    print(f"\nwave-program compiles across all inputs: {exec_stats.compiles} "
          f"(signature cache absorbs per-input graph variation)")


if __name__ == "__main__":
    main()
