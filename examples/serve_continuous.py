"""Serving driver: continuous batching through the ACS window (DESIGN §4,
§10). Requests arrive over time; each owns a KV-cache slot; the ACS
dependency window automatically co-schedules new prefills with the
in-flight decode (disjoint slots => independent), while each request's own
prefill -> decode chain stays serialized by its RAW hazards.

Runs both servers on the same staggered arrivals: the live SessionServer
(admission emits prefills into the open window while the previous decode
group is still in flight) and the per-step batch-drain baseline.

    PYTHONPATH=src python examples/serve_continuous.py
"""

import dataclasses

import numpy as np

import jax

from repro.configs import ARCHS
from repro.models import init_params
from repro.runtime import ContinuousBatchingServer, SessionServer


ARRIVALS = {0: 2, 2: 1, 4: 2, 6: 1}  # iteration -> new requests


def run_batch(cfg, params, rng):
    server = ContinuousBatchingServer(cfg, params, max_slots=3, max_len=48)
    finished = []
    for it in range(40):
        for _ in range(ARRIVALS.get(it, 0)):
            req = server.submit(rng.randint(0, cfg.vocab, rng.randint(4, 9)),
                                max_new=6)
            print(f"[batch iter {it}] submitted request {req.rid}")
        for r in server.step():
            finished.append(r)
            print(f"[batch iter {it}] finished request {r.rid}: tokens {r.generated}")
        if not server.queue and not server.active and it > 8:
            break
    waves = server.report_log
    multi = sum(1 for e in waves if e.get("tasks_this_run", 0) > 1
                and e.get("waves_this_run", 0) < e.get("tasks_this_run", 0))
    print(f"batch: served {len(finished)} requests in {len(waves)} drains; "
          f"{multi} drains co-scheduled independent work in one wave\n")


def run_session(cfg, params, rng):
    server = SessionServer(cfg, params, max_slots=3, max_len=48,
                           scheduler="frontier")
    finished = []
    for it in range(120):
        for _ in range(ARRIVALS.get(it, 0)):
            req = server.submit(rng.randint(0, cfg.vocab, rng.randint(4, 9)),
                                max_new=6)
            print(f"[session pump {it}] submitted request {req.rid} "
                  f"(queue depth {req.queue_depth})")
        done = server.pump()
        for r in done:
            finished.append(r)
            print(f"[session pump {it}] finished request {r.rid}: tokens {r.generated}")
        if not server.queue and not server.active and it > 8:
            break
        if not done:
            server.session.drive()  # block only when nothing retired this pump
    report = server.close()
    print(f"session: served {len(finished)} requests; "
          f"{report.max_inflight_groups()} groups overlapped in flight; "
          f"retired by stream tag: {dict(sorted(server.session.retired_by_tag.items()))}")


def main():
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=512,
    )
    params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
    run_batch(cfg, params, np.random.RandomState(0))
    run_session(cfg, params, np.random.RandomState(0))


if __name__ == "__main__":
    main()
