"""Serving driver: continuous batching through the ACS window (DESIGN §4).
Requests arrive over time; each owns a KV-cache slot; the ACS dependency
window automatically co-schedules new prefills with the in-flight decode
wave (disjoint slots => same wave), while each request's own prefill ->
decode chain stays serialized by its RAW hazards.

    PYTHONPATH=src python examples/serve_continuous.py
"""

import dataclasses

import numpy as np

import jax

from repro.configs import ARCHS
from repro.models import init_params
from repro.runtime import ContinuousBatchingServer


def main():
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=512,
    )
    params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
    server = ContinuousBatchingServer(cfg, params, max_slots=3, max_len=48)
    rng = np.random.RandomState(0)

    # staggered arrivals: a new request shows up every other iteration
    arrivals = {0: 2, 2: 1, 4: 2, 6: 1}
    finished = []
    for it in range(40):
        for _ in range(arrivals.get(it, 0)):
            req = server.submit(rng.randint(0, cfg.vocab, rng.randint(4, 9)),
                                max_new=6)
            print(f"[iter {it}] submitted request {req.rid}")
        done = server.step()
        for r in done:
            finished.append(r)
            print(f"[iter {it}] finished request {r.rid}: tokens {r.generated}")
        if not server.queue and not server.active and it > 8:
            break

    waves = [e for e in server.report_log]
    multi = sum(1 for e in waves if e.get("tasks_this_run", 0) > 1
                and e.get("waves_this_run", 0) < e.get("tasks_this_run", 0))
    print(f"\nserved {len(finished)} requests in {len(waves)} iterations; "
          f"{multi} iterations co-scheduled independent work in one wave")


if __name__ == "__main__":
    main()
