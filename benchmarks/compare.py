"""Diff a committed BENCH_*.json perf-trajectory artifact against a
freshly generated one (same schema: ``run.py --json=PATH``). Usage::

    python -m benchmarks.compare COMMITTED FRESH [--rtol=0.5]

The committed artifact is the trajectory baseline; CI regenerates the
same leg and runs this driver before overwriting it, so a regression
fails the workflow instead of silently rewriting history. Three classes
of difference:

* **failures** (exit 1): a metric the committed artifact carries is
  missing from the fresh run (the writer stopped emitting it), or a
  *gate* metric — a 0/1 verdict like ``*_matches_serial``,
  ``pallas_used``, ``*_host_syncs_O1`` — flipped from 1 to 0;
* **warnings** (exit 0): a numeric value drifted beyond ``--rtol``
  relative tolerance (timings and counters wobble with load; they are
  reported, not gated), or a string value changed;
* **info**: metrics the fresh run added (a new bench column) and gates
  that flipped 0 -> 1 (an improvement).

Gates are recognised by name, not value: a counter that happens to equal
1 (e.g. ``session_host_syncs``) is numeric, never a gate.
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

__all__ = ["GATE_MARKERS", "is_gate", "compare_payloads", "main"]

# Substrings that mark a metric as a 0/1 verdict column. Every bench gate
# emits under one of these spellings (bench_device / bench_soak /
# bench_depcheck); plain counters never use them.
GATE_MARKERS = (
    "matches", "beats", "_O1", "used", "sublinear", "fewer_", "bounded",
    "recycled", "compacted", "stable", "flat", "grows", "within",
)


def is_gate(metric: str, value) -> bool:
    return (isinstance(value, (bool, int)) and not isinstance(value, float)
            and value in (0, 1)
            and any(m in metric for m in GATE_MARKERS))


def _metrics(payload) -> Dict[Tuple[str, str], object]:
    return {(r["section"], r["metric"]): r["value"]
            for r in payload["results"]}


def compare_payloads(committed, fresh, rtol: float = 0.5):
    """Returns ``(failures, warnings, infos)`` — lists of report lines."""
    failures: List[str] = []
    warnings: List[str] = []
    infos: List[str] = []
    cm, fm = _metrics(committed), _metrics(fresh)
    for (section, metric), cval in sorted(cm.items()):
        key = f"{section},{metric}"
        if (section, metric) not in fm:
            failures.append(f"missing from fresh run: {key} (committed={cval})")
            continue
        fval = fm[(section, metric)]
        if is_gate(metric, cval) or is_gate(metric, fval):
            if cval == 1 and fval != 1:
                failures.append(f"gate regressed 1 -> {fval}: {key}")
            elif cval != 1 and fval == 1:
                infos.append(f"gate improved {cval} -> 1: {key}")
            continue
        if isinstance(cval, (int, float)) and isinstance(fval, (int, float)) \
                and not isinstance(cval, bool) and not isinstance(fval, bool):
            denom = max(abs(cval), abs(fval), 1e-12)
            if abs(cval - fval) / denom > rtol:
                warnings.append(
                    f"numeric drift beyond rtol={rtol}: {key} "
                    f"committed={cval} fresh={fval}")
        elif cval != fval:
            warnings.append(f"value changed: {key} "
                            f"committed={cval!r} fresh={fval!r}")
    for (section, metric) in sorted(fm.keys() - cm.keys()):
        infos.append(f"new metric in fresh run: {section},{metric}")
    return failures, warnings, infos


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    rtol = 0.5
    paths = []
    for arg in argv:
        if arg.startswith("--rtol="):
            rtol = float(arg[len("--rtol="):])
        elif arg.startswith("--"):
            raise SystemExit(f"unknown flag {arg!r}; only --rtol=F is accepted")
        else:
            paths.append(arg)
    if len(paths) != 2:
        raise SystemExit(
            "usage: python -m benchmarks.compare COMMITTED FRESH [--rtol=F]")
    with open(paths[0]) as fh:
        committed = json.load(fh)
    with open(paths[1]) as fh:
        fresh = json.load(fh)
    failures, warnings, infos = compare_payloads(committed, fresh, rtol=rtol)
    for line in infos:
        print(f"INFO  {line}")
    for line in warnings:
        print(f"WARN  {line}")
    for line in failures:
        print(f"FAIL  {line}")
    print(f"compare: {len(failures)} failure(s), {len(warnings)} warning(s), "
          f"{len(infos)} info (rtol={rtol})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
