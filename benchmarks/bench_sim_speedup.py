"""Figs 21/22 reproduction: deep-RL physics simulation speedups for the 5
paper environments. Reports (a) REAL wall-clock on this host — serial
per-kernel dispatch vs ACS-SW wave dispatch (the dispatch-overhead
amortization that is the software half of the paper's win), and (b) the
MODELED policy comparison on RTX3060-class constants (serial / ACS-SW /
ACS-HW / CUDAGraph-with-construction), which is where the paper's
2.19x-max numbers live."""

from __future__ import annotations

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.sim import ENVIRONMENTS, PhysicsEngine

from .common import emit, modeled_policies, paper_scale_sim_tasks, speedup_table, wall

ENVS = ("ant", "grasp", "humanoid", "cheetah", "walker2d")
STEPS = 3
N_ENVS, GROUP = 16, 4


def build_tasks(env: str, seed: int):
    eng = PhysicsEngine(ENVIRONMENTS[env], n_envs=N_ENVS, group_size=GROUP,
                        seed=seed)
    stream = TaskStream()
    eng.emit_batch(stream, STEPS)
    return stream.tasks


def main() -> None:
    for env in ENVS:
        # -- real wall clock (compile-warmed: same wave signatures recur) ---
        sched = WaveScheduler(window_size=32)
        warm = build_tasks(env, seed=0)
        sched.run(warm)                       # warm the wave cache
        serial_warm = build_tasks(env, seed=0)
        run_serial(serial_warm)

        t_acs = wall(lambda: sched.run(build_tasks(env, seed=1)), repeats=2)
        t_ser = wall(lambda: run_serial(build_tasks(env, seed=1)), repeats=2)
        emit("fig21_sim_real", f"{env}_acs_sw_speedup", round(t_ser / t_acs, 3))

        # -- modeled policies (fig 22, paper-scale stream) -------------------
        tasks = paper_scale_sim_tasks(env, seed=2)
        speedup_table(f"fig22_sim_model_{env}", modeled_policies(tasks))


if __name__ == "__main__":
    main()
