"""Beyond-paper: ACS expert-waves for MoE (DESIGN.md §4). Routed expert
GEMMs are paper-style small kernels with input-dependent assignment; the
ACS window batches a wave of same-shape expert tasks into ONE grouped-GEMM
launch (kernels/grouped_matmul). Reports dispatch reduction + real wall
clock vs per-expert serial dispatch, and validates numerics."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import BufferPool, Task, TaskStream, WaveScheduler, run_serial
from repro.core.task import default_segments
from repro.kernels import ref
from repro.kernels.grouped_matmul import grouped_matmul

from .common import emit, wall

E, TOP_K, D, DE = 8, 2, 64, 32   # experts, topk, d_model, d_expert
T = 64                            # tokens
BM = 8                            # token-group tile


def route(seed):
    rng = np.random.RandomState(seed)
    probs = rng.rand(T, E)
    top = np.argsort(-probs, axis=1)[:, :TOP_K]
    return top, rng


def build_expert_stream(seed):
    """One task per (expert, token-tile): the paper-style small kernels."""
    top, rng = route(seed)
    x = rng.randn(T, D).astype(np.float32)
    w = rng.randn(E, D, DE).astype(np.float32)

    # sort token-slots by expert, pad each group to BM rows
    flat = [(int(top[t, k]), t) for t in range(T) for k in range(TOP_K)]
    flat.sort()
    tiles, rows = [], []
    for e in range(E):
        toks = [t for ee, t in flat if ee == e]
        for i in range(0, len(toks), BM):
            chunk = toks[i : i + BM] + [0] * (BM - len(toks[i : i + BM]))
            tiles.append(e)
            rows.append(chunk)
    xs = np.stack([x[r] for r in rows])  # [tiles, BM, D]

    pool = BufferPool()
    stream = TaskStream()
    outs = []
    wbufs = [pool.alloc((D, DE), np.float32, value=jnp.asarray(w[e]))
             for e in range(E)]
    for i, e in enumerate(tiles):
        xb = pool.alloc((BM, D), np.float32, value=jnp.asarray(xs[i]))
        ob = pool.alloc((BM, DE), np.float32, value=jnp.zeros((BM, DE)))
        outs.append(ob)
        r, wseg = default_segments((xb, wbufs[e]), (ob,))
        stream.push(Task(opcode="expert_gemm", fn=lambda a, b: a @ b,
                         inputs=(xb, wbufs[e]), outputs=(ob,),
                         read_segments=r, write_segments=wseg,
                         cost_flops=2 * BM * D * DE,
                         cost_bytes=4 * (BM * D + D * DE + BM * DE)))
    return stream.tasks, (xs, w, np.asarray(tiles, np.int32)), outs


def main() -> None:
    # dispatch accounting: serial = 1 launch/task; ACS wave = 1 launch/wave
    tasks, (xs, w, tiles), _ = build_expert_stream(0)
    sched = WaveScheduler(window_size=32)
    report = sched.run(tasks)
    emit("moe_waves", "tasks", len(tasks))
    emit("moe_waves", "acs_dispatches", report.exec_stats["dispatches"])
    emit("moe_waves", "serial_dispatches", len(tasks))

    # single grouped-GEMM launch == the whole wave; validate numerics
    xflat = jnp.asarray(xs.reshape(-1, D))
    got = grouped_matmul(xflat, jnp.asarray(w), jnp.asarray(tiles), block_m=BM,
                         block_n=16)
    expect = ref.grouped_matmul_ref(xflat, jnp.asarray(w), jnp.asarray(tiles),
                                    block_m=BM)
    err = float(jnp.max(jnp.abs(got - expect)))
    emit("moe_waves", "grouped_gemm_max_err", f"{err:.2e}")

    t_serial = wall(lambda: run_serial(build_expert_stream(1)[0]), repeats=2)
    sched2 = WaveScheduler(window_size=32)
    sched2.run(build_expert_stream(2)[0])  # warm
    t_acs = wall(lambda: sched2.run(build_expert_stream(3)[0]), repeats=2)
    emit("moe_waves", "acs_sw_real_speedup", round(t_serial / t_acs, 3))


if __name__ == "__main__":
    main()
