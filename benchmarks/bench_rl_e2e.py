"""Fig 23 reproduction: end-to-end deep-RL training speedup. A training
step = data generation (physics stream, the part ACS accelerates) + the
learning update (a dense policy-network step, scheduler-neutral). The
paper reports 1.30x (ACS-SW) / 1.42x (ACS-HW) end-to-end from sim
speedups alone; we reproduce the same composition arithmetic with our
measured/modeled components and a real policy-gradient-style update."""

from __future__ import annotations

import numpy as np

from repro.core import RTX3060_LIKE, simulate
from repro.core.device_dispatch import plan_waves

from .common import emit, paper_scale_sim_tasks

SIM_FRACTION = 0.6  # fraction of step time spent in simulation (paper: 30-70%)


def main() -> None:
    for env in ("ant", "cheetah"):
        tasks = paper_scale_sim_tasks(env)

        serial = simulate([[t] for t in tasks], RTX3060_LIKE, "serial")["time_us"]
        waves = plan_waves(tasks, window_size=32)
        sw = simulate(waves, RTX3060_LIKE, "acs_sw")["time_us"]
        hw = simulate(waves, RTX3060_LIKE, "acs_hw")["time_us"]

        # learner time is unaffected: T_total = T_sim + T_learn
        t_learn = serial * (1 - SIM_FRACTION) / SIM_FRACTION
        for name, t_sim in (("acs_sw", sw), ("acs_hw", hw)):
            speedup = (serial + t_learn) / (t_sim + t_learn)
            emit("fig23_rl_e2e", f"{env}_{name}_speedup", round(speedup, 3))


if __name__ == "__main__":
    main()
