"""Shared benchmark utilities: stream builders, timed policy comparisons,
CSV emission. Real wall-clock numbers come from executing the task streams
on this host (serial per-kernel dispatch vs ACS wave dispatch); modeled
numbers come from core.perfmodel with the paper's RTX3060-class constants
(the Accel-Sim role — see DESIGN.md §8)."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core import (
    AsyncFrontierScheduler,
    PLAN_MODES,
    RTX3060_LIKE,
    SCHEDULER_NAMES,
    TaskStream,
    ThreadedStreamScheduler,
    WaveScheduler,
    run_serial,
    simulate,
)
from repro.core.device_dispatch import plan_waves
from repro.core.dag_baseline import DagRunner, build_full_dag


# Every emit() row, kept for ``run.py --json=PATH`` (the machine-readable
# BENCH_*.json perf trajectory; CI uploads it as an artifact).
RESULTS: List[Dict[str, object]] = []


def emit(name: str, metric: str, value) -> None:
    RESULTS.append({"section": name, "metric": metric, "value": value})
    print(f"{name},{metric},{value}")


# -- scheduler selection (shared by bench_frontier and the run.py CLI) -----
#
# ``OPTIONS`` holds run-wide flag overrides parsed by ``run.py``
# (e.g. ``--window=16 --streams=8 --inflight=4 --plan-mode=frontier``);
# benches read them via ``opt()``/``choice()`` so one CLI tunes every
# section consistently.
OPTIONS: Dict[str, str] = {}

# CLI flag keys run.py accepts; each --<flag>=N maps onto make_scheduler.
FLAG_KEYS = ("window", "streams", "inflight")

# String-valued flags with a fixed vocabulary, validated by run.py:
#   --plan-mode  selects the device runner's plan lowering (DESIGN §2 A3);
#   --scheduler  restricts comparison sections to serial + one policy.
CHOICE_FLAGS: Dict[str, Sequence[str]] = {
    "plan-mode": PLAN_MODES,
    "scheduler": SCHEDULER_NAMES,
}


def opt(key: str, default: int) -> int:
    return int(OPTIONS.get(key, default))


def choice(key: str, default: str) -> str:
    return OPTIONS.get(key, default)


def smoke() -> bool:
    """True under ``run.py --smoke``: sections shrink to CI-sized inputs
    (plan-lowering and scheduler-API regressions should fail in CI, not at
    bench time)."""
    return OPTIONS.get("smoke") == "1"


def chosen_policies(default: Sequence[str]) -> List[str]:
    """Comparison sections honor ``--scheduler=NAME`` by shrinking their
    policy set to the serial baseline + the named policy."""
    sel = OPTIONS.get("scheduler")
    if sel is None:
        return list(default)
    return ["serial"] + ([sel] if sel != "serial" else [])


def make_scheduler(name: str, window: int = 32, num_streams: int = 4,
                   max_inflight: int = 8, plan_mode: str = "wave"):
    """repro.core.make_scheduler with CLI flag overrides applied."""
    from repro.core import make_scheduler as core_make_scheduler

    return core_make_scheduler(
        name,
        window_size=opt("window", window),
        num_streams=opt("streams", num_streams),
        max_inflight=opt("inflight", max_inflight),
        plan_mode=choice("plan-mode", plan_mode),
    )


def paper_scale_sim_tasks(env: str, steps: int = 2, seed: int = 0,
                          n_envs: int = 2048, group_size: int = 512):
    """Emit (without executing) a paper-scale simulation stream: the
    default 2048 envs in groups of 512 puts the kernel-size distribution
    in the paper's Fig 4/5 range (tens to ~200 CTAs), which is what the
    device model's occupancy/speedup numbers are sensitive to. Emission
    alone is cheap — the modeled benches never run these kernels."""
    from repro.sim import ENVIRONMENTS, PhysicsEngine

    eng = PhysicsEngine(ENVIRONMENTS[env], n_envs=n_envs,
                        group_size=group_size, seed=seed)
    stream = TaskStream()
    eng.emit_batch(stream, steps)
    return stream.tasks


def wall(fn: Callable, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# CUDA Graph per-input construction cost model, two components:
#   (a) graph building: ~2us per cudaGraphAddKernelNode/instantiate node;
#   (b) dependency DERIVATION: for an input-dependent graph the app must
#       compute the edges itself before it can build the DAG — all-pairs
#       segment checks at the native per-pair rate (Table II: ~50ns).
# (b) is quadratic in stream length and is exactly the cost ACS's windowed
# checks amortize away — charging it to the DAG baseline is the paper's
# §II-D argument. Static graphs pay neither (construct once, replay).
GRAPH_NODE_US = 2.0
PAIR_CHECK_US = 0.05


def cudagraph_construct_us(n_tasks: int, n_checks: int = 0,
                           include_derivation: bool = True) -> float:
    build = n_tasks * GRAPH_NODE_US
    if include_derivation:
        build += n_checks * PAIR_CHECK_US
    return build


def modeled_policies(tasks, window: int = 32, model=RTX3060_LIKE,
                     dyn_construct: bool = True) -> Dict[str, Dict]:
    """Model serial / ACS-SW / ACS-HW / CUDAGraph on one stream."""
    waves = plan_waves(tasks, window_size=window)
    serial = simulate([[t] for t in tasks], model, "serial")
    sw = simulate(waves, model, "acs_sw")
    hw = simulate(waves, model, "acs_hw")
    edges, checks = build_full_dag(tasks)
    construct_us = (
        cudagraph_construct_us(len(tasks), checks) if dyn_construct else 0.0
    )
    from repro.core.dag_baseline import level_schedule

    levels = level_schedule(tasks, edges)
    cg = simulate(levels, model, "cudagraph", construct_us=construct_us)
    return {"serial": serial, "acs_sw": sw, "acs_hw": hw, "cudagraph": cg}


def speedup_table(name: str, policies: Dict[str, Dict]) -> None:
    base = policies["serial"]["time_us"]
    for pol, res in policies.items():
        if pol == "serial":
            continue
        emit(name, f"{pol}_speedup", round(base / res["time_us"], 3))
    for pol, res in policies.items():
        emit(name, f"{pol}_occupancy", round(res["occupancy"], 3))
