"""Figs 25/26 reproduction: dynamic DNN inference (InstaNAS-like I-NAS,
Dynamic Routing DR, CondConv CC). Per-input graphs: the DAG baseline pays
construction per image; ACS does not. Real wall-clock + modeled policies
+ occupancy."""

from __future__ import annotations

import numpy as np

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.dyn import WORKLOADS

from .common import emit, modeled_policies, speedup_table, wall

NETS = {"instanas": "I-NAS", "dynamic_routing": "DR", "condconv": "CC"}


def build_tasks(name: str, input_seed: int, params=None):
    init_fn, build_fn, _ = WORKLOADS[name]
    params = params if params is not None else init_fn(0)
    rng = np.random.RandomState(input_seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32) * (1.0 + 0.3 * input_seed)
    stream = TaskStream()
    build_fn(params, stream, x)
    return stream.tasks


def main() -> None:
    for name, tag in NETS.items():
        sched = WaveScheduler(window_size=32)
        sched.run(build_tasks(name, 0))   # warm compile caches
        run_serial(build_tasks(name, 0))

        t_acs = wall(lambda: sched.run(build_tasks(name, 1)), repeats=2)
        t_ser = wall(lambda: run_serial(build_tasks(name, 1)), repeats=2)
        emit("fig25_dyn_real", f"{tag}_acs_sw_speedup", round(t_ser / t_acs, 3))

        tasks = build_tasks(name, 2)
        speedup_table(f"fig25_dyn_model_{tag}", modeled_policies(tasks))


if __name__ == "__main__":
    main()
