"""ACS-HW analogue vs host-side scheduling on the REAL workloads.

The seed's device-resident window only ran a uniform toy universe; the
shape-class slab arena (DESIGN §2 A3) lets it execute the actual sim and
dyn streams — so this section finally puts the one-dispatch path on the
same axis as the host schedulers:

* **policies**: serial (one dispatch per kernel), threaded (paper ACS-SW:
  K streams, per-kernel sync), frontier (async group retirement), and the
  device runner in both plan modes (wave / frontier lowering; ONE dispatch
  per stream).
* **columns**: wall seconds + speedup vs serial, dispatch count (the
  §II-D communication-overhead axis), active fraction (host: wave-width
  occupancy proxy; device: plan table density), and — device only — the
  arena's padding waste per shape class, the price of uniform row
  indexing over heterogeneous kernels.
* **equivalence**: every policy's final buffer contents are checked
  bit-identical against the serial baseline (``matches_serial``).

Timing is warm: each policy runs one throwaway stream first (populating
jit / lowered-program caches, as a long-running runtime would), then a
structurally identical fresh stream is timed.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceWindowRunner, TaskStream

from .common import chosen_policies, emit, make_scheduler, opt, smoke

HOST_POLICIES = ("serial", "threaded", "frontier")
DEVICE_MODES = ("wave", "frontier")


def _sim_leg():
    from repro.sim import ENVIRONMENTS, PhysicsEngine

    n_envs, group, steps = (4, 2, 1) if smoke() else (8, 4, 2)

    def build(seed=0):
        eng = PhysicsEngine(ENVIRONMENTS["cheetah"], n_envs=n_envs,
                            group_size=group, seed=seed)
        stream = TaskStream()
        eng.emit_batch(stream, steps)
        return eng.state_snapshot, stream.tasks

    return "device_sim_cheetah", build


def _dyn_leg():
    from repro.dyn import WORKLOADS

    init_fn, build_fn, _ = WORKLOADS["dynamic_routing"]
    rng = np.random.RandomState(0)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)

    def build(seed=0):
        params = init_fn(0)
        stream = TaskStream()
        out = build_fn(params, stream, x)
        return (lambda o=out: np.asarray(o.value)), stream.tasks

    return "device_dyn_routing", build


def _snapshot(fn):
    return np.asarray(fn())


def _per_run(measured, warm, key):
    """Measured-run counter for schedulers whose ExecStats may persist
    across runs (serial/frontier accumulate; threaded resets). The warm
    and measured streams are structurally identical, so a cumulative
    counter shows measured > warm and the delta is the per-run value."""
    m, w = measured.exec_stats[key], warm.exec_stats[key]
    return m - w if m > w else m


def compare(name: str, build) -> None:
    window = opt("window", 32)
    # serial reference run (also the timing baseline)
    _, tasks = build()
    serial_run = make_scheduler("serial", window=window)
    serial_warm = serial_run(tasks)  # warm jit caches
    snap, tasks = build()
    t0 = time.perf_counter()
    serial_report = serial_run(tasks)
    base = time.perf_counter() - t0
    ref = _snapshot(snap)
    emit(name, "tasks", len(tasks))
    emit(name, "serial_wall_s", round(base, 4))
    emit(name, "serial_dispatches", _per_run(serial_report, serial_warm, "dispatches"))
    emit(name, "serial_active_fraction", round(serial_report.occupancy_proxy(), 3))

    # device is handled by the plan-mode loop below, not as a host policy
    policies = [p for p in chosen_policies(HOST_POLICIES)
                if p not in ("serial", "device")]
    for pol in policies:
        run = make_scheduler(pol, window=window)
        _, warm_tasks = build()
        warm_report = run(warm_tasks)
        snap, tasks = build()
        t0 = time.perf_counter()
        report = run(tasks)
        wall = time.perf_counter() - t0
        emit(name, f"{pol}_wall_s", round(wall, 4))
        emit(name, f"{pol}_speedup", round(base / wall, 3))
        emit(name, f"{pol}_dispatches", _per_run(report, warm_report, "dispatches"))
        emit(name, f"{pol}_active_fraction", round(report.occupancy_proxy(), 3))
        emit(name, f"{pol}_matches_serial", int(np.array_equal(_snapshot(snap), ref)))

    if "device" not in chosen_policies(("device",)):
        return
    for mode in DEVICE_MODES:
        runner = DeviceWindowRunner(window_size=window, plan_mode=mode)
        _, warm_tasks = build()
        runner.run(warm_tasks)  # compile the lowered program
        snap, tasks = build()
        t0 = time.perf_counter()
        report = runner.run(tasks)
        wall = time.perf_counter() - t0
        pol = f"device_{mode}"
        emit(name, f"{pol}_wall_s", round(wall, 4))
        emit(name, f"{pol}_speedup", round(base / wall, 3))
        emit(name, f"{pol}_dispatches", report.exec_stats["dispatches"])
        emit(name, f"{pol}_active_fraction", round(report.plan_active_fraction, 3))
        emit(name, f"{pol}_matches_serial", int(np.array_equal(_snapshot(snap), ref)))
        emit(name, f"{pol}_plan_steps", report.arena_stats["device_steps"])
        emit(name, f"{pol}_shape_classes", report.arena_stats["n_classes"])
        emit(name, f"{pol}_padding_waste", report.arena_stats["total_waste_frac"])
        if mode == DEVICE_MODES[0]:  # arena layout is plan-mode independent
            for label, entry in sorted(report.arena_stats["per_class"].items()):
                emit(name, f"waste_{label.replace(',', ';').replace(' ', '')}",
                     entry["waste_frac"])


def main() -> None:
    for name, build in (_sim_leg(), _dyn_leg()):
        compare(name, build)


if __name__ == "__main__":
    main()
