"""ACS-HW analogue vs host-side scheduling on the REAL workloads.

The seed's device-resident window only ran a uniform toy universe; the
shape-class slab arena (DESIGN §2 A3) lets it execute the actual sim and
dyn streams — so this section finally puts the one-dispatch path on the
same axis as the host schedulers:

* **policies**: serial (one dispatch per kernel), threaded (paper ACS-SW:
  K streams, per-kernel sync), frontier (async group retirement), and the
  device runner in all three plan modes (wave / frontier step-table
  lowering, and the ``loop`` ready-queue program that advances the whole
  dependency frontier inside ONE ``lax.while_loop`` dispatch).
* **columns**: wall seconds + speedup vs serial, dispatch count (the
  §II-D communication-overhead axis), active fraction (host: wave-width
  occupancy proxy; device: plan table density), and — device only — the
  arena's padding waste per shape class, the price of uniform row
  indexing over heterogeneous kernels.
* **equivalence**: every policy's final buffer contents are checked
  bit-identical against the serial baseline (``matches_serial``).

Timing is warm: each policy runs one throwaway stream first (populating
jit / lowered-program caches, as a long-running runtime would), then a
structurally identical fresh stream is timed.

The ``device_session_recurring`` section is the persistent-window leg
(DESIGN §2 A3): a recurring-structure multi-stream workload (decode-chain
shaped — the same kernel chains over the same persistent state buffers,
stream after stream) served three ways: per-stream device dispatch (one
plan+pack+dispatch per stream), the live frontier session, and the
persistent :class:`DeviceSession` (streams accumulate in the rolling
window; recurring slices hit the session's structure-keyed plan cache and
whole backlogs drain in one epoch dispatch). Columns: dispatches,
plan-cache hits, host syncs — the host-round-trip reduction the
persistent window buys. A fourth leg serves the same workload through
``plan_mode="loop"`` (gate: host syncs stay O(1) for the whole recurring
workload, not per kernel), and the ``device_loop_pallas`` section forces
the ready-queue Pallas kernel (interpret mode off-TPU) on the
single-class chain universe and checks it bit-identical to both the
interpreter lowering and the serial baseline.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    BufferPool,
    DeviceWindowRunner,
    Task,
    TaskStream,
    make_session,
    run_serial,
)
from repro.core.task import default_segments
from repro.kernels.ops import LOOP_BRANCHES, register_loop_branches

from .common import chosen_policies, emit, make_scheduler, opt, smoke

HOST_POLICIES = ("serial", "threaded", "frontier")
DEVICE_MODES = ("wave", "frontier", "loop")


def _sim_leg():
    from repro.sim import ENVIRONMENTS, PhysicsEngine

    n_envs, group, steps = (4, 2, 1) if smoke() else (8, 4, 2)

    def build(seed=0):
        eng = PhysicsEngine(ENVIRONMENTS["cheetah"], n_envs=n_envs,
                            group_size=group, seed=seed)
        stream = TaskStream()
        eng.emit_batch(stream, steps)
        return eng.state_snapshot, stream.tasks

    return "device_sim_cheetah", build


def _dyn_leg():
    from repro.dyn import WORKLOADS

    init_fn, build_fn, _ = WORKLOADS["dynamic_routing"]
    rng = np.random.RandomState(0)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)

    def build(seed=0):
        params = init_fn(0)
        stream = TaskStream()
        out = build_fn(params, stream, x)
        return (lambda o=out: np.asarray(o.value)), stream.tasks

    return "device_dyn_routing", build


def _snapshot(fn):
    return np.asarray(fn())


def _per_run(measured, warm, key):
    """Measured-run counter for schedulers whose ExecStats may persist
    across runs (serial/frontier accumulate; threaded resets). The warm
    and measured streams are structurally identical, so a cumulative
    counter shows measured > warm and the delta is the per-run value."""
    m, w = measured.exec_stats[key], warm.exec_stats[key]
    return m - w if m > w else m


def compare(name: str, build) -> None:
    window = opt("window", 32)
    # serial reference run (also the timing baseline)
    _, tasks = build()
    serial_run = make_scheduler("serial", window=window)
    serial_warm = serial_run(tasks)  # warm jit caches
    snap, tasks = build()
    t0 = time.perf_counter()
    serial_report = serial_run(tasks)
    base = time.perf_counter() - t0
    ref = _snapshot(snap)
    emit(name, "tasks", len(tasks))
    emit(name, "serial_wall_s", round(base, 4))
    emit(name, "serial_dispatches", _per_run(serial_report, serial_warm, "dispatches"))
    emit(name, "serial_active_fraction", round(serial_report.occupancy_proxy(), 3))

    # device is handled by the plan-mode loop below, not as a host policy
    policies = [p for p in chosen_policies(HOST_POLICIES)
                if p not in ("serial", "device")]
    for pol in policies:
        run = make_scheduler(pol, window=window)
        _, warm_tasks = build()
        warm_report = run(warm_tasks)
        snap, tasks = build()
        t0 = time.perf_counter()
        report = run(tasks)
        wall = time.perf_counter() - t0
        emit(name, f"{pol}_wall_s", round(wall, 4))
        emit(name, f"{pol}_speedup", round(base / wall, 3))
        emit(name, f"{pol}_dispatches", _per_run(report, warm_report, "dispatches"))
        emit(name, f"{pol}_active_fraction", round(report.occupancy_proxy(), 3))
        emit(name, f"{pol}_matches_serial", int(np.array_equal(_snapshot(snap), ref)))

    if "device" not in chosen_policies(("device",)):
        return
    walls = {}
    for mode in DEVICE_MODES:
        runner = DeviceWindowRunner(window_size=window, plan_mode=mode)
        _, warm_tasks = build()
        runner.run(warm_tasks)  # compile the lowered program
        snap, tasks = build()
        t0 = time.perf_counter()
        report = runner.run(tasks)
        wall = time.perf_counter() - t0
        walls[mode] = wall
        pol = f"device_{mode}"
        emit(name, f"{pol}_wall_s", round(wall, 4))
        emit(name, f"{pol}_speedup", round(base / wall, 3))
        emit(name, f"{pol}_dispatches", report.exec_stats["dispatches"])
        emit(name, f"{pol}_active_fraction", round(report.plan_active_fraction, 3))
        emit(name, f"{pol}_matches_serial", int(np.array_equal(_snapshot(snap), ref)))
        emit(name, f"{pol}_plan_steps", report.arena_stats["device_steps"])
        emit(name, f"{pol}_shape_classes", report.arena_stats["n_classes"])
        emit(name, f"{pol}_padding_waste", report.arena_stats["total_waste_frac"])
        if mode == "loop":
            emit(name, f"{pol}_executor", report.loop_executor)
        if mode == DEVICE_MODES[0]:  # arena layout is plan-mode independent
            for label, entry in sorted(report.arena_stats["per_class"].items()):
                emit(name, f"waste_{label.replace(',', ';').replace(' ', '')}",
                     entry["waste_frac"])
    if "wave" in walls and "loop" in walls and walls["loop"] > 0:
        # > 1.0 means the ready-queue program beat the step-table lowering
        # (informational ratio, no hard gate: both are one-dispatch paths).
        emit(name, "loop_vs_wave", round(walls["wave"] / walls["loop"], 3))


# ---------------------------------------------------------------------------
# Persistent window: recurring-structure multi-stream leg
# ---------------------------------------------------------------------------

# The shared ready-queue switch-branch fns (kernels/ops.py): using the
# SAME objects the registry's switch table holds is what makes the chain
# universe eligible for the Pallas fast path (identity-checked lowering).
_axpy = LOOP_BRANCHES["axpy"]
_mul = LOOP_BRANCHES["mul"]


def _chain_universe(seed=0, n_chains=6, width=16):
    """Persistent per-chain state buffers + one shared (read-only) weight —
    the decode-chain shape: every stream applies the same kernel chain to
    the same buffers, so stream structure AND arena addresses recur."""
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    states = [
        pool.alloc((width,), np.float32, name=f"chain{i}",
                   value=rng.randn(width).astype(np.float32))
        for i in range(n_chains)
    ]
    weight = pool.alloc((width,), np.float32, name="weight",
                        value=rng.randn(width).astype(np.float32))
    return states, weight


def _emit_chain_stream(states, weight, depth=4):
    """One stream: per chain, ``depth`` RAW-serialized kernels; chains are
    mutually independent (disjoint state buffers)."""
    tasks = []
    for s in states:
        for d in range(depth):
            fn = _axpy if d % 2 == 0 else _mul
            ins, outs = (s, weight), (s,)
            r, w = default_segments(ins, outs)
            tasks.append(Task(opcode="axpy" if d % 2 == 0 else "mul", fn=fn,
                              inputs=ins, outputs=outs,
                              read_segments=r, write_segments=w))
    return tasks


def session_compare() -> None:
    name = "device_session_recurring"
    window = opt("window", 32)
    n_streams = 4 if smoke() else 8
    n_chains = 4 if smoke() else 6

    def snap(states):
        return np.stack([np.asarray(s.value) for s in states])

    # serial reference over all K streams
    states, weight = _chain_universe(n_chains=n_chains)
    for _ in range(n_streams):
        run_serial(_emit_chain_stream(states, weight))
    ref = snap(states)
    emit(name, "streams", n_streams)
    emit(name, "tasks_per_stream", len(_emit_chain_stream(*_chain_universe(n_chains=n_chains))))

    # per-stream device dispatch: one plan + pack + dispatch per stream
    states, weight = _chain_universe(n_chains=n_chains)
    runner = DeviceWindowRunner(window_size=window)
    runner.run(_emit_chain_stream(states, weight))  # compile warm
    states, weight = _chain_universe(n_chains=n_chains)
    t0 = time.perf_counter()
    dispatches = 0
    for _ in range(n_streams):
        report = runner.run(_emit_chain_stream(states, weight))
        dispatches += report.exec_stats["dispatches"]
    per_stream_wall = time.perf_counter() - t0
    emit(name, "per_stream_wall_s", round(per_stream_wall, 4))
    emit(name, "per_stream_dispatches", dispatches)
    emit(name, "per_stream_matches_serial", int(np.array_equal(snap(states), ref)))

    # live frontier session on the same pattern (per-group dispatches)
    states, weight = _chain_universe(n_chains=n_chains)
    fs = make_session("frontier", window_size=window)
    t0 = time.perf_counter()
    for _ in range(n_streams):
        fs.submit(_emit_chain_stream(states, weight))
        fs.poll()
    freport = fs.close()
    emit(name, "frontier_session_wall_s", round(time.perf_counter() - t0, 4))
    emit(name, "frontier_session_dispatches", freport.exec_stats["dispatches"])
    emit(name, "frontier_session_matches_serial",
         int(np.array_equal(snap(states), ref)))

    # persistent device session: first two streams poll per stream (epoch
    # each — the second hits the plan cache), the rest accumulate in the
    # rolling window and drain in ONE epoch dispatch.
    states, weight = _chain_universe(n_chains=n_chains)
    ds = make_session("device", window_size=window)
    t0 = time.perf_counter()
    for k in range(n_streams):
        ds.submit(_emit_chain_stream(states, weight))
        if k < 2:
            ds.poll()
    dreport = ds.close()
    stats = dreport.session_stats
    # session wall includes cold lowering/compilation of its two epoch
    # structures (the per-stream runner above is compile-warmed); the
    # dispatch/cache columns are the structural comparison.
    emit(name, "session_wall_s", round(time.perf_counter() - t0, 4))
    emit(name, "session_compiles", dreport.exec_stats["compiles"])
    emit(name, "session_epochs", stats["epochs"])
    emit(name, "session_dispatches", stats["device_dispatches"])
    emit(name, "session_plan_cache_hits", stats["plan_cache_hits"])
    emit(name, "session_plan_cache_misses", stats["plan_cache_misses"])
    emit(name, "session_host_syncs", stats["host_syncs"])
    emit(name, "session_matches_serial", int(np.array_equal(snap(states), ref)))
    emit(name, "session_fewer_dispatches_than_per_stream",
         int(stats["device_dispatches"] < dispatches))

    # same workload through the ready-queue epoch executor: every epoch is
    # one while_loop dispatch, and NOTHING in the recurring stream forces a
    # host round-trip — host_syncs stays O(1) for the whole workload (the
    # single close() read-back), not per stream or per kernel.
    states, weight = _chain_universe(n_chains=n_chains)
    ls = make_session("device", window_size=window, plan_mode="loop")
    t0 = time.perf_counter()
    for k in range(n_streams):
        ls.submit(_emit_chain_stream(states, weight))
        if k < 2:
            ls.poll()
    lreport = ls.close()
    lstats = lreport.session_stats
    emit(name, "loop_session_wall_s", round(time.perf_counter() - t0, 4))
    emit(name, "loop_session_epochs", lstats["epochs"])
    emit(name, "loop_session_dispatches", lstats["device_dispatches"])
    emit(name, "loop_session_loop_dispatches", lstats["loop_dispatches"])
    emit(name, "loop_session_plan_cache_hits", lstats["plan_cache_hits"])
    emit(name, "loop_session_host_syncs", lstats["host_syncs"])
    emit(name, "loop_session_host_syncs_d2h", lstats["host_syncs_d2h"])
    emit(name, "loop_session_host_syncs_h2d", lstats["host_syncs_h2d"])
    emit(name, "loop_session_host_syncs_O1", int(lstats["host_syncs"] <= 2))
    emit(name, "loop_session_matches_serial",
         int(np.array_equal(snap(states), ref)))


# ---------------------------------------------------------------------------
# Ready-queue Pallas fast path (forced; interpret mode off-TPU)
# ---------------------------------------------------------------------------

def pallas_loop_leg() -> None:
    """Single-class chain universe through the forced-Pallas ready queue:
    checks the on-device ``lax.switch`` kernel table produces the same
    bits as the while_loop interpreter AND the serial baseline."""
    name = "device_loop_pallas"
    window = opt("window", 32)
    n_chains = 4 if smoke() else 6

    def snap(states):
        return np.stack([np.asarray(s.value) for s in states])

    states, weight = _chain_universe(n_chains=n_chains)
    run_serial(_emit_chain_stream(states, weight))
    ref = snap(states)

    # interpreter lowering (loop_pallas=False)
    states, weight = _chain_universe(n_chains=n_chains)
    interp = DeviceWindowRunner(window_size=window, plan_mode="loop",
                                loop_pallas=False)
    ireport = interp.run(_emit_chain_stream(states, weight))
    interp_snap = snap(states)
    emit(name, "interpreter_executor", ireport.loop_executor)
    emit(name, "interpreter_matches_serial",
         int(np.array_equal(interp_snap, ref)))

    # forced Pallas (interpret mode off-TPU); branch fns must be admitted
    # to the registry switch table for the lowering to take the fast path.
    states, weight = _chain_universe(n_chains=n_chains)
    runner = DeviceWindowRunner(window_size=window, plan_mode="loop",
                                loop_pallas=True)
    register_loop_branches(runner.registry)
    runner.run(_emit_chain_stream(states, weight))  # warm compile
    states, weight = _chain_universe(n_chains=n_chains)
    t0 = time.perf_counter()
    preport = runner.run(_emit_chain_stream(states, weight))
    emit(name, "pallas_wall_s", round(time.perf_counter() - t0, 4))
    emit(name, "pallas_executor", preport.loop_executor)
    emit(name, "pallas_used", int(preport.loop_executor == "pallas"))
    emit(name, "pallas_dispatches", preport.exec_stats["dispatches"])
    pallas_snap = snap(states)
    emit(name, "pallas_matches_serial", int(np.array_equal(pallas_snap, ref)))
    emit(name, "pallas_matches_interpreter",
         int(np.array_equal(pallas_snap, interp_snap)))


def main() -> None:
    for name, build in (_sim_leg(), _dyn_leg()):
        compare(name, build)
    if "device" in chosen_policies(("device",)):
        session_compare()
        pallas_loop_leg()


if __name__ == "__main__":
    main()
