"""Table II reproduction: dependency-check latency vs (window size,
segments per kernel). The paper reports 410ns-1.64us in its C++ runtime;
the reproduced quantity is one incoming kernel checked against the whole
window. Two paths are measured: the scalar per-resident loop (Algorithm 1
verbatim) and the vectorized whole-window pass the production window uses
(core.segments.window_upstreams). Python/numpy carries a constant-factor
overhead vs the paper's native runtime — what must hold (and is gated)
is the §IV-D budget analogue on THIS runtime: the per-insertion check
must be comparable to (<2x) one host kernel dispatch, the unit of work
it schedules."""

from __future__ import annotations

import time

import numpy as np

from repro.core import Segment, SegmentSet, depends_on
from repro.core.segments import window_upstreams
from .common import emit


def _mksets(rng, window, n_segments):
    def mkset():
        return SegmentSet([
            Segment(int(rng.randint(0, 1 << 30)), int(rng.randint(64, 4096)))
            for _ in range(n_segments)
        ])

    resident = [(mkset(), mkset()) for _ in range(window)]
    return resident, (mkset(), mkset())


def bench_scalar(window: int, n_segments: int, iters: int = 300) -> float:
    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    t0 = time.perf_counter()
    for _ in range(iters):
        for r_old, w_old in resident:
            depends_on(incoming[0], incoming[1], r_old, w_old)
    return (time.perf_counter() - t0) / iters * 1e9


def bench_vectorized(window: int, n_segments: int, iters: int = 300) -> float:
    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    rr = [r for r, _ in resident]
    ww = [w for _, w in resident]
    window_upstreams(incoming[0], incoming[1], rr, ww)  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        window_upstreams(incoming[0], incoming[1], rr, ww)
    return (time.perf_counter() - t0) / iters * 1e9


def bench_stacked(window: int, n_segments: int, iters: int = 1000) -> float:
    """Steady-state window (pre-stacked arrays): the pure interval math."""
    from repro.core.segments import StackedWindow

    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    sw = StackedWindow([r for r, _ in resident], [w for _, w in resident])
    sw.check(incoming[0], incoming[1])  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        sw.check(incoming[0], incoming[1])
    return (time.perf_counter() - t0) / iters * 1e9


def main() -> None:
    for window in (16, 32):
        for segs in (6, 10):
            emit("table2_depcheck", f"w{window}_s{segs}_scalar_ns",
                 round(bench_scalar(window, segs)))
            emit("table2_depcheck", f"w{window}_s{segs}_stacked_ns",
                 round(bench_stacked(window, segs)))
    # §IV-D budget on THIS runtime: the check must stay under the cost of
    # the work it schedules — one host dispatch of a small jitted kernel.
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = jnp.ones(256)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(100):
        jax.block_until_ready(f(x))
    dispatch_ns = (time.perf_counter() - t0) / 100 * 1e9

    ns32 = bench_stacked(32, 10)
    emit("table2_depcheck", "stacked_w32_s10_us", round(ns32 / 1000, 2))
    emit("table2_depcheck", "host_dispatch_us", round(dispatch_ns / 1000, 2))
    emit("table2_depcheck", "check_vs_dispatch_ratio",
         round(ns32 / dispatch_ns, 2))
    emit("table2_depcheck", "check_within_2x_dispatch",
         int(ns32 < 2.0 * dispatch_ns))


if __name__ == "__main__":
    main()
