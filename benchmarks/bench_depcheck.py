"""Table II reproduction: dependency-check latency vs (window size,
segments per kernel). The paper reports 410ns-1.64us in its C++ runtime;
the reproduced quantity is one incoming kernel checked against the whole
window. Python/numpy carries a constant-factor overhead vs the paper's
native runtime — what must hold (and is gated) is the §IV-D budget
analogue on THIS runtime: the per-insertion check must be comparable to
(<2x) one host kernel dispatch, the unit of work it schedules.

Three paths are measured:

* scalar per-resident loop (Algorithm 1 verbatim) — the oracle;
* the vectorized whole-window scan (``segments.window_upstreams``: stack
  the residents' segments + one broadcasted pass) — the seed window's
  per-insertion check, O(window x segments^2). ``stacked`` isolates the
  pure interval math on pre-built arrays;
* the interval scoreboard (``core.scoreboard``) — the production path
  since the scoreboard refactor. Its leg measures the steady-state
  per-task cost (retire oldest + probe/insert incoming, the full
  rolling-window transaction), which must beat the whole-window scan at
  window >= 64 and grow sublinearly in window size — that is the property
  that makes window 128-512 affordable (gated below).
"""

from __future__ import annotations

import collections
import time

import numpy as np

from repro.core import IntervalScoreboard, Segment, SegmentSet, depends_on
from repro.core.segments import window_upstreams

from .common import emit, smoke


def _mkset(rng, n_segments):
    return SegmentSet([
        Segment(int(rng.randint(0, 1 << 30)), int(rng.randint(64, 4096)))
        for _ in range(n_segments)
    ])


def _mksets(rng, window, n_segments):
    resident = [(_mkset(rng, n_segments), _mkset(rng, n_segments))
                for _ in range(window)]
    return resident, (_mkset(rng, n_segments), _mkset(rng, n_segments))


def bench_scalar(window: int, n_segments: int, iters: int = 300) -> float:
    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    t0 = time.perf_counter()
    for _ in range(iters):
        for r_old, w_old in resident:
            depends_on(incoming[0], incoming[1], r_old, w_old)
    return (time.perf_counter() - t0) / iters * 1e9


def bench_pairwise_scan(window: int, n_segments: int, iters: int = 100) -> float:
    """The seed per-insertion check: stack every resident's segments and
    run one broadcasted pass (what ``SchedulingWindow._fill`` did)."""
    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    rr = [r for r, _ in resident]
    ww = [w for _, w in resident]
    window_upstreams(incoming[0], incoming[1], rr, ww)  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        window_upstreams(incoming[0], incoming[1], rr, ww)
    return (time.perf_counter() - t0) / iters * 1e9


def bench_stacked(window: int, n_segments: int, iters: int = 1000) -> float:
    """Pre-stacked window arrays: the pure broadcasted interval math."""
    from repro.core.segments import StackedWindow

    resident, incoming = _mksets(np.random.RandomState(0), window, n_segments)
    sw = StackedWindow([r for r, _ in resident], [w for _, w in resident])
    sw.check(incoming[0], incoming[1])  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        sw.check(incoming[0], incoming[1])
    return (time.perf_counter() - t0) / iters * 1e9


def bench_scoreboard(window: int, n_segments: int, iters: int = 400):
    """Steady-state rolling-window transaction on the scoreboard: retire
    the oldest resident, probe + insert the incoming kernel. Returns
    (ns per transaction, probed cells per insertion, live boundaries)."""
    rng = np.random.RandomState(0)
    sb = IntervalScoreboard()
    live = collections.deque()
    streams = [(_mkset(rng, n_segments), _mkset(rng, n_segments))
               for _ in range(window + iters)]
    tid = 0
    for _ in range(window):
        sb.insert(tid, *streams[tid])
        live.append(tid)
        tid += 1
    probes0 = sb.probe_cells
    t0 = time.perf_counter()
    for _ in range(iters):
        sb.retire(live.popleft())
        sb.insert(tid, *streams[tid])
        live.append(tid)
        tid += 1
    per_ns = (time.perf_counter() - t0) / iters * 1e9
    probes_per = (sb.probe_cells - probes0) / iters
    return per_ns, probes_per, sb.boundaries


def main() -> None:
    iters = 60 if smoke() else 300
    for window in (16, 32):
        for segs in (6, 10):
            emit("table2_depcheck", f"w{window}_s{segs}_scalar_ns",
                 round(bench_scalar(window, segs, iters)))
            emit("table2_depcheck", f"w{window}_s{segs}_stacked_ns",
                 round(bench_stacked(window, segs, max(iters, 200))))

    # Scoreboard vs the seed whole-window scan, across the window sweep
    # the scoreboard exists to unlock. Acceptance bars: the scoreboard
    # beats the scan from window 64 up, and its cost grows sublinearly
    # (window x4 from 64 -> 256 must cost < x2).
    segs = 10
    sb_iters = 200 if smoke() else 400
    scan_iters = 60 if smoke() else 100
    sb_cost = {}
    for window in (16, 32, 64, 128, 256):
        sb_ns, probes_per, boundaries = bench_scoreboard(window, segs, sb_iters)
        scan_ns = bench_pairwise_scan(window, segs, scan_iters)
        sb_cost[window] = sb_ns
        emit("table2_depcheck", f"w{window}_s{segs}_scoreboard_ns", round(sb_ns))
        emit("table2_depcheck", f"w{window}_s{segs}_pairwise_scan_ns",
             round(scan_ns))
        emit("table2_depcheck", f"w{window}_s{segs}_probes_per_insert",
             round(probes_per, 1))
        emit("table2_depcheck", f"w{window}_s{segs}_boundaries", boundaries)
        emit("table2_depcheck", f"w{window}_s{segs}_scan_over_scoreboard",
             round(scan_ns / sb_ns, 2))
        if window >= 64:
            emit("table2_depcheck", f"scoreboard_beats_scan_w{window}",
                 int(sb_ns < scan_ns))
    growth = sb_cost[256] / sb_cost[64]
    emit("table2_depcheck", "scoreboard_growth_64_to_256", round(growth, 2))
    emit("table2_depcheck", "scoreboard_sublinear_64_to_256", int(growth < 2.0))

    # §IV-D budget on THIS runtime: the check must stay under the cost of
    # the work it schedules — one host dispatch of a small jitted kernel.
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = jnp.ones(256)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(100):
        jax.block_until_ready(f(x))
    dispatch_ns = (time.perf_counter() - t0) / 100 * 1e9

    ns32 = bench_stacked(32, 10)
    sb256 = sb_cost[256]
    emit("table2_depcheck", "stacked_w32_s10_us", round(ns32 / 1000, 2))
    emit("table2_depcheck", "scoreboard_w256_s10_us", round(sb256 / 1000, 2))
    emit("table2_depcheck", "host_dispatch_us", round(dispatch_ns / 1000, 2))
    emit("table2_depcheck", "check_vs_dispatch_ratio",
         round(ns32 / dispatch_ns, 2))
    emit("table2_depcheck", "check_within_2x_dispatch",
         int(ns32 < 2.0 * dispatch_ns))
    emit("table2_depcheck", "scoreboard_w256_within_2x_dispatch",
         int(sb256 < 2.0 * dispatch_ns))


if __name__ == "__main__":
    main()
