"""Figs 2/24 reproduction: achieved occupancy. Baseline serial execution
of small-kernel simulation streams underutilizes the device (paper: ~34%);
ACS roughly doubles it. Occupancy here is the modeled active-slot fraction
(busy slot-time / total slot-time) plus the wave-width proxy from the real
scheduler run."""

from __future__ import annotations

from repro.core import RTX3060_LIKE, simulate
from repro.core.device_dispatch import plan_waves

from .common import emit, paper_scale_sim_tasks


def main() -> None:
    base_occ, acs_occ = [], []
    for env in ("ant", "grasp", "humanoid", "cheetah", "walker2d"):
        tasks = paper_scale_sim_tasks(env)

        serial = simulate([[t] for t in tasks], RTX3060_LIKE, "serial")
        waves = plan_waves(tasks, window_size=32)
        hw = simulate(waves, RTX3060_LIKE, "acs_hw")
        base_occ.append(serial["occupancy"])
        acs_occ.append(hw["occupancy"])
        emit("fig24_occupancy", f"{env}_baseline", round(serial["occupancy"], 3))
        emit("fig24_occupancy", f"{env}_acs_hw", round(hw["occupancy"], 3))

        widths = [len(w) for w in plan_waves(tasks, window_size=32)]
        emit("fig24_occupancy", f"{env}_wave_width_proxy",
             round(sum(widths) / len(widths), 2))
    emit("fig24_occupancy", "mean_baseline",
         round(sum(base_occ) / len(base_occ), 3))
    emit("fig24_occupancy", "mean_acs_hw",
         round(sum(acs_occ) / len(acs_occ), 3))


if __name__ == "__main__":
    main()
