"""Open-loop serving latency: session-frontier vs per-step batch drains.

The ACS runtime argument (paper §III-D, DESIGN.md §10) is that the window
must be *continuously refilled while kernels execute*. This section
measures what that buys a server: requests arrive open-loop (Poisson, the
arrival process does not wait for the server), and we compare

* ``SessionServer(scheduler="frontier")`` — admission emits prefills into
  the live window at pump cadence, while the previous decode group is
  still in flight;
* ``ContinuousBatchingServer`` — the seed per-step design: each iteration
  rebuilds a stream and drains it to empty, so a request arriving mid-step
  waits out the whole running drain before its prefill is even admitted;
* ``SessionServer(scheduler="device")`` — the persistent device window as
  a serving session (epoch drains between pumps; measured for context and
  for its per-epoch stats — slot values are opaque pytrees, so serving
  kernels take the session's in-epoch host path).

Methodology (DESIGN.md §10): both servers are compile-warmed (every decode
arity — a missed arity costs a ~1s jit burst mid-run), the offered load is
calibrated to ~75% of the batch server's closed-loop capacity, and both
servers then serve the *same* Poisson arrival waves (equal offered load).
Latency runs from scheduled arrival to last-token retirement, so admission
queueing is charged to the server. The comparison is **paired**: each wave
runs on both servers back-to-back (order alternating per wave) and the
headline is the median over waves of the per-wave p95 ratio — on a noisy
shared host, absolute percentiles drift with whatever else the machine is
doing, but a paired ratio mostly cancels it. Pooled percentiles are also
emitted for context. The session server keeps ONE live session open across
all waves (the point of the PR); the batch server drains per step.

Headline: session beats batch on the median paired p95 ratio (plus
p50/p95/p99, throughput, admission-wait, and window residency context).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs import ARCHS
from repro.models import init_params

from .common import emit, opt, smoke


def _bench_cfg():
    # big enough that one decode round costs ~10ms (structural latency
    # differences must dominate host scheduling jitter), small enough
    # that warmup compiles stay in seconds
    return dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(),
        n_layers=4, d_model=256, d_ff=768, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=64,
    )


def _drive(server, is_session, prompts, arrivals, max_new):
    """Open-loop event loop: inject each request at its scheduled arrival;
    otherwise pump (session) / step (batch); idle-sleep only when the
    server is empty and the next arrival is in the future."""
    n = len(prompts)
    t0 = time.perf_counter()
    nxt = 0
    done = []
    while len(done) < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            req = server.submit(prompts[nxt], max_new=max_new)
            req.t_arrival = t0 + arrivals[nxt]  # latency from scheduled arrival
            nxt += 1
        finished = server.pump() if is_session else server.step()
        done.extend(finished)
        if not finished:
            if is_session and (server.active or server.queue):
                server.session.drive()  # block for one retirement
            elif not server.active and not server.queue and nxt < n:
                time.sleep(min(max(arrivals[nxt] - (time.perf_counter() - t0), 0.0),
                               0.001))
    return done, time.perf_counter() - t0


def main() -> None:
    import jax

    from repro.runtime import ContinuousBatchingServer, SessionServer

    cfg = _bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
    n_req = 12 if smoke() else 20      # per wave
    n_waves = 4 if smoke() else 5
    max_new = 4 if smoke() else 6
    max_slots = 4
    max_len = 16 + max_new + 4
    window = opt("window", 16)

    # fixed prompt length => one prefill signature (compile cost amortizes
    # identically for both servers). Each draw gets its own RandomState:
    # a shared stream would make warm_prompts depend on n_req (which
    # differs between --smoke and full runs), so the warmup trace — and
    # anything downstream of it — would silently change with sizing flags.
    prompt_rng = np.random.RandomState(0)
    prompts = [prompt_rng.randint(0, cfg.vocab, 16) for _ in range(n_req)]
    warm_rng = np.random.RandomState(1)
    warm_prompts = [warm_rng.randint(0, cfg.vocab, 16)
                    for _ in range(max_slots)]

    def _warm(server):
        """Closed-loop warmup: one drained round per concurrency level k
        compiles EVERY decode arity 1..max_slots (a decode's jit signature
        includes its slot arity; a missed arity costs a ~1s compile
        mid-run): k requests admitted together decode as an arity-k group
        for several rounds before any of them finishes."""
        for k in range(1, max_slots + 1):
            for p in warm_prompts[:k]:
                server.submit(p, max_new=3)
            server.run_until_drained()
        server.report_log.clear()

    batch_server = ContinuousBatchingServer(cfg, params, max_slots=max_slots,
                                            max_len=max_len, window=window)
    _warm(batch_server)
    session_server = SessionServer(cfg, params, max_slots=max_slots,
                                   max_len=max_len, window=window,
                                   scheduler="frontier",
                                   max_inflight=opt("inflight", 8))
    _warm(session_server)
    # the persistent device window as a serving session: slot values are
    # opaque cache pytrees, so every kernel takes the in-epoch host path —
    # measured for its epoch/admission structure (epoch stats emitted at
    # close), not for arena residency
    device_server = SessionServer(cfg, params, max_slots=max_slots,
                                  max_len=max_len, window=window,
                                  scheduler="device")
    _warm(device_server)

    # Calibrate offered load on the warmed batch server: closed-loop
    # makespan of one slot-set gives the mean service time; arrivals are
    # then Poisson at ~75% of that capacity — loaded, not saturated.
    t0 = time.perf_counter()
    for p in prompts[:max_slots]:
        batch_server.submit(p, max_new=max_new)
    batch_server.run_until_drained()
    batch_server.report_log.clear()
    per_req = (time.perf_counter() - t0) / max_slots
    rate = 0.75 / max(per_req, 1e-4)  # requests/second
    waves = [np.cumsum(np.random.RandomState(1000 + w).exponential(1.0 / rate,
                                                                   size=n_req))
             for w in range(n_waves)]
    emit("serving", "offered_rate_rps", round(rate, 2))
    emit("serving", "n_requests", n_req * n_waves)

    servers = {"batch": (batch_server, False),
               "session_frontier": (session_server, True),
               "session_device": (device_server, True)}
    lat = {k: [] for k in servers}
    admit_wait = {k: [] for k in servers}
    span = {k: 0.0 for k in servers}
    ratios = []
    for w, arrivals in enumerate(waves):
        wave_p95 = {}
        # The headline pair (batch vs session_frontier) stays ADJACENT and
        # strictly order-alternating — exactly the PR3 pairing, so host
        # drift cancels in the ratio; the device server alternates around
        # the pair so its own drift exposure averages out too.
        pair = (("batch", "session_frontier") if w % 2 == 0
                else ("session_frontier", "batch"))
        order = (pair + ("session_device",) if w % 2 == 0
                 else ("session_device",) + pair)
        for name in order:
            server, is_session = servers[name]
            done, makespan = _drive(server, is_session, prompts, arrivals,
                                    max_new)
            assert len(done) == n_req, f"{name}: {len(done)}/{n_req} finished"
            assert all(len(r.generated) == max_new for r in done)
            wave_lat = [r.latency for r in done]
            wave_p95[name] = float(np.percentile(wave_lat, 95))
            lat[name].extend(wave_lat)
            admit_wait[name].extend(r.t_admit - r.t_arrival for r in done)
            span[name] += makespan
        ratios.append(wave_p95["batch"] / max(wave_p95["session_frontier"], 1e-9))

    for name, (server, is_session) in servers.items():
        if is_session:
            wstats = server.session.window_stats()
            max_resident = wstats["max_resident"]
            emit("serving", f"{name}_mean_resident",
                 round(float(np.mean(server.occupancy_samples or [0])), 2))
            # dependency-engine accounting: interval cells probed vs the
            # pairwise checks Algorithm 1 would have burned per admit
            emit("serving", f"{name}_probes_per_insert",
                 round(wstats["scoreboard_probes"] / max(wstats["inserted"], 1), 2))
        else:
            max_resident = max([e.get("window_max_resident", 0)
                                for e in server.report_log] or [0])
        for p in (50, 95, 99):
            emit("serving", f"{name}_p{p}_ms",
                 round(float(np.percentile(lat[name], p)) * 1e3, 1))
        emit("serving", f"{name}_throughput_rps",
             round(n_req * n_waves / span[name], 2))
        emit("serving", f"{name}_admit_wait_p95_ms",
             round(float(np.percentile(admit_wait[name], 95)) * 1e3, 1))
        emit("serving", f"{name}_window_max_resident", int(max_resident))

    session_server.close()
    device_server.close()
    dstats = device_server.report_log[-1]["device_session"]
    emit("serving", "session_device_epochs", dstats["epochs"])
    emit("serving", "session_device_plan_mode", dstats["plan_mode"])
    emit("serving", "session_device_host_syncs", dstats["host_syncs"])
    # audited split (DESIGN §2 A3): every host<->device transition is
    # attributed to a direction and to the stream tag that forced it, so
    # "who is making us sync" reads straight off the bench output.
    emit("serving", "session_device_host_syncs_d2h", dstats["host_syncs_d2h"])
    emit("serving", "session_device_host_syncs_h2d", dstats["host_syncs_h2d"])
    for tag in sorted(dstats["host_syncs_by_tag"]):
        emit("serving", f"session_device_host_syncs_tag_{tag}",
             dstats["host_syncs_by_tag"][tag])
    emit("serving", "session_device_host_task_dispatches",
         dstats["host_task_dispatches"])
    speedup = float(np.median(ratios))
    emit("serving", "paired_wave_p95_ratios",
         "|".join(f"{r:.2f}" for r in ratios))
    emit("serving", "session_p95_speedup", round(speedup, 3))
    emit("serving", "session_beats_batch_p95", int(speedup > 1.0))


if __name__ == "__main__":
    main()
