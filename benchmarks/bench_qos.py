"""Multi-tenant QoS serving plane (DESIGN.md §13): per-class tail latency
under adversarial mixes, with scheduling-only guarantees.

Two serving mixes (the §13 adversarial pair) plus a window-level leg:

* **Short high-priority arrivals into a full window** — a flooding tenant
  fills every slot with long decode chains; short interactive requests
  then arrive one at a time. Compared three ways on the SAME prompts:
  unloaded (each interactive request served alone — the floor), the
  fairness-only scheduler (pre-QoS knobs: one priority class, no
  preemption), and the QoS plane (priority classes + cooperative
  preemption at segment/epoch boundaries). Gates: QoS keeps the
  interactive-class p99 within 2x the unloaded floor while aggregate
  tokens/sec stays within 5% of the fairness-only baseline, and every
  request's token stream is bit-identical between the QoS and fairness
  runs — preemption (park/resume of opaque slot state) changes WHEN a
  chain runs, never what it computes. Timing gates use the median of
  several paired trials (same prompts, fairness and QoS runs
  interleaved): on a noisy shared host a paired ratio mostly cancels
  the load, exactly the bench_serving methodology; the pooled p99/p99.9
  per class are emitted for the record.

* **One-tenant flood vs a quiet tenant** — the flood submits a strictly
  higher-priority backlog; the quiet tenant's single low-priority request
  must still be admitted before the flood fully drains (aging promotes it
  within ``priority * aging_s``). Admission ORDER is the claim, so this
  mix needs no warmup and runs on the batch server's admission plane.

* **Window / mesh leg** — priority-bucketed READY ordering at the
  SchedulingWindow level (fresh urgent inserts jump ahead of a resident
  flood), and the mixed-priority hazard stream staying bit-identical to
  ``run_serial`` through the device loop lowering and the mesh-sharded
  session (priority-aware placement; runs at whatever device count XLA
  exposes — the CI mesh lane forces 8).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs import ARCHS
from repro.models import init_params

from .common import emit, smoke


def _bench_cfg():
    # small enough that warmup compiles stay in seconds, big enough that a
    # decode round has measurable cost (the tail-latency claims compare
    # scheduling structure, not kernel speed)
    return dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(),
        n_layers=2, d_model=128, d_ff=384, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=32,
    )


def _p(lats, q):
    return round(float(np.percentile(lats, q)) * 1e3, 2)


def _warm(server, prompts, max_slots):
    """Compile every decode arity 1..max_slots before measuring."""
    for k in range(1, max_slots + 1):
        for p in prompts[:k]:
            server.submit(p, max_new=3)
        server.run_until_drained()
    server.report_log.clear()


def _serve_until(server, req):
    """Pump (and block on retirement when idle) until ``req`` finishes;
    returns every request that finished along the way."""
    done = []
    while not req.finished:
        got = server.pump()
        done.extend(got)
        if not got:
            server.session.drive()
    return done


def _run_mix(server, flood_prompts, high_prompts, flood_new, high_new,
             flood_prio, high_prio):
    """The full-window mix: admit the flood first, then inject the short
    requests one at a time (each waits for the previous — the interactive
    pattern). Returns (per-request tokens by rid-order, high latencies,
    wall, total tokens)."""
    t0 = time.perf_counter()
    flood = [server.submit(p, max_new=flood_new, tenant="flood",
                           priority=flood_prio)
             for p in flood_prompts]
    server.pump()  # flood takes every slot before any high request exists
    done = []
    highs = []
    for p in high_prompts:
        r = server.submit(p, max_new=high_new, tenant="interactive",
                          priority=high_prio)
        highs.append(r)
        done.extend(_serve_until(server, r))
    done.extend(server.run_until_drained())
    wall = time.perf_counter() - t0
    assert len(done) == len(flood) + len(highs)
    tokens = {r.rid - flood[0].rid: list(r.generated) for r in done}
    return tokens, [r.latency for r in highs], wall, sum(
        len(g) for g in tokens.values())


def main() -> None:
    import jax

    from repro.runtime import (PRIORITY_HIGH, PRIORITY_LOW, PRIORITY_NORMAL,
                               ContinuousBatchingServer, SessionServer)

    cfg = _bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
    n_flood = 4 if smoke() else 8
    n_high = 5 if smoke() else 10
    flood_new = 10 if smoke() else 16
    high_new = 5
    max_slots = 2
    max_len = 8 + flood_new + 4
    trials = 5

    rng = np.random.RandomState(0)
    flood_prompts = [rng.randint(0, cfg.vocab, 8) for _ in range(n_flood)]
    high_prompts = [rng.randint(0, cfg.vocab, 8) for _ in range(n_high)]
    emit("qos", "n_flood", n_flood)
    emit("qos", "n_high", n_high)
    emit("qos", "trials", trials)

    def _make(preempt):
        return SessionServer(cfg, params, max_slots=max_slots,
                             max_len=max_len, scheduler="frontier",
                             preempt_rounds=preempt)

    # ---- mix 1: short high-priority arrivals into a full window ----------
    fair = _make(preempt=None)          # pre-QoS knobs: one class, no parks
    _warm(fair, high_prompts, max_slots)
    qos = _make(preempt=2)
    _warm(qos, high_prompts, max_slots)

    unloaded_all, fair_all, qos_all = [], [], []
    lat_ratios, tps_ratios = [], []
    matches = True
    for _ in range(trials):
        # unloaded floor, re-measured each trial on the warmed QoS server
        # before the flood (an empty queue reduces the plane to plain FIFO)
        unloaded = []
        for p in high_prompts:
            r = qos.submit(p, max_new=high_new, tenant="interactive")
            _serve_until(qos, r)
            unloaded.append(r.latency)
        fair_tok, fair_lat, fair_wall, fair_tokens = _run_mix(
            fair, flood_prompts, high_prompts, flood_new, high_new,
            PRIORITY_NORMAL, PRIORITY_NORMAL)
        qos_tok, qos_lat, qos_wall, qos_tokens = _run_mix(
            qos, flood_prompts, high_prompts, flood_new, high_new,
            PRIORITY_LOW, PRIORITY_HIGH)
        unloaded_all.extend(unloaded)
        fair_all.extend(fair_lat)
        qos_all.extend(qos_lat)
        lat_ratios.append(float(np.percentile(qos_lat, 99))
                          / float(np.percentile(unloaded, 99)))
        tps_ratios.append((qos_tokens / qos_wall) / (fair_tokens / fair_wall))
        # preemption moves work in time, never in value: every request's
        # token stream must be bit-identical to the fairness (no-QoS) run
        matches = matches and fair_tok == qos_tok

    emit("qos", "unloaded_high_p99_ms", _p(unloaded_all, 99))
    for name, lat in (("fairness", fair_all), ("qos", qos_all)):
        emit("qos", f"{name}_high_p99_ms", _p(lat, 99))
        emit("qos", f"{name}_high_p99_9_ms", _p(lat, 99.9))
    emit("qos", "qos_high_p99_vs_unloaded_median_ratio",
         round(float(np.median(lat_ratios)), 2))
    emit("qos", "qos_vs_fairness_tokens_median_ratio",
         round(float(np.median(tps_ratios)), 3))
    emit("qos", "qos_preemptions", qos.preemptions)
    emit("qos", "qos_high_p99_within_2x_unloaded",
         int(float(np.median(lat_ratios)) <= 2.0))
    emit("qos", "qos_throughput_within_fairness",
         int(float(np.median(tps_ratios)) >= 0.95))
    emit("qos", "qos_tokens_matches_fairness", int(matches))
    fair.close()
    qos.close()

    # ---- mix 2: one-tenant flood must not starve a quiet tenant ----------
    # admission ORDER is the claim (timing-free), so the batch server's
    # admission plane suffices and no compile warmup is needed
    aged = ContinuousBatchingServer(cfg, params, max_slots=max_slots,
                                    max_len=16, aging_s=0.02)
    flood_reqs = [aged.submit(p, max_new=4, tenant="flood",
                              priority=PRIORITY_HIGH)
                  for p in flood_prompts + flood_prompts]
    quiet = aged.submit(high_prompts[0], max_new=2, tenant="quiet",
                        priority=PRIORITY_LOW)
    while aged.queue or aged.active:
        aged.step()
    emit("qos", "qos_aging_beats_flood_drain",
         int(quiet.t_admit < max(f.t_admit for f in flood_reqs)))

    # ---- window / mesh leg ----------------------------------------------
    import jax.numpy as jnp

    from repro.core import (BufferPool, SchedulingWindow, Task, TaskStream,
                            make_scheduler, make_session, run_serial)
    from repro.core.task import default_segments
    from repro.core.wrapper import AcsKernel
    from repro.kernels.ops import LOOP_BRANCHES

    # priority-bucketed READY order: a full window of low-priority flood
    # tasks, then fresh urgent inserts — they must jump the entire flood
    pool = BufferPool()
    n_low, n_hi = 40, 8
    wbufs = [pool.alloc((4,), np.float32, value=np.zeros(4, np.float32))
             for _ in range(n_low + n_hi)]

    def _mk(buf, priority):
        r, w = default_segments([], [buf])
        return Task(opcode="op", fn=lambda: None, inputs=(),
                    outputs=(buf,), read_segments=r, write_segments=w,
                    priority=priority)

    win = SchedulingWindow(n_low + n_hi)
    win.submit_all([_mk(wbufs[i], 2) for i in range(n_low)])
    hi_tasks = [_mk(wbufs[n_low + i], 0) for i in range(n_hi)]
    win.submit_all(hi_tasks)
    head = win.ready_tasks()[:n_hi]
    emit("qos", "qos_priority_beats_fifo",
         int([t.tid for t in head] == [t.tid for t in hi_tasks]))

    # mixed-priority hazard stream: bit-identity to run_serial through the
    # device loop lowering and the mesh-sharded session (priority-aware
    # placement); runs at whatever device count XLA exposes
    def _build(seed=3):
        srng = np.random.RandomState(seed)
        spool = BufferPool()
        sbufs = [spool.alloc((4,), np.float32,
                             value=jnp.asarray(srng.randn(4).astype(np.float32)))
                 for _ in range(6)]
        kernels = {"axpy": AcsKernel(name="axpy_qos", fn=LOOP_BRANCHES["axpy"]),
                   "mul": AcsKernel(name="mul_qos", fn=LOOP_BRANCHES["mul"])}
        streams = {"hi": TaskStream(tag="hi", priority=0),
                   "lo": TaskStream(tag="lo", priority=2)}
        tasks = []
        for _ in range(24):
            tag = "hi" if srng.rand() < 0.5 else "lo"
            kern = kernels["axpy" if srng.rand() < 0.5 else "mul"]
            tasks.append(kern.launch(
                streams[tag],
                inputs=(sbufs[srng.randint(6)], sbufs[srng.randint(6)]),
                outputs=(sbufs[srng.randint(6)],)))
        return (lambda: np.stack([np.asarray(b.value) for b in sbufs])), tasks

    snap, tasks = _build()
    run_serial(tasks)
    ref = snap()

    snap, tasks = _build()
    make_scheduler("device", window_size=16, plan_mode="loop")(tasks)
    emit("qos", "qos_loop_matches_serial", int(np.array_equal(snap(), ref)))

    snap, tasks = _build()
    session = make_session("mesh", window_size=16)
    feed_rng = np.random.RandomState(7)
    i = 0
    while i < len(tasks):
        k = 1 + feed_rng.randint(6)
        session.submit(tasks[i:i + k])
        i += k
        if feed_rng.rand() < 0.6:
            session.poll()
    session.close()
    emit("qos", "qos_mesh_matches_serial", int(np.array_equal(snap(), ref)))
    emit("qos", "n_devices", jax.device_count())


if __name__ == "__main__":
    main()
