"""Figs 27/28 reproduction: static NAS CNNs (NASNet, AmoebaNet, SqueezeNet,
RandomWire). Static graphs => the CUDAGraph baseline amortizes construction
(construct once) and matches ACS-HW, reproducing the paper's observation;
ACS still beats serial."""

from __future__ import annotations

import numpy as np

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.dyn import WORKLOADS

from .common import emit, modeled_policies, speedup_table, wall

NETS = {"nasnet": "NASNet", "amoebanet": "Amoeba", "squeezenet": "Squeeze",
        "randwire": "RW"}


def build_tasks(name: str, input_seed: int):
    init_fn, build_fn, _ = WORKLOADS[name]
    params = init_fn(0)
    rng = np.random.RandomState(input_seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)
    stream = TaskStream()
    build_fn(params, stream, x)
    return stream.tasks


def main() -> None:
    for name, tag in NETS.items():
        sched = WaveScheduler(window_size=32)
        sched.run(build_tasks(name, 0))
        run_serial(build_tasks(name, 0))
        t_acs = wall(lambda: sched.run(build_tasks(name, 1)), repeats=2)
        t_ser = wall(lambda: run_serial(build_tasks(name, 1)), repeats=2)
        emit("fig27_static_real", f"{tag}_acs_sw_speedup",
             round(t_ser / t_acs, 3))

        tasks = build_tasks(name, 2)
        # static graph: CUDAGraph constructs once (amortized to ~0)
        pol = modeled_policies(tasks, dyn_construct=False)
        speedup_table(f"fig27_static_model_{tag}", pol)
        ok = pol["cudagraph"]["time_us"] <= pol["acs_hw"]["time_us"] * 1.05
        emit(f"fig27_static_model_{tag}", "cudagraph_matches_acshw", int(ok))


if __name__ == "__main__":
    main()
