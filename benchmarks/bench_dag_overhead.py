"""Fig 9 reproduction: full-DAG (CUDA Graph-style) construction time as a
fraction of execution time, per simulation environment. The paper measures
~47% on average for Brax; the point is that per-input DAG construction is
the same order as execution for these streams."""

from __future__ import annotations

from repro.core import RTX3060_LIKE, simulate
from repro.core.dag_baseline import build_full_dag, level_schedule

from .common import cudagraph_construct_us, emit, paper_scale_sim_tasks


def main() -> None:
    fracs_build, fracs_full = [], []
    for env in ("ant", "grasp", "humanoid", "cheetah", "walker2d"):
        tasks = paper_scale_sim_tasks(env)

        edges, checks = build_full_dag(tasks)
        levels = level_schedule(tasks, edges)
        build_us = cudagraph_construct_us(len(tasks), checks,
                                          include_derivation=False)
        full_us = cudagraph_construct_us(len(tasks), checks)

        exec_us = simulate(levels, RTX3060_LIKE, "cudagraph")["time_us"]
        f_build = build_us / (build_us + exec_us)
        f_full = full_us / (full_us + exec_us)
        fracs_build.append(f_build)
        fracs_full.append(f_full)
        emit("fig9_dag_overhead", f"{env}_graphbuild_frac", round(f_build, 3))
        emit("fig9_dag_overhead", f"{env}_with_dep_derivation_frac",
             round(f_full, 3))
        emit("fig9_dag_overhead", f"{env}_dep_checks", checks)
    emit("fig9_dag_overhead", "mean_graphbuild_frac",
         round(sum(fracs_build) / len(fracs_build), 3))
    emit("fig9_dag_overhead", "mean_with_dep_derivation_frac",
         round(sum(fracs_full) / len(fracs_full), 3))


if __name__ == "__main__":
    main()
