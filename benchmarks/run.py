"""Benchmark runner: one section per paper table/figure (DESIGN.md §8).
Prints ``name,metric,value`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--flag=value ...] [section ...]

Flags (consumed by sections via common.opt): --window=N sets the ACS
window size, --streams=K the thread count for the threaded scheduler,
--inflight=M the frontier scheduler's in-flight group cap.
"""

from __future__ import annotations

import sys
import time

from . import (
    bench_dag_overhead,
    bench_depcheck,
    bench_dynamic_dnn,
    bench_frontier,
    bench_moe_waves,
    bench_occupancy,
    bench_rl_e2e,
    bench_sim_speedup,
    bench_static_dnn,
    bench_window_size,
    common,
)

SECTIONS = {
    "depcheck": bench_depcheck,          # Table II
    "dag_overhead": bench_dag_overhead,  # Fig 9
    "sim_speedup": bench_sim_speedup,    # Figs 21/22
    "rl_e2e": bench_rl_e2e,              # Fig 23
    "occupancy": bench_occupancy,        # Figs 2/24
    "dynamic_dnn": bench_dynamic_dnn,    # Figs 25/26
    "static_dnn": bench_static_dnn,      # Figs 27/28
    "window_size": bench_window_size,    # Fig 29
    "moe_waves": bench_moe_waves,        # beyond-paper (DESIGN §4)
    "frontier": bench_frontier,          # beyond-paper (DESIGN §9)
}


def main() -> None:
    chosen = []
    for arg in sys.argv[1:]:
        if arg.startswith("--") and "=" in arg:
            key, _, value = arg[2:].partition("=")
            if key not in common.FLAG_KEYS:
                raise SystemExit(
                    f"unknown flag --{key}; choose from: "
                    + ", ".join(f"--{k}=N" for k in common.FLAG_KEYS)
                )
            if not value.isdigit() or int(value) < 1:
                raise SystemExit(f"--{key} expects a positive integer, got {value!r}")
            common.OPTIONS[key] = value
        else:
            chosen.append(arg)
    unknown = [n for n in chosen if n not in SECTIONS]
    if unknown:
        raise SystemExit(
            f"unknown section(s) {unknown}; choose from: {', '.join(SECTIONS)}"
        )
    chosen = chosen or list(SECTIONS)
    print("section,metric,value")
    for name in chosen:
        mod = SECTIONS[name]
        t0 = time.time()
        mod.main()
        print(f"_timing,{name}_seconds,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
