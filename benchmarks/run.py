"""Benchmark runner: one section per paper table/figure (DESIGN.md §8).
Prints ``name,metric,value`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [section ...]
"""

from __future__ import annotations

import sys
import time

from . import (
    bench_dag_overhead,
    bench_depcheck,
    bench_dynamic_dnn,
    bench_moe_waves,
    bench_occupancy,
    bench_rl_e2e,
    bench_sim_speedup,
    bench_static_dnn,
    bench_window_size,
)

SECTIONS = {
    "depcheck": bench_depcheck,          # Table II
    "dag_overhead": bench_dag_overhead,  # Fig 9
    "sim_speedup": bench_sim_speedup,    # Figs 21/22
    "rl_e2e": bench_rl_e2e,              # Fig 23
    "occupancy": bench_occupancy,        # Figs 2/24
    "dynamic_dnn": bench_dynamic_dnn,    # Figs 25/26
    "static_dnn": bench_static_dnn,      # Figs 27/28
    "window_size": bench_window_size,    # Fig 29
    "moe_waves": bench_moe_waves,        # beyond-paper (DESIGN §4)
}


def main() -> None:
    chosen = sys.argv[1:] or list(SECTIONS)
    print("section,metric,value")
    for name in chosen:
        mod = SECTIONS[name]
        t0 = time.time()
        mod.main()
        print(f"_timing,{name}_seconds,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
