"""Benchmark runner: one section per paper table/figure (DESIGN.md §8).
Prints ``name,metric,value`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--flag=value ...] [section ...]

Flags (consumed by sections via benchmarks.common):
  --window=N       ACS window size
  --streams=K      thread count for the threaded scheduler
  --inflight=M     frontier scheduler's in-flight group cap
  --plan-mode=P    device runner plan lowering: wave | frontier | loop
  --scheduler=S    restrict comparison sections to serial + S
  --json=PATH      also write every emitted row (plus flags and per-section
                   timings) as machine-readable JSON — the BENCH_*.json
                   perf-trajectory format CI uploads as an artifact
  --smoke          CI-sized inputs; defaults to the plan-lowering sections
"""

from __future__ import annotations

import json
import sys
import time

from . import (
    bench_dag_overhead,
    bench_depcheck,
    bench_device,
    bench_dynamic_dnn,
    bench_frontier,
    bench_mesh_scaling,
    bench_moe_waves,
    bench_occupancy,
    bench_qos,
    bench_rl_e2e,
    bench_serving,
    bench_sim_speedup,
    bench_soak,
    bench_static_dnn,
    bench_window_size,
    common,
)

SECTIONS = {
    "depcheck": bench_depcheck,          # Table II
    "dag_overhead": bench_dag_overhead,  # Fig 9
    "sim_speedup": bench_sim_speedup,    # Figs 21/22
    "rl_e2e": bench_rl_e2e,              # Fig 23
    "occupancy": bench_occupancy,        # Figs 2/24
    "dynamic_dnn": bench_dynamic_dnn,    # Figs 25/26
    "static_dnn": bench_static_dnn,      # Figs 27/28
    "window_size": bench_window_size,    # Fig 29
    "moe_waves": bench_moe_waves,        # beyond-paper (DESIGN §4)
    "frontier": bench_frontier,          # beyond-paper (DESIGN §9)
    "device": bench_device,              # ACS-HW analogue (DESIGN §2 A3)
    "serving": bench_serving,            # live sessions (DESIGN §10)
    "soak": bench_soak,                  # lifetime invariants (DESIGN §2 A3)
    "mesh_scaling": bench_mesh_scaling,  # mesh-sharded window (DESIGN §12)
    "qos": bench_qos,                    # multi-tenant QoS plane (DESIGN §13)
}

# The sections --smoke runs when none are named: the ones exercising plan
# lowering, the unified scheduler API, the live-session serving path, and
# the scoreboard dependency engine (depcheck's probe-vs-scan counters and
# window_size's window=256 leg over the real sim/dyn streams) — so
# regressions there fail in CI, not at bench time.
SMOKE_SECTIONS = ("depcheck", "device", "frontier", "serving",
                  "window_size", "mesh_scaling", "qos")


def main() -> None:
    chosen = []
    json_path = None
    for arg in sys.argv[1:]:
        if arg == "--smoke":
            common.OPTIONS["smoke"] = "1"
        elif arg.startswith("--json="):
            json_path = arg[len("--json="):]
            if not json_path:
                raise SystemExit("--json expects a path (--json=bench.json)")
        elif arg.startswith("--") and "=" in arg:
            key, _, value = arg[2:].partition("=")
            if key in common.FLAG_KEYS:
                if not value.isdigit() or int(value) < 1:
                    raise SystemExit(f"--{key} expects a positive integer, got {value!r}")
            elif key in common.CHOICE_FLAGS:
                allowed = common.CHOICE_FLAGS[key]
                if value not in allowed:
                    raise SystemExit(
                        f"--{key} expects one of {{{', '.join(allowed)}}}, got {value!r}"
                    )
            else:
                flags = [f"--{k}=N" for k in common.FLAG_KEYS]
                flags += [f"--{k}={{{'|'.join(v)}}}" for k, v in common.CHOICE_FLAGS.items()]
                raise SystemExit(
                    f"unknown flag --{key}; choose from: "
                    + ", ".join(flags + ["--json=PATH", "--smoke"])
                )
            common.OPTIONS[key] = value
        elif arg.startswith("--"):
            raise SystemExit(
                f"malformed flag {arg!r}: flags take --name=value form "
                "(e.g. --scheduler=frontier); --smoke is the only bare flag"
            )
        else:
            chosen.append(arg)
    unknown = [n for n in chosen if n not in SECTIONS]
    if unknown:
        raise SystemExit(
            f"unknown section(s) {unknown}; choose from: {', '.join(SECTIONS)}"
        )
    if not chosen:
        chosen = list(SMOKE_SECTIONS) if common.smoke() else list(SECTIONS)
    print("section,metric,value")
    timings = {}
    for name in chosen:
        mod = SECTIONS[name]
        t0 = time.time()
        mod.main()
        timings[name] = round(time.time() - t0, 1)
        print(f"_timing,{name}_seconds,{timings[name]}")
    if json_path is not None:
        payload = {
            "flags": dict(common.OPTIONS),
            "sections": chosen,
            "timings_seconds": timings,
            "results": common.RESULTS,
        }
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"_json,path,{json_path}")


if __name__ == "__main__":
    main()
