"""Mesh-sharded window scaling: one slab window vs N per-device shards.

The multi-tenant serving problem from DESIGN.md §12: T tenants share one
runtime, each request is a K-deep dependent chain over that tenant's
weights. A single :class:`DeviceSession` sees every tenant's kernel specs
interleaved in one window, so its epoch *structures* churn — each new
tenant mix is a new plan signature, and on this host every new signature
is an XLA retrace. :class:`MeshDeviceSession` shards the window across
devices and places each tenant's chains on the shard that already holds
its weights (read-home affinity), so every shard sees a stable spec
subset and the plan cache converges after warmup.

Capacity here = inverse wall time for the same open-loop arrival trace
(Poisson bursts over T tenants, chain buffers recycled through the pool
free-hook). The A/B is equal-settings: both sides use the ready-queue
``loop`` lowering and ``pad_payloads=True`` (bucketed payload shapes —
the same knob on both sides, so neither gets free shape-canonicalisation
the other lacks).

Gates (CI compares before overwriting BENCH_serving.json):

* ``mesh_n4_beats_single_2p5x`` — 4-shard mesh sustains >= 2.5x the
  single-window capacity on the same trace;
* ``mesh_n4_p95_within_single`` — sharding does not trade tail latency
  for capacity (p95 request latency equal or better);
* ``mesh_n4_fewer_compiles`` — the mechanism check: the win must come
  from retrace elimination, not from timing luck;
* ``mesh_d2d_matches_serial`` / ``mesh_d2d_matches_staged`` — the
  device-to-device transfer path is bit-identical to both the serial
  baseline and the host-staged path on a cross-shard-heavy stream
  (exact payloads: ``pad_payloads`` stays off on this leg);
* ``mesh_d2d_transfer_host_syncs_O1`` — forced d2d moves every
  cross-shard edge without a single ``mesh-transfer``-tagged host sync
  (the staged control shows the nonzero count d2d eliminates);
* ``mesh_d2d_bytes_matches_staged`` — the ShardTransferTable byte audit
  is mode-invariant: both paths account the same rows moved;
* ``mesh_overlap_capacity_within_sequential`` /
  ``mesh_overlap_p95_within_sequential`` — the overlapped drain pump
  sustains at least sequential-drain capacity (tolerance for host
  timing noise) at equal-or-better p95;
* ``mesh_overlap_drains_used`` — ``drain_overlap > 1``: at least two
  shards' epochs were genuinely in flight at once.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BufferPool, TaskStream
from repro.core.device_dispatch import DeviceSession
from repro.core.mesh_session import MeshDeviceSession
from repro.core.wrapper import AcsKernel

from .common import emit, smoke

D = 64           # per-request state vector length
N_TENANTS = 8    # distinct kernel specs competing for the window
CHAIN = 4        # dependent kernels per request (decode-chain analogue)
N_SHARDS = 4     # the ISSUE's N=4 mesh leg


def _make_kernels() -> List[AcsKernel]:
    def mk(i: int):
        c = np.float32(i + 1)

        def fn(x, w):
            return x * np.float32(0.999) + w * c

        fn.__name__ = f"tenant{i}"
        return fn

    return [AcsKernel(name=f"tenant{i}", fn=mk(i)) for i in range(N_TENANTS)]


def _arrival_rounds(n_reqs: int, seed: int) -> List[List[int]]:
    """Poisson bursts of tenant ids: each round is one pump interval's
    admissions, so both sides see identical arrival pressure."""
    rng = np.random.RandomState(seed)
    rounds: List[List[int]] = []
    done = 0
    while done < n_reqs:
        burst = min(int(rng.poisson(3)), n_reqs - done)
        rounds.append([int(rng.randint(N_TENANTS)) for _ in range(burst)])
        done += burst
    return rounds


class _Tenancy:
    """One session's view of the tenant fleet: weights live in the pool
    for the whole session lifetime, request state buffers recycle through
    the free hook. Shared across the warmup and measured traces so plan
    caches see one continuous serving lifetime."""

    def __init__(self, session):
        self.session = session
        self.pool = BufferPool()
        self.pool.add_free_hook(session.release_buffer)
        self.weights = [
            self.pool.alloc((D,), np.float32, name=f"w{i}",
                            value=jnp.arange(D, dtype=jnp.float32) + i)
            for i in range(N_TENANTS)
        ]
        self.rid = 0

    def drive(self, kernels: List[AcsKernel], rounds: List[List[int]]):
        """Run one arrival trace; returns (wall_seconds, latencies)."""
        latencies: List[float] = []
        session = self.session
        t0 = time.perf_counter()
        for round_tenants in rounds:
            for tenant in round_tenants:
                st = self.pool.alloc((D,), np.float32, name=f"req{self.rid}",
                                     value=jnp.ones(D, jnp.float32))
                stream = TaskStream(sink=session, tag=f"t{tenant}",
                                    record=False)
                last = None
                for _ in range(CHAIN):
                    last = kernels[tenant].launch(
                        stream, inputs=(st, self.weights[tenant]),
                        outputs=(st,))
                t_sub = time.perf_counter()

                def _done(_task, name=st.name, t_sub=t_sub):
                    latencies.append(time.perf_counter() - t_sub)
                    self.pool.free(name)

                session.on_task_retired(last, _done)
                self.rid += 1
            session.poll()
        session.flush()
        return time.perf_counter() - t0, latencies


def _cross_shard_stream(pool: BufferPool, kernels: List[AcsKernel]):
    """A cross-shard-heavy fixed stream: N independent two-buffer chains
    (placement spreads them across shards) joined every other round by a
    read of the neighbour chain's state — every join is a cross-shard
    edge once shards differ. Returns (buffers, tasks)."""
    rng = np.random.RandomState(11)
    chains = [
        [pool.alloc((D,), np.float32, name=f"c{c}b{k}",
                    value=jnp.asarray(rng.randn(D).astype(np.float32)))
         for k in range(2)]
        for c in range(N_SHARDS)
    ]
    stream = TaskStream()
    tasks = []
    for r in range(6):
        for c in range(N_SHARDS):
            a, b = chains[c]
            tasks.append(kernels[0].launch(stream, inputs=(a, b),
                                           outputs=(a,)))
            tasks.append(kernels[1].launch(stream, inputs=(a, b),
                                           outputs=(b,)))
        if r % 2 == 1:
            for c in range(N_SHARDS):
                other = chains[(c + 1) % N_SHARDS][0]
                a = chains[c][0]
                tasks.append(kernels[0].launch(stream, inputs=(other, a),
                                               outputs=(a,)))
    bufs = [b for ch in chains for b in ch]
    return bufs, tasks


def _mesh_transfer_syncs(stats: Dict) -> int:
    return sum(s.get("host_syncs_by_tag", {}).get("mesh-transfer", 0)
               for s in stats.get("per_shard", []))


def _d2d_differential() -> None:
    """The transfer-protocol A/B: the same cross-shard stream through
    run_serial, a forced-staged mesh, and a forced-d2d mesh. Bit-identity
    requires exact payloads, so ``pad_payloads`` stays off here (both
    mesh sides alike — the timing legs above keep their bucketing)."""
    from repro.core import run_serial

    kernels = _make_kernels()[:2]

    def run(mode):
        pool = BufferPool()
        bufs, tasks = _cross_shard_stream(pool, kernels)
        if mode == "serial":
            run_serial(tasks)
            return np.stack([np.asarray(b.value) for b in bufs]), None
        sess = MeshDeviceSession(window_size=64, n_shards=N_SHARDS,
                                 transfer_mode=mode)
        sess.submit(tasks)
        sess.close()
        return (np.stack([np.asarray(b.value) for b in bufs]),
                sess.session_stats())

    ref, _ = run("serial")
    staged_vals, staged = run("staged")
    d2d_vals, d2d = run("d2d")

    emit("mesh_scaling", "d2d_cross_shard_edges", d2d["cross_shard_edges"])
    emit("mesh_scaling", "d2d_moves", d2d["d2d_moves"])
    emit("mesh_scaling", "d2d_fallback_moves", d2d["d2d_fallbacks"])
    emit("mesh_scaling", "d2d_row_invalidations", d2d["row_invalidations"])
    emit("mesh_scaling", "d2d_transfer_bytes", d2d["transfers"]["bytes"])
    emit("mesh_scaling", "staged_transfer_bytes", staged["transfers"]["bytes"])
    emit("mesh_scaling", "d2d_mesh_transfer_host_syncs",
         _mesh_transfer_syncs(d2d))
    emit("mesh_scaling", "staged_mesh_transfer_host_syncs",
         _mesh_transfer_syncs(staged))
    emit("mesh_scaling", "mesh_d2d_matches_serial",
         int(np.array_equal(d2d_vals, ref)))
    emit("mesh_scaling", "mesh_d2d_matches_staged",
         int(np.array_equal(d2d_vals, staged_vals)))
    emit("mesh_scaling", "mesh_d2d_transfer_host_syncs_O1",
         int(_mesh_transfer_syncs(d2d) == 0))
    emit("mesh_scaling", "mesh_d2d_bytes_matches_staged",
         int(d2d["transfers"]["bytes"] == staged["transfers"]["bytes"]))


def main() -> None:
    # Warmup populates both sides' plan caches (untimed): the capacity
    # claim is about a *serving* runtime, which runs for hours — what
    # matters is the steady-state rate, not the first epochs' compiles.
    # The single window never converges (its epoch structures mix all
    # T tenants, so new tenant multisets keep arriving and retracing);
    # the mesh shards see a per-tenant spec subset and stop compiling.
    n_warm = 40 if smoke() else 80
    n_reqs = 40 if smoke() else 240
    kernels = _make_kernels()
    warm_rounds = _arrival_rounds(n_warm, seed=5)
    rounds = _arrival_rounds(n_reqs, seed=17)

    emit("mesh_scaling", "n_devices", len(jax.devices()))
    emit("mesh_scaling", "n_warm_reqs", n_warm)
    emit("mesh_scaling", "n_reqs", n_reqs)
    emit("mesh_scaling", "n_tenants", N_TENANTS)
    emit("mesh_scaling", "chain_depth", CHAIN)

    results: Dict[str, Dict] = {}
    configs = {
        "single": lambda: DeviceSession(
            window_size=256, plan_mode="loop", history_limit=4096,
            pad_payloads=True),
        f"mesh{N_SHARDS}": lambda: MeshDeviceSession(
            window_size=256, n_shards=N_SHARDS, history_limit=4096,
            pad_payloads=True),
        f"mesh{N_SHARDS}_seq": lambda: MeshDeviceSession(
            window_size=256, n_shards=N_SHARDS, history_limit=4096,
            pad_payloads=True, overlap_drains=False),
    }
    # Warm every leg up front, then interleave the mesh legs' measured
    # drives. The overlap-vs-sequential A/B compares two host-timed legs
    # on a shared machine whose load drifts over the bench's lifetime:
    # running one leg to completion before the other bakes that drift
    # into the ratio. Alternating drive-for-drive and taking each leg's
    # best wall / best p95 cancels it. The single-window leg is dominated
    # by retrace time and one measured drive suffices.
    tenancies: Dict[str, _Tenancy] = {}
    warm_compiles: Dict[str, int] = {}
    for name, make in configs.items():
        tenancies[name] = _Tenancy(make())
        tenancies[name].drive(kernels, warm_rounds)
        warm_compiles[name] = (tenancies[name].session.session_stats()
                               .get("compiled_programs", 0))
        results[name] = {"wall": float("inf"), "p95": float("inf"),
                         "done": 0}

    # Five drives per mesh leg: smoke-sized traces make p95 close to a
    # max-statistic (2nd-worst of ~40), so the best-of needs more draws.
    repeats = {name: (5 if name.startswith("mesh") else 1)
               for name in configs}
    for rep in range(max(repeats.values())):
        for name in configs:
            if rep >= repeats[name]:
                continue
            wall, lats = tenancies[name].drive(kernels, rounds)
            res = results[name]
            res["wall"] = min(res["wall"], wall)
            if lats:
                res["p95"] = min(res["p95"],
                                 float(np.percentile(lats, 95)))
            res["done"] = len(lats)

    for name in configs:
        tenancy = tenancies[name]
        stats = tenancy.session.session_stats()
        tenancy.session.close()
        # Compiles attributable to the measured phase alone.
        stats["measured_compiles"] = (stats.get("compiled_programs", 0)
                                      - warm_compiles[name])
        results[name]["stats"] = stats
        res = results[name]
        emit("mesh_scaling", f"{name}_wall_seconds", round(res["wall"], 4))
        emit("mesh_scaling", f"{name}_reqs_done", res["done"])
        emit("mesh_scaling", f"{name}_p95_latency_s", round(res["p95"], 5))
        emit("mesh_scaling", f"{name}_compiled_programs",
             stats.get("compiled_programs", 0))
        emit("mesh_scaling", f"{name}_measured_compiles",
             stats["measured_compiles"])
        emit("mesh_scaling", f"{name}_plan_cache_hits",
             stats.get("plan_cache_hits", 0))

    single, mesh = results["single"], results[f"mesh{N_SHARDS}"]
    seq = results[f"mesh{N_SHARDS}_seq"]
    ms = mesh["stats"]
    emit("mesh_scaling", "cross_shard_edges", ms.get("cross_shard_edges", 0))
    emit("mesh_scaling", "sub_epoch_barriers", ms.get("sub_epoch_barriers", 0))
    emit("mesh_scaling", "transfer_mode", ms.get("transfer_mode", "?"))
    emit("mesh_scaling", "link_d2d_moves", ms.get("d2d_moves", 0))
    emit("mesh_scaling", "link_staged_moves", ms.get("staged_moves", 0))
    emit("mesh_scaling", "link_d2d_fallbacks", ms.get("d2d_fallbacks", 0))
    emit("mesh_scaling", "drain_overlap", ms.get("drain_overlap", 0))
    for reason, count in sorted(ms.get("placements", {}).items()):
        emit("mesh_scaling", f"placements_{reason}", count)
    for i, shard_stats in enumerate(ms.get("per_shard", [])):
        emit("mesh_scaling", f"shard{i}_host_syncs",
             shard_stats.get("host_syncs", 0))
        emit("mesh_scaling", f"shard{i}_compiled_programs",
             shard_stats.get("compiled_programs", 0))

    capacity_ratio = single["wall"] / max(mesh["wall"], 1e-9)
    emit("mesh_scaling", "mesh_n4_capacity_ratio", round(capacity_ratio, 3))
    emit("mesh_scaling", "mesh_n4_beats_single_2p5x",
         int(capacity_ratio >= 2.5))
    emit("mesh_scaling", "mesh_n4_p95_within_single",
         int(mesh["p95"] <= single["p95"]))
    emit("mesh_scaling", "mesh_n4_fewer_compiles",
         int(ms["measured_compiles"]
             < single["stats"]["measured_compiles"]))

    # Overlapped vs sequential drains: same trace, same settings, only the
    # drain pump differs. Overlap must not cost capacity or tail latency.
    # Tolerances cover forced-host-device reality: all "devices" share one
    # CPU, so overlap cannot physically win here — the gate asserts the
    # pump adds no real overhead, and real parallel gains need real
    # accelerators. p95 gets the wider band because deferred retirement
    # legitimately shifts completion callbacks later within a sub-epoch.
    emit("mesh_scaling", f"mesh{N_SHARDS}_seq_drain_overlap",
         seq["stats"].get("drain_overlap", 0))
    emit("mesh_scaling", "overlap_vs_seq_wall_ratio",
         round(mesh["wall"] / max(seq["wall"], 1e-9), 3))
    emit("mesh_scaling", "overlap_vs_seq_p95_ratio",
         round(mesh["p95"] / max(seq["p95"], 1e-9), 3))
    emit("mesh_scaling", "mesh_overlap_capacity_within_sequential",
         int(mesh["wall"] <= seq["wall"] * 1.08))
    emit("mesh_scaling", "mesh_overlap_p95_within_sequential",
         int(mesh["p95"] <= seq["p95"] * 1.25))
    emit("mesh_scaling", "mesh_overlap_drains_used",
         int(ms.get("drain_overlap", 0) > 1))

    _d2d_differential()


if __name__ == "__main__":
    main()
