"""Mesh-sharded window scaling: one slab window vs N per-device shards.

The multi-tenant serving problem from DESIGN.md §12: T tenants share one
runtime, each request is a K-deep dependent chain over that tenant's
weights. A single :class:`DeviceSession` sees every tenant's kernel specs
interleaved in one window, so its epoch *structures* churn — each new
tenant mix is a new plan signature, and on this host every new signature
is an XLA retrace. :class:`MeshDeviceSession` shards the window across
devices and places each tenant's chains on the shard that already holds
its weights (read-home affinity), so every shard sees a stable spec
subset and the plan cache converges after warmup.

Capacity here = inverse wall time for the same open-loop arrival trace
(Poisson bursts over T tenants, chain buffers recycled through the pool
free-hook). The A/B is equal-settings: both sides use the ready-queue
``loop`` lowering and ``pad_payloads=True`` (bucketed payload shapes —
the same knob on both sides, so neither gets free shape-canonicalisation
the other lacks).

Gates (CI compares before overwriting BENCH_serving.json):

* ``mesh_n4_beats_single_2p5x`` — 4-shard mesh sustains >= 2.5x the
  single-window capacity on the same trace;
* ``mesh_n4_p95_within_single`` — sharding does not trade tail latency
  for capacity (p95 request latency equal or better);
* ``mesh_n4_fewer_compiles`` — the mechanism check: the win must come
  from retrace elimination, not from timing luck.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BufferPool, TaskStream
from repro.core.device_dispatch import DeviceSession
from repro.core.mesh_session import MeshDeviceSession
from repro.core.wrapper import AcsKernel

from .common import emit, smoke

D = 64           # per-request state vector length
N_TENANTS = 8    # distinct kernel specs competing for the window
CHAIN = 4        # dependent kernels per request (decode-chain analogue)
N_SHARDS = 4     # the ISSUE's N=4 mesh leg


def _make_kernels() -> List[AcsKernel]:
    def mk(i: int):
        c = np.float32(i + 1)

        def fn(x, w):
            return x * np.float32(0.999) + w * c

        fn.__name__ = f"tenant{i}"
        return fn

    return [AcsKernel(name=f"tenant{i}", fn=mk(i)) for i in range(N_TENANTS)]


def _arrival_rounds(n_reqs: int, seed: int) -> List[List[int]]:
    """Poisson bursts of tenant ids: each round is one pump interval's
    admissions, so both sides see identical arrival pressure."""
    rng = np.random.RandomState(seed)
    rounds: List[List[int]] = []
    done = 0
    while done < n_reqs:
        burst = min(int(rng.poisson(3)), n_reqs - done)
        rounds.append([int(rng.randint(N_TENANTS)) for _ in range(burst)])
        done += burst
    return rounds


class _Tenancy:
    """One session's view of the tenant fleet: weights live in the pool
    for the whole session lifetime, request state buffers recycle through
    the free hook. Shared across the warmup and measured traces so plan
    caches see one continuous serving lifetime."""

    def __init__(self, session):
        self.session = session
        self.pool = BufferPool()
        self.pool.add_free_hook(session.release_buffer)
        self.weights = [
            self.pool.alloc((D,), np.float32, name=f"w{i}",
                            value=jnp.arange(D, dtype=jnp.float32) + i)
            for i in range(N_TENANTS)
        ]
        self.rid = 0

    def drive(self, kernels: List[AcsKernel], rounds: List[List[int]]):
        """Run one arrival trace; returns (wall_seconds, latencies)."""
        latencies: List[float] = []
        session = self.session
        t0 = time.perf_counter()
        for round_tenants in rounds:
            for tenant in round_tenants:
                st = self.pool.alloc((D,), np.float32, name=f"req{self.rid}",
                                     value=jnp.ones(D, jnp.float32))
                stream = TaskStream(sink=session, tag=f"t{tenant}",
                                    record=False)
                last = None
                for _ in range(CHAIN):
                    last = kernels[tenant].launch(
                        stream, inputs=(st, self.weights[tenant]),
                        outputs=(st,))
                t_sub = time.perf_counter()

                def _done(_task, name=st.name, t_sub=t_sub):
                    latencies.append(time.perf_counter() - t_sub)
                    self.pool.free(name)

                session.on_task_retired(last, _done)
                self.rid += 1
            session.poll()
        session.flush()
        return time.perf_counter() - t0, latencies


def main() -> None:
    # Warmup populates both sides' plan caches (untimed): the capacity
    # claim is about a *serving* runtime, which runs for hours — what
    # matters is the steady-state rate, not the first epochs' compiles.
    # The single window never converges (its epoch structures mix all
    # T tenants, so new tenant multisets keep arriving and retracing);
    # the mesh shards see a per-tenant spec subset and stop compiling.
    n_warm = 40 if smoke() else 80
    n_reqs = 40 if smoke() else 240
    kernels = _make_kernels()
    warm_rounds = _arrival_rounds(n_warm, seed=5)
    rounds = _arrival_rounds(n_reqs, seed=17)

    emit("mesh_scaling", "n_devices", len(jax.devices()))
    emit("mesh_scaling", "n_warm_reqs", n_warm)
    emit("mesh_scaling", "n_reqs", n_reqs)
    emit("mesh_scaling", "n_tenants", N_TENANTS)
    emit("mesh_scaling", "chain_depth", CHAIN)

    results: Dict[str, Dict] = {}
    configs = {
        "single": lambda: DeviceSession(
            window_size=256, plan_mode="loop", history_limit=4096,
            pad_payloads=True),
        f"mesh{N_SHARDS}": lambda: MeshDeviceSession(
            window_size=256, n_shards=N_SHARDS, history_limit=4096,
            pad_payloads=True),
    }
    for name, make in configs.items():
        tenancy = _Tenancy(make())
        tenancy.drive(kernels, warm_rounds)
        warm_stats = tenancy.session.session_stats()
        wall, lats = tenancy.drive(kernels, rounds)
        stats = tenancy.session.session_stats()
        tenancy.session.close()
        # Compiles attributable to the measured phase alone.
        stats["measured_compiles"] = (stats.get("compiled_programs", 0)
                                      - warm_stats.get("compiled_programs", 0))
        p95 = float(np.percentile(lats, 95)) if lats else float("nan")
        results[name] = {"wall": wall, "p95": p95, "stats": stats,
                         "done": len(lats)}
        emit("mesh_scaling", f"{name}_wall_seconds", round(wall, 4))
        emit("mesh_scaling", f"{name}_reqs_done", len(lats))
        emit("mesh_scaling", f"{name}_p95_latency_s", round(p95, 5))
        emit("mesh_scaling", f"{name}_compiled_programs",
             stats.get("compiled_programs", 0))
        emit("mesh_scaling", f"{name}_measured_compiles",
             stats["measured_compiles"])
        emit("mesh_scaling", f"{name}_plan_cache_hits",
             stats.get("plan_cache_hits", 0))

    single, mesh = results["single"], results[f"mesh{N_SHARDS}"]
    ms = mesh["stats"]
    emit("mesh_scaling", "cross_shard_edges", ms.get("cross_shard_edges", 0))
    emit("mesh_scaling", "sub_epoch_barriers", ms.get("sub_epoch_barriers", 0))
    for reason, count in sorted(ms.get("placements", {}).items()):
        emit("mesh_scaling", f"placements_{reason}", count)
    for i, shard_stats in enumerate(ms.get("per_shard", [])):
        emit("mesh_scaling", f"shard{i}_host_syncs",
             shard_stats.get("host_syncs", 0))
        emit("mesh_scaling", f"shard{i}_compiled_programs",
             shard_stats.get("compiled_programs", 0))

    capacity_ratio = single["wall"] / max(mesh["wall"], 1e-9)
    emit("mesh_scaling", "mesh_n4_capacity_ratio", round(capacity_ratio, 3))
    emit("mesh_scaling", "mesh_n4_beats_single_2p5x",
         int(capacity_ratio >= 2.5))
    emit("mesh_scaling", "mesh_n4_p95_within_single",
         int(mesh["p95"] <= single["p95"]))
    emit("mesh_scaling", "mesh_n4_fewer_compiles",
         int(ms["measured_compiles"]
             < single["stats"]["measured_compiles"]))


if __name__ == "__main__":
    main()
