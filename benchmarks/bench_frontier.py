"""Frontier vs barrier scheduling on irregular, input-dependent streams.

Compares the four ACS-SW execution policies — serial per-kernel dispatch,
wave-synchronous (WaveScheduler), paper-faithful K-thread streams
(ThreadedStreamScheduler), and the async frontier (AsyncFrontierScheduler)
— on (a) the physics-simulation stream (the paper's headline irregular
workload) and (b) a dynamic-DNN inference stream (per-input graphs).

Two legs per workload, because compile-cache behaviour is the story:

* **irregular leg** (the paper's input-dependent scenario): every measured
  stream is a *fresh* graph — a new seed/input nobody has seen. The wave
  scheduler's compiled-program cache keys on whole-wave shape multisets,
  which change with every input, so it recompiles mid-measurement; the
  frontier's cache keys on per-kernel signatures, which recur across
  inputs. This is the same irregularity argument the paper makes against
  CUDA Graph reconstruction, one level down. ``frontier_vs_best_barrier``
  (the acceptance metric) comes from this leg.
* **recurring leg**: the same stream shape re-run with every cache warm —
  the regime where whole-front fusion amortizes best. Reported for
  honesty: when graphs never change, the wave path's single-dispatch-per-
  front wins on host overhead, exactly as static CUDA Graph beats ACS in
  the paper's Fig 27.

Also emitted: the frontier's blocking-sync count vs dispatch count (the
§II-D sync-overhead bar: syncs << dispatches), its peak in-flight group
depth (>1 = the barrier is actually gone), and the ACS-HW device-plan
active-slot fraction for wave vs frontier plan modes.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import AsyncFrontierScheduler, TaskStream
from repro.core.device_dispatch import plan_active_fraction, plan_frontier, plan_waves
from repro.sim import ENVIRONMENTS, PhysicsEngine

from .common import emit, make_scheduler, opt, smoke, wall

SIM_ENVS = ("cheetah", "ant")
STEPS = 3
N_ENVS, GROUP = 16, 4
DYN_NETS = ("instanas", "dynamic_routing")


def _sim_size():
    return (4, 2, 1) if smoke() else (N_ENVS, GROUP, STEPS)


def sim_tasks(env: str, seed: int):
    n_envs, group, steps = _sim_size()
    eng = PhysicsEngine(ENVIRONMENTS[env], n_envs=n_envs, group_size=group,
                        seed=seed)
    stream = TaskStream()
    eng.emit_batch(stream, steps)
    return stream.tasks


def dyn_tasks(name: str, input_seed: int, params):
    from repro.dyn import WORKLOADS

    _, build_fn, _ = WORKLOADS[name]
    rng = np.random.RandomState(input_seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32) * (1.0 + 0.3 * input_seed)
    stream = TaskStream()
    build_fn(params, stream, x)
    return stream.tasks


def compare(name: str, build, warm_seeds=(0,), fresh_seeds=(10, 11, 12, 13)) -> None:
    if smoke():
        fresh_seeds = fresh_seeds[:2]
    window = opt("window", 32)
    # Persistent scheduler objects (compile caches live across streams, as a
    # long-running runtime's would); the frontier's is kept explicit so its
    # ExecStats can be delta'd per leg below.
    frontier = AsyncFrontierScheduler(window_size=window,
                                      max_inflight=opt("inflight", 8))
    policies = {
        "serial": make_scheduler("serial", window=window),
        "wave": make_scheduler("wave", window=window),
        "threaded": make_scheduler("threaded", window=window),
        "frontier": frontier.run,
    }
    for pol, run in policies.items():
        for s in warm_seeds:  # populate per-kernel caches everywhere
            run(build(s))

    # -- irregular leg: every measured stream is a never-seen graph -------
    irr_times = {}
    last_report = {}
    pre = frontier.executor.stats.as_dict()  # counters are cumulative
    for pol, run in policies.items():
        t0 = time.perf_counter()
        for s in fresh_seeds:
            last_report[pol] = run(build(s))
        irr_times[pol] = time.perf_counter() - t0
    post = frontier.executor.stats.as_dict()
    base = irr_times["serial"]
    for pol in ("wave", "threaded", "frontier"):
        emit(name, f"{pol}_speedup", round(base / irr_times[pol], 3))
    dispatches = post["dispatches"] - pre["dispatches"]
    syncs = post["blocking_syncs"] - pre["blocking_syncs"]
    emit(name, "frontier_dispatches", dispatches)
    emit(name, "frontier_blocking_syncs", syncs)
    max_groups = last_report["frontier"].max_inflight_groups()
    emit(name, "frontier_max_inflight_groups", max_groups)
    best = min(irr_times["wave"], irr_times["threaded"])
    emit(name, "frontier_vs_best_barrier", round(best / irr_times["frontier"], 3))
    # Structural gates (no timing): the §II-D sync-overhead claim — the
    # frontier must dispatch far more than it blocks — and the barrier
    # really being gone (more than one group in flight at once).
    emit(name, "frontier_fewer_syncs_than_dispatches",
         int(syncs * 4 <= dispatches))
    emit(name, "frontier_overlap_used", int(max_groups > 1))

    # -- recurring leg: warm-shape re-runs (wave fusion's best case) ------
    rec_times = {
        pol: wall(lambda r=run: r(build(warm_seeds[0])), repeats=2)
        for pol, run in policies.items()
    }
    for pol in ("wave", "threaded", "frontier"):
        emit(name, f"{pol}_speedup_recurring",
             round(rec_times["serial"] / rec_times[pol], 3))


def device_plan_density(name: str, tasks) -> None:
    window = opt("window", 32)
    wave_plan = plan_waves(tasks, window)
    frontier_plan = plan_frontier(tasks, window)
    wave_frac = plan_active_fraction(wave_plan)
    frontier_frac = plan_active_fraction(frontier_plan)
    emit(name, "wave_plan_active_fraction", round(wave_frac, 3))
    emit(name, "frontier_plan_active_fraction", round(frontier_frac, 3))
    emit(name, "wave_plan_steps", len(wave_plan))
    emit(name, "frontier_plan_steps", len(frontier_plan))
    # Structural gate: frontier plans pack at least as densely as waves on
    # the same stream (plan-shape property, independent of host timing).
    emit(name, "frontier_density_beats_wave",
         int(frontier_frac >= wave_frac))


def main() -> None:
    sim_envs = SIM_ENVS[:1] if smoke() else SIM_ENVS
    dyn_nets = DYN_NETS[-1:] if smoke() else DYN_NETS
    for env in sim_envs:
        compare(f"frontier_sim_{env}", lambda s, e=env: sim_tasks(e, s))
        device_plan_density(f"frontier_sim_{env}", sim_tasks(env, 3))

    from repro.dyn import WORKLOADS

    for net in dyn_nets:
        init_fn = WORKLOADS[net][0]
        params = init_fn(0)
        compare(f"frontier_dyn_{net}",
                lambda s, n=net, p=params: dyn_tasks(n, s, p))


if __name__ == "__main__":
    main()
