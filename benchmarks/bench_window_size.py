"""Fig 29 reproduction: scheduling-window size sensitivity (16 vs 32).
The paper finds sims gain ~4.5% from 32 (more inter-kernel parallelism
exposed) while DNNs are insensitive."""

from __future__ import annotations

import numpy as np

from repro.core import RTX3060_LIKE, TaskStream, simulate
from repro.core.device_dispatch import plan_waves
from repro.dyn import WORKLOADS

from .common import emit, paper_scale_sim_tasks


def modeled_time(tasks, window):
    waves = plan_waves(tasks, window_size=window)
    return simulate(waves, RTX3060_LIKE, "acs_hw")["time_us"]


def main() -> None:
    gains = []
    for env in ("ant", "grasp", "humanoid", "cheetah", "walker2d"):
        tasks = paper_scale_sim_tasks(env, n_envs=2048, group_size=128)
        t16 = modeled_time(tasks, 16)
        t32 = modeled_time(tasks, 32)
        gains.append(t16 / t32 - 1.0)
        emit("fig29_window", f"{env}_w32_over_w16_gain", round(t16 / t32 - 1, 4))
    emit("fig29_window", "sim_mean_gain", round(float(np.mean(gains)), 4))

    for name in ("instanas", "squeezenet"):
        init_fn, build_fn, _ = WORKLOADS[name]
        params = init_fn(0)
        stream = TaskStream()
        build_fn(params, stream,
                 np.random.RandomState(0).randn(1, 3, 32, 32).astype(np.float32))
        t16 = modeled_time(stream.tasks, 16)
        t32 = modeled_time(stream.tasks, 32)
        emit("fig29_window", f"{name}_w32_over_w16_gain",
             round(t16 / t32 - 1, 4))


if __name__ == "__main__":
    main()
