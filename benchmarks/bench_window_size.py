"""Fig 29 reproduction + large-window sweep.

The paper finds sims gain ~4.5% from window 32 (more inter-kernel
parallelism exposed) while DNNs are insensitive — and stops at 32 because
its pairwise dependency check grows linearly with the window. With the
interval scoreboard the check is O(segments x log intervals) per
insertion, so this sweep now runs the REAL sim/dyn streams through
windows up to 256 end-to-end and emits, alongside the modeled speedup:

* ``plan_us_per_task`` — measured wall time of the windowed dependency
  analysis (scoreboard path) per inserted kernel;
* ``pairwise_us_per_task`` — the same fill/drain replayed with the seed's
  whole-window scan (``window_upstreams``, now the oracle), showing where
  the old path stopped scaling;
* ``probes_per_insert`` vs ``checks_per_insert`` — interval cells the
  scoreboard actually inspected vs the pairwise-equivalent check count
  Algorithm 1 budgets (Table II honesty).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import RTX3060_LIKE, TaskStream, simulate
from repro.core.device_dispatch import plan_waves
from repro.core.segments import pairwise_window_replay
from repro.dyn import WORKLOADS

from .common import emit, paper_scale_sim_tasks, smoke

WINDOWS = (16, 32, 64, 128, 256)


def planned(tasks, window):
    """(modeled acs_hw time, plan seconds, planning window stats)."""
    t0 = time.perf_counter()
    waves, win = plan_waves(tasks, window_size=window, return_window=True)
    plan_s = time.perf_counter() - t0
    return simulate(waves, RTX3060_LIKE, "acs_hw")["time_us"], plan_s, win.stats


def pairwise_plan_seconds(tasks, window_size):
    """Time the seed insertion path — every fill dep-checks the incoming
    kernel against ALL residents via the vectorized whole-window scan —
    over the same fill/retire-wave loop plan_waves runs. This is the
    O(window x segments^2) cost curve the scoreboard replaced."""
    t0 = time.perf_counter()
    pairwise_window_replay(tasks, window_size)
    return time.perf_counter() - t0


def sweep(name: str, tasks, windows, pairwise_windows) -> dict:
    times = {}
    for window in windows:
        t_us, plan_s, stats = planned(tasks, window)
        times[window] = t_us
        n = max(stats.inserted, 1)
        emit("fig29_window", f"{name}_w{window}_plan_us_per_task",
             round(plan_s / n * 1e6, 2))
        emit("fig29_window", f"{name}_w{window}_probes_per_insert",
             round(stats.scoreboard_probes / n, 2))
        emit("fig29_window", f"{name}_w{window}_checks_per_insert",
             round(stats.dep_checks / n, 2))
        if window in pairwise_windows:
            pair_s = pairwise_plan_seconds(tasks, window)
            emit("fig29_window", f"{name}_w{window}_pairwise_us_per_task",
                 round(pair_s / n * 1e6, 2))
    return times


def main() -> None:
    if smoke():
        sim_envs = ("ant",)
        dyn_nets = ("instanas",)
        n_envs, group = 256, 64
        pairwise_windows = (32, 256)
    else:
        sim_envs = ("ant", "grasp", "humanoid", "cheetah", "walker2d")
        dyn_nets = ("instanas", "squeezenet")
        n_envs, group = 2048, 128
        pairwise_windows = (16, 32, 64, 128, 256)

    gains, gains256 = [], []
    for env in sim_envs:
        tasks = paper_scale_sim_tasks(env, n_envs=n_envs, group_size=group)
        times = sweep(env, tasks, WINDOWS, pairwise_windows)
        gains.append(times[16] / times[32] - 1.0)
        gains256.append(times[16] / times[256] - 1.0)
        emit("fig29_window", f"{env}_w32_over_w16_gain",
             round(times[16] / times[32] - 1, 4))
        emit("fig29_window", f"{env}_w256_over_w16_gain",
             round(times[16] / times[256] - 1, 4))
    emit("fig29_window", "sim_mean_gain", round(float(np.mean(gains)), 4))
    emit("fig29_window", "sim_mean_gain_w256",
         round(float(np.mean(gains256)), 4))

    for name in dyn_nets:
        init_fn, build_fn, _ = WORKLOADS[name]
        params = init_fn(0)
        stream = TaskStream()
        build_fn(params, stream,
                 np.random.RandomState(0).randn(1, 3, 32, 32).astype(np.float32))
        times = sweep(name, stream.tasks, WINDOWS, pairwise_windows)
        emit("fig29_window", f"{name}_w32_over_w16_gain",
             round(times[16] / times[32] - 1, 4))
        emit("fig29_window", f"{name}_w256_over_w16_gain",
             round(times[16] / times[256] - 1, 4))


if __name__ == "__main__":
    main()
