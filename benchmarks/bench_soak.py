"""Unbounded-lifetime soak: hours-equivalent traffic through one live
``SessionServer(scheduler="device")`` session.

The fixed bug class (DESIGN.md §2 A3 gap (2)): before row recycling the
device arena leaked one slab row per buffer it ever saw, the plan cache
grew one entry per leaked address pattern, and server bookkeeping
(``task_kinds``, ``report_log``, ``occupancy_samples``) grew without
bound — a serving process was a slow memory bomb. This section soaks ONE
server with Poisson request traffic plus per-request auxiliary
device-lowerable chains (serving kernels themselves take the host path —
slot values are opaque cache pytrees — so the aux chains are what
exercises arena residency), frees every aux buffer through the pool
free-hook, and shifts the aux shape class mid-soak so a whole class goes
dead and a compaction epoch must fire.

Gates (emitted as 0/1 metrics; the smoke leg runs in CI):

* ``slab_flat``            — slab bytes at the last checkpoint of each
                             shape-class regime equal the first steady
                             checkpoint of that regime (no per-phase growth);
* ``plan_cache_bounded``   — cache entries stay under a small constant
                             across every checkpoint (not one per phase);
* ``rows_recycled``        — recurring traffic actually reuses freed rows
                             (the free-list path, not just compaction);
* ``compacted``            — at least one compaction epoch fired and
                             invalidated only its own structure keys;
* ``matches_serial``       — the aux program re-run through the device
                             session is bit-identical to ``run_serial``
                             across the compaction epoch;
* ``rss_bounded``          — resident set growth after warmup stays under
                             a generous margin (catches the leak's order
                             of magnitude, not allocator noise);
* ``p95_stable``           — last-phase request p95 within a loose factor
                             of the first phase (no progressive slowdown);
* ``bookkeeping_bounded``  — ``task_kinds`` drains, ``report_log`` and
                             ``occupancy_samples`` respect history_limit.

The counterfactual leg re-runs the same chain traffic into a session
WITHOUT freeing — the pre-fix behavior — and reports its monotone slab
growth for contrast.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.configs import ARCHS
from repro.models import init_params

from .common import emit, smoke

RSS_MARGIN_MB = 192.0  # generous: allocator + jit-cache noise, not leaks
P95_FACTOR = 5.0       # loose: shared-host jitter, not progressive slowdown
PLAN_CACHE_CAP = 8     # entries; pre-fix grows ~one per phase


def _soak_cfg():
    # soak measures lifetime invariants, not kernel throughput: the model
    # only needs to be big enough to produce real prefill/decode chains
    return dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(),
        n_layers=1, d_model=32, d_ff=64, vocab=64,
        n_heads=2, n_kv_heads=1, head_dim=16,
    )


def _rss_mb() -> float:
    with open("/proc/self/statm") as fh:
        resident_pages = int(fh.read().split()[1])
    return resident_pages * os.sysconf("SC_PAGE_SIZE") / 1e6


def _axpy(x, y):
    return x + 2.0 * y


def _aux_shape(phase: int, n_phases: int):
    # rank-distinct shapes => distinct arena classes (a (16,) vs (8,) pair
    # would pad into the SAME class); the mid-soak switch strands the old
    # class entirely free, forcing a compaction epoch
    return (8,) if phase < n_phases // 2 else (2, 8)


def _aux_chains(session, pool, phase: int, n_phases: int, k: int, tag: str):
    """k request-shaped chains (3 fresh buffers, 2 dependent tasks each)
    submitted into the live session; returns the buffer names so the
    caller can free them through the pool (free-hook -> arena row)."""
    from repro.core import Task
    from repro.core.task import default_segments

    shape = _aux_shape(phase, n_phases)
    names = []
    for i in range(k):
        import jax.numpy as jnp

        bufs = [pool.alloc(shape, np.float32,
                           name=f"{tag}_p{phase}_c{i}_b{j}",
                           value=jnp.full(shape, float(phase * 100 + i + j)))
                for j in range(3)]
        names.extend(b.name for b in bufs)
        for src, dst in ((0, 2), (2, 0)):
            r, w = default_segments((bufs[src], bufs[1]), (bufs[dst],))
            session.submit(Task(opcode="soak_axpy", fn=_axpy,
                                inputs=(bufs[src], bufs[1]),
                                outputs=(bufs[dst],),
                                read_segments=r, write_segments=w))
    return names


def _drive_phase(server, prompts, arrivals, max_new):
    """Open-loop: inject each request at its scheduled arrival, pump the
    live session in between."""
    t0 = time.perf_counter()
    nxt, done = 0, []
    while len(done) < len(prompts):
        now = time.perf_counter() - t0
        while nxt < len(prompts) and arrivals[nxt] <= now:
            req = server.submit(prompts[nxt], max_new=max_new)
            req.t_arrival = t0 + arrivals[nxt]
            nxt += 1
        finished = server.pump()
        done.extend(finished)
        if not finished and (server.active or server.queue):
            server.session.drive()
    return done


def _identity_program(session, pool):
    """The differential leg's program: class-A traffic, release most of it
    (stranding rows), then class-B traffic — spans a compaction epoch on
    the device session. Returns the final buffer values, host-ordered."""
    import jax.numpy as jnp

    from repro.core import Task
    from repro.core.task import default_segments

    def chain(ins, out):
        r, w = default_segments(ins, (out,))
        session.submit(Task(opcode="soak_axpy", fn=_axpy, inputs=ins,
                            outputs=(out,), read_segments=r,
                            write_segments=w))

    a = [pool.alloc((8,), np.float32, value=jnp.full(8, float(i)))
         for i in range(8)]
    for i in range(0, 8, 2):
        chain((a[i], a[i + 1]), a[i + 1])
    session.flush()
    released = 0
    if hasattr(session, "release_buffer"):
        released = sum(bool(session.release_buffer(b)) for b in a[2:])
    # waste is now 6/8 >= 0.5: the device session compacts before the
    # next epoch executes, and these chains recycle the dead rows
    b = [pool.alloc((8,), np.float32, value=jnp.full(8, 10.0 + i))
         for i in range(3)]
    chain((a[0], a[1]), b[0])
    chain((b[0], b[1]), b[2])
    chain((b[2], a[0]), b[1])
    session.flush()
    keep = a[:2] + b
    return [np.asarray(x.value) for x in keep], released


def main() -> None:
    import jax

    from repro.runtime import SessionServer

    cfg = _soak_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
    n_phases = 6 if smoke() else 12
    reqs_per_phase = 4 if smoke() else 10
    chains_per_phase = 4 if smoke() else 6
    max_new = 2 if smoke() else 3
    history_limit = 64

    server = SessionServer(cfg, params, max_slots=2, max_len=16,
                           scheduler="device", history_limit=history_limit)
    rng = np.random.RandomState(0)

    # warmup: compile every decode arity once so jit bursts don't pollute
    # the RSS / latency checkpoints
    for k in (1, 2):
        for _ in range(k):
            server.submit(rng.randint(0, cfg.vocab, 5), max_new=2)
        server.run_until_drained()
    rss0 = _rss_mb()

    checkpoints = []
    p95 = []
    half = n_phases // 2
    # long-lived "carrier" buffers per shape regime (the serving analogy:
    # resident KV blocks) keep the class's live count above the freed
    # per-phase scratch, so the waste ratio stays under the compaction
    # threshold and the scratch rows RECYCLE through the free-list; only
    # the regime switch (everything dead at once) compacts
    carrier_chains = chains_per_phase + 2
    carriers: list = []
    prev_names: list = []
    for phase in range(n_phases):
        prompts = [rng.randint(0, cfg.vocab, 5) for _ in range(reqs_per_phase)]
        arrivals = np.cumsum(
            np.random.RandomState(1000 + phase).exponential(
                0.005, size=reqs_per_phase))
        done = _drive_phase(server, prompts, arrivals, max_new)
        assert len(done) == reqs_per_phase
        p95.append(float(np.percentile([r.latency for r in done], 95)))
        # per-phase aux residency: free LAST phase's buffers (free-hook ->
        # arena free-list) immediately before this phase's allocs, so the
        # new chains RECYCLE the dead rows instead of growing the slab.
        # At the mid-soak shape switch the old class's rows go dead with
        # no taker — that's the compaction epoch.
        for name in prev_names:
            server.pool.free(name)
        if phase in (0, half):  # regime switch: retire the old carriers
            for name in carriers:
                server.pool.free(name)
            carriers = _aux_chains(server.session, server.pool, phase,
                                   n_phases, carrier_chains, "carrier")
        prev_names = _aux_chains(server.session, server.pool, phase,
                                 n_phases, chains_per_phase, "aux")
        server.session.flush()
        stats = server.session.session_stats()
        stats["rss_mb"] = _rss_mb()
        stats["task_kinds"] = len(server.task_kinds)
        checkpoints.append(stats)

    slab = [c["slab_bytes"] for c in checkpoints]
    entries = [c["plan_cache_entries"] for c in checkpoints]
    last = checkpoints[-1]

    emit("soak", "phases", n_phases)
    emit("soak", "requests", n_phases * reqs_per_phase)
    emit("soak", "slab_bytes_per_phase", "|".join(str(s) for s in slab))
    emit("soak", "plan_cache_entries_per_phase",
         "|".join(str(e) for e in entries))
    emit("soak", "arena_recycled_rows", last["arena_recycled_rows"])
    emit("soak", "arena_compactions", last["arena_compactions"])
    emit("soak", "plan_cache_invalidations", last["plan_cache_invalidations"])
    emit("soak", "rss_start_mb", round(rss0, 1))
    emit("soak", "rss_end_mb", round(last["rss_mb"], 1))
    emit("soak", "p95_first_ms", round(p95[0] * 1e3, 1))
    emit("soak", "p95_last_ms", round(p95[-1] * 1e3, 1))

    # gates ----------------------------------------------------------------
    slab_flat = (slab[half - 1] == slab[1]          # class-A regime flat
                 and slab[-1] == slab[half + 1])    # class-B regime flat
    emit("soak", "slab_flat", int(slab_flat))
    emit("soak", "plan_cache_bounded",
         int(max(entries) <= PLAN_CACHE_CAP))
    emit("soak", "rows_recycled", int(last["arena_recycled_rows"] > 0))
    emit("soak", "compacted", int(last["arena_compactions"] >= 1
                                  and last["plan_cache_invalidations"] >= 1))
    emit("soak", "rss_bounded",
         int(last["rss_mb"] - rss0 <= RSS_MARGIN_MB))
    emit("soak", "p95_stable", int(p95[-1] <= P95_FACTOR * max(p95[0], 1e-4)))
    emit("soak", "bookkeeping_bounded",
         int(last["task_kinds"] == 0
             and len(server.report_log) <= history_limit
             and len(server.occupancy_samples) <= history_limit))
    server.close()

    # bit-identity across a compaction epoch (differential leg) ------------
    from repro.core import make_session
    from repro.core.buffers import BufferPool

    ref, _ = _identity_program(make_session("serial"), BufferPool())
    dev_session = make_session("device", window_size=16)
    got, released = _identity_program(dev_session, BufferPool())
    dstats = dev_session.session_stats()
    dev_session.close()
    matches = (released == 6
               and dstats["arena_compactions"] >= 1
               and len(got) == len(ref)
               and all(np.array_equal(g, r) for g, r in zip(got, ref)))
    emit("soak", "matches_serial", int(matches))

    # counterfactual: the pre-fix leak (no free) — monotone slab growth ----
    from repro.core import DeviceSession

    leaky = DeviceSession(window_size=16)
    leaky_pool = BufferPool()
    leak_slab = []
    for phase in range(4):
        _aux_chains(leaky, leaky_pool, phase=0, n_phases=2,
                    k=chains_per_phase, tag=f"leak{phase}")
        leaky.flush()
        leak_slab.append(leaky.session_stats()["slab_bytes"])
    leaky.close()
    emit("soak", "counterfactual_slab_bytes_per_phase",
         "|".join(str(s) for s in leak_slab))
    emit("soak", "counterfactual_grows",
         int(all(b > a for a, b in zip(leak_slab, leak_slab[1:]))))


if __name__ == "__main__":
    main()
