"""Multi-tenant QoS serving plane (DESIGN §13) + the serving bug sweep.

Covers the three named regressions (``Request.latency`` pre-finish,
silent ``run_until_drained`` exhaustion, ``_pick_next`` rescan cost /
equivalence) and the QoS behaviors: priority-first admission, weighted
shares, hard quotas, deadline promotion, the aging starvation bound
under a one-tenant flood (across the device and mesh schedulers), and
cooperative preemption of long decode chains at segment/epoch
boundaries — with bit-identical tokens to an unpreempted run."""

import dataclasses
import time

import numpy as np
import pytest

import jax

from repro.configs import ARCHS
from repro.models import init_params
from repro.runtime import (
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    ContinuousBatchingServer,
    DrainTimeout,
    Request,
    SessionServer,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    return dataclasses.replace(cfg, n_layers=1, d_model=32, d_ff=64, vocab=64,
                               n_heads=2, n_kv_heads=1, head_dim=16)


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return init_params(tiny_cfg, jax.random.PRNGKey(0), tp_size=1)


def _prompts(tiny_cfg, n, seed=0, length=5):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, tiny_cfg.vocab, length) for _ in range(n)]


# ---------------------------------------------------------------------------
# Bug 1: Request.latency before finish
# ---------------------------------------------------------------------------

class TestLatencyPreFinish:
    def test_latency_is_none_until_finished(self):
        req = Request(prompt=np.array([1, 2, 3], np.int32))
        req.t_arrival = time.perf_counter()
        assert not req.finished
        # the old property returned t_finish - t_arrival == -t_arrival: a
        # large negative number silently poisoning percentile math
        assert req.latency is None
        req.t_finish = req.t_arrival + 0.25
        assert req.finished
        assert req.latency == pytest.approx(0.25)

    def test_queued_and_active_requests_report_none(self, tiny_cfg,
                                                    tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1,
                               max_len=16)
        reqs = [server.submit(p, max_new=2)
                for p in _prompts(tiny_cfg, 3, seed=4)]
        server.pump()  # one admitted (active), two queued
        assert all(r.latency is None for r in reqs)
        done = server.run_until_drained()
        server.close()
        assert len(done) == 3
        for r in done:
            assert r.latency is not None and r.latency > 0
        # percentile aggregation over finished requests stays well-formed
        assert float(np.percentile([r.latency for r in done], 99)) > 0


# ---------------------------------------------------------------------------
# Bug 2: silent run_until_drained exhaustion
# ---------------------------------------------------------------------------

class TestDrainTimeout:
    def test_session_server_raises_on_stalled_session(self, tiny_cfg,
                                                      tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1,
                               max_len=16)
        server.submit(_prompts(tiny_cfg, 1)[0], max_new=2)
        server.submit(_prompts(tiny_cfg, 2)[1], max_new=2)
        # stall stub: the session never retires anything
        server.session.poll = lambda: []
        server.session.drive = lambda: []
        with pytest.raises(DrainTimeout) as ei:
            server.run_until_drained(max_iters=5)
        assert ei.value.active_slots == 1  # one admitted into the only slot
        assert ei.value.queue_depth == 1   # one stuck behind it
        assert ei.value.finished == []
        assert "5" in str(ei.value)

    def test_batch_server_raises_when_steps_exhaust(self, tiny_cfg,
                                                    tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=1, max_len=16)
        server.submit(_prompts(tiny_cfg, 1)[0], max_new=2)
        server.step = lambda: []  # stall stub: no progress per step
        with pytest.raises(DrainTimeout) as ei:
            server.run_until_drained(max_iters=3)
        assert ei.value.queue_depth == 1
        assert ei.value.active_slots == 0

    def test_healthy_drain_does_not_raise(self, tiny_cfg, tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2,
                               max_len=16)
        server.submit(_prompts(tiny_cfg, 1)[0], max_new=2)
        done = server.run_until_drained()
        server.close()
        assert len(done) == 1


# ---------------------------------------------------------------------------
# Bug 3: _pick_next — incremental counts must reproduce the old scan
# ---------------------------------------------------------------------------

def _old_pick_rid(queue, active):
    """The pre-QoS admission rule, verbatim: rebuild per-tenant active
    counts, pick the queued request whose tenant holds the fewest active
    slots, oldest-first tie-break (deque order)."""
    counts = {}
    for r in active.values():
        counts[r.tenant] = counts.get(r.tenant, 0) + 1
    best, best_load = 0, counts.get(queue[0].tenant, 0)
    for i in range(1, len(queue)):
        load = counts.get(queue[i].tenant, 0)
        if load < best_load:
            best, best_load = i, load
    return queue[best].rid


class TestPickNextEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_property_choice_unchanged_vs_old_scan(self, seed, tiny_cfg,
                                                   tiny_params):
        """Property: under the default knobs (one priority class, unit
        weights, no quotas/deadlines), the incremental-count _pick_next
        chooses EXACTLY the request the old O(active x queue) scan would
        have, across randomized submit / grant / release traces."""
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=4, max_len=16,
                                          max_queue=64)
        rng = np.random.RandomState(seed)
        tenants = ["alpha", "beta", "gamma"]
        prompt = _prompts(tiny_cfg, 1, seed=seed)[0]
        checked = 0
        for _ in range(120):
            r = rng.rand()
            if r < 0.45 and len(server.queue) < server.max_queue:
                server.submit(prompt, max_new=1,
                              tenant=tenants[rng.randint(len(tenants))])
            elif r < 0.8 and server.queue and server.free:
                want = _old_pick_rid(server.queue, server.active)
                req = server._pick_next()
                assert req is not None and req.rid == want
                server._grant_slot(req)
                server.pool.free(f"req{req.rid}_prompt")
                checked += 1
            elif server.active:
                s = list(server.active)[rng.randint(len(server.active))]
                server._release_slot(s)
        assert checked >= 10, "trace exercised too few admissions"

    def test_incremental_counts_track_active_exactly(self, tiny_cfg,
                                                     tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=3, max_len=16)
        prompt = _prompts(tiny_cfg, 1)[0]
        for t in ("a", "a", "b"):
            server.submit(prompt, max_new=1, tenant=t)
        while server.queue and server.free:
            req = server._pick_next()
            server._grant_slot(req)
        assert server._tenant_active == {"a": 2, "b": 1}
        for s in list(server.active):
            server._release_slot(s)
        assert server._tenant_active == {}


# ---------------------------------------------------------------------------
# QoS admission: priorities, weights, quotas, deadlines
# ---------------------------------------------------------------------------

class TestQosAdmission:
    def test_priority_class_admitted_first(self, tiny_cfg, tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=1, max_len=16)
        prompt = _prompts(tiny_cfg, 1)[0]
        low = server.submit(prompt, max_new=1, priority=PRIORITY_LOW)
        normal = server.submit(prompt, max_new=1)
        high = server.submit(prompt, max_new=1, priority=PRIORITY_HIGH)
        assert server._pick_next() is high
        assert server._pick_next() is normal
        assert server._pick_next() is low

    def test_weighted_shares_hold_proportional_slots(self, tiny_cfg,
                                                     tiny_params):
        server = ContinuousBatchingServer(
            tiny_cfg, tiny_params, max_slots=3, max_len=16,
            tenant_weights={"heavy": 2.0})
        prompt = _prompts(tiny_cfg, 1)[0]
        for t in ("heavy", "light", "heavy", "light", "heavy", "light"):
            server.submit(prompt, max_new=1, tenant=t)
        while server.queue and server.free:
            server._grant_slot(server._pick_next())
        by_tenant = {}
        for r in server.active.values():
            by_tenant[r.tenant] = by_tenant.get(r.tenant, 0) + 1
        assert by_tenant == {"heavy": 2, "light": 1}

    def test_quota_caps_active_slots_and_never_drops(self, tiny_cfg,
                                                     tiny_params):
        server = ContinuousBatchingServer(
            tiny_cfg, tiny_params, max_slots=3, max_len=16,
            tenant_quota={"flood": 1})
        prompt = _prompts(tiny_cfg, 1)[0]
        floods = [server.submit(prompt, max_new=1, tenant="flood")
                  for _ in range(4)]
        while server.queue and server.free:
            req = server._pick_next()
            if req is None:
                break
            server._grant_slot(req)
        # quota holds: one active, the rest stay QUEUED (not dropped)
        assert len(server.active) == 1
        assert len(server.queue) == 3
        assert server._pick_next() is None
        # releasing the slot re-opens admission for the next flood request
        server._release_slot(floods[0].slot)
        nxt = server._pick_next()
        assert nxt is floods[1]

    def test_quota_respected_through_full_serve(self, tiny_cfg,
                                                tiny_params):
        server = ContinuousBatchingServer(
            tiny_cfg, tiny_params, max_slots=2, max_len=16,
            tenant_quota={"flood": 1})
        for p in _prompts(tiny_cfg, 4, seed=5):
            server.submit(p, max_new=1, tenant="flood")
        done = []
        for _ in range(40):
            done.extend(server.step())
            assert sum(1 for r in server.active.values()
                       if r.tenant == "flood") <= 1
            if not server.queue and not server.active:
                break
        assert len(done) == 4

    def test_deadline_promotion_beats_arrival_order(self, tiny_cfg,
                                                    tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=1, max_len=16)
        prompt = _prompts(tiny_cfg, 1)[0]
        older = server.submit(prompt, max_new=1)
        urgent = server.submit(prompt, max_new=1, deadline=0.002)
        time.sleep(0.005)  # more than half the deadline budget is gone
        assert server.effective_priority(urgent) == PRIORITY_HIGH
        assert server._pick_next() is urgent
        assert server._pick_next() is older

    def test_submit_validates_qos_fields(self, tiny_cfg, tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=1, max_len=16)
        prompt = _prompts(tiny_cfg, 1)[0]
        with pytest.raises(ValueError, match="priority"):
            server.submit(prompt, priority=-1)
        with pytest.raises(ValueError, match="deadline"):
            server.submit(prompt, deadline=0.0)
        with pytest.raises(ValueError, match="weight"):
            ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=1,
                                     max_len=16,
                                     tenant_weights={"x": 0.0})
        with pytest.raises(ValueError, match="aging_s"):
            ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=1,
                                     max_len=16, aging_s=-1.0)
        with pytest.raises(ValueError, match="preempt_rounds"):
            SessionServer(tiny_cfg, tiny_params, max_slots=1, max_len=16,
                          preempt_rounds=0)

    def test_aged_request_ties_but_never_outranks_fresh_high(
            self, tiny_cfg, tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params,
                                          max_slots=1, max_len=16,
                                          aging_s=0.001)
        prompt = _prompts(tiny_cfg, 1)[0]
        aged = server.submit(prompt, max_new=1, priority=PRIORITY_LOW)
        time.sleep(0.01)  # ages far past bucket 0
        assert server.effective_priority(aged) == PRIORITY_HIGH


# ---------------------------------------------------------------------------
# Starvation bound under a one-tenant flood — device AND mesh schedulers
# ---------------------------------------------------------------------------

class TestFloodFairness:
    @pytest.mark.parametrize("scheduler", ["device", "mesh"])
    def test_flood_cannot_starve_quiet_tenant_beyond_aging_bound(
            self, tiny_cfg, tiny_params, scheduler):
        """Adversarial mix: a flooding tenant submits a backlog of
        strictly higher-priority requests; a quiet tenant's low-priority
        request must still be admitted before the flood fully drains —
        aging promotes it to the top bucket within priority * aging_s,
        after which its zero tenant load wins the tie."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2,
                               max_len=16, scheduler=scheduler,
                               aging_s=0.02)
        flood = [server.submit(p, max_new=3, tenant="flood",
                               priority=PRIORITY_HIGH)
                 for p in _prompts(tiny_cfg, 10, seed=6)]
        quiet = server.submit(_prompts(tiny_cfg, 1, seed=7)[0], max_new=2,
                              tenant="quiet", priority=PRIORITY_LOW)
        done = server.run_until_drained()
        server.close()
        assert len(done) == 11
        assert quiet.t_admit < max(f.t_admit for f in flood), (
            "quiet tenant was starved until the entire flood drained")
        assert len(quiet.generated) == 2

    def test_without_aging_strict_priority_starves_until_flood_drains(
            self, tiny_cfg, tiny_params):
        """Contrast leg: aging disabled, same mix — the quiet LOW request
        is admitted only after every HIGH flood request (this is what
        the aging invariant prevents)."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2,
                               max_len=16, scheduler="frontier",
                               aging_s=None)
        flood = [server.submit(p, max_new=3, tenant="flood",
                               priority=PRIORITY_HIGH)
                 for p in _prompts(tiny_cfg, 6, seed=6)]
        quiet = server.submit(_prompts(tiny_cfg, 1, seed=7)[0], max_new=2,
                              tenant="quiet", priority=PRIORITY_LOW)
        server.run_until_drained()
        server.close()
        assert quiet.t_admit >= max(f.t_admit for f in flood)


# ---------------------------------------------------------------------------
# Cooperative preemption at segment/epoch boundaries
# ---------------------------------------------------------------------------

class TestPreemption:
    @pytest.mark.parametrize("scheduler", ["frontier", "device", "mesh"])
    def test_flood_chain_yields_slot_to_high_priority(self, tiny_cfg,
                                                      tiny_params,
                                                      scheduler):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1,
                               max_len=32, scheduler=scheduler,
                               preempt_rounds=2)
        p = _prompts(tiny_cfg, 2, seed=8)
        flood = server.submit(p[0], max_new=10, priority=PRIORITY_LOW)
        server.pump()  # flood takes the only slot
        high = server.submit(p[1], max_new=2, priority=PRIORITY_HIGH)
        done = server.run_until_drained()
        server.close()
        done += server.pump()
        by = {r.rid: r for r in done}
        assert by[flood.rid].preemptions >= 1
        assert server.preemptions >= 1
        assert by[high.rid].t_finish < by[flood.rid].t_finish, (
            "preemption must let the high-priority request finish first")
        # the preempted chain still completes in full
        assert len(by[flood.rid].generated) == 10
        assert len(by[high.rid].generated) == 2

    def test_preempted_tokens_bit_identical_to_unpreempted(self, tiny_cfg,
                                                           tiny_params):
        """Park/resume restores the opaque (cache, tok, pos) verbatim:
        the token streams must be bit-identical to a run with preemption
        disabled (which itself matches run_serial per the serving
        differential tests)."""
        p = _prompts(tiny_cfg, 2, seed=9)

        def run(preempt_rounds):
            server = SessionServer(tiny_cfg, tiny_params, max_slots=1,
                                   max_len=32, scheduler="frontier",
                                   preempt_rounds=preempt_rounds)
            flood = server.submit(p[0], max_new=10, priority=PRIORITY_LOW)
            server.pump()
            high = server.submit(p[1], max_new=2, priority=PRIORITY_HIGH)
            done = server.run_until_drained()
            server.close()
            done += server.pump()
            by = {r.rid: r for r in done}
            return by[flood.rid], by[high.rid], server

        flood_p, high_p, server_p = run(preempt_rounds=2)
        flood_n, high_n, _ = run(preempt_rounds=None)
        assert server_p.preemptions >= 1
        assert flood_p.preemptions >= 1 and flood_n.preemptions == 0
        assert flood_p.generated == flood_n.generated
        assert high_p.generated == high_n.generated

    def test_no_preemption_between_equal_priorities(self, tiny_cfg,
                                                    tiny_params):
        """Equal urgency never parks a chain — no thrash between peers."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1,
                               max_len=16, scheduler="frontier",
                               preempt_rounds=1)
        p = _prompts(tiny_cfg, 3, seed=10)
        reqs = [server.submit(x, max_new=3) for x in p]
        done = server.run_until_drained()
        server.close()
        done += server.pump()
        assert len(done) == 3
        assert server.preemptions == 0
        assert all(r.preemptions == 0 for r in reqs)

    def test_close_drains_segmented_chains(self, tiny_cfg, tiny_params):
        """close() under preempt_rounds must finish lazily-emitted chain
        segments (they submit from retirement callbacks, which cannot
        feed a closed window) — requests stay collectable via pump()."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2,
                               max_len=16, scheduler="frontier",
                               preempt_rounds=1)
        reqs = [server.submit(x, max_new=4)
                for x in _prompts(tiny_cfg, 3, seed=11)]
        server.pump()  # admit — chains in flight, segments pending
        server.close()
        done = server.pump()
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        assert all(len(r.generated) == 4 for r in done)
