"""THE correctness property of ACS (paper §III): out-of-order execution of
provably-independent kernels must be observationally equivalent to the
serial single-stream baseline — for every window size, executor, and
randomly generated irregular task graph.

Random streams are generated hypothesis-style over a shared buffer pool:
each task reads 1-2 random buffers and writes one (possibly overlapping a
read — creating RAW/WAR/WAW hazards), with non-commutative arithmetic so
any illegal reorder changes the result.
"""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax.numpy as jnp

from repro.core import (
    BufferPool,
    DagRunner,
    Task,
    ThreadedStreamScheduler,
    WaveScheduler,
    run_serial,
)
from repro.core.executors import FusedWaveExecutor, SerialExecutor
from repro.core.task import default_segments

D = 4  # buffer width


def _axpy(x, y):
    return 1.5 * x + y + 1.0  # non-commutative vs. mul


def _mul(x, y):
    return x * y - 0.5


def _neg(x, y):
    return -x + 0.25 * y


OPS = {"axpy": _axpy, "mul": _mul, "neg": _neg}


def build_stream(seed: int, n_tasks: int, n_buffers: int):
    """Deterministic random irregular task stream. Returns (pool, tasks)."""
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        i0, i1 = rng.randint(n_buffers), rng.randint(n_buffers)
        o = rng.randint(n_buffers)
        ins = (buffers[i0], buffers[i1])
        outs = (buffers[o],)
        r, w = default_segments(ins, outs)
        tasks.append(
            Task(opcode=op, fn=OPS[op], inputs=ins, outputs=outs, read_segments=r, write_segments=w)
        )
    return pool, buffers, tasks


def final_values(buffers):
    return np.stack([np.asarray(b.value) for b in buffers])


def run_with(scheduler_factory, seed, n_tasks=40, n_buffers=8):
    pool, buffers, tasks = build_stream(seed, n_tasks, n_buffers)
    scheduler_factory(tasks)
    return final_values(buffers)


class TestSerialEquivalence:
    @pytest.mark.parametrize("window", [1, 2, 4, 32])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_wave_scheduler_matches_serial(self, window, seed):
        ref = run_with(lambda ts: run_serial(ts), seed)
        got = run_with(lambda ts: WaveScheduler(window_size=window).run(ts), seed)
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    @pytest.mark.parametrize("seed", [0, 3])
    def test_wave_scheduler_serial_executor_matches(self, seed):
        """Window reordering alone (no fusion) is also equivalent."""
        ref = run_with(lambda ts: run_serial(ts), seed)
        got = run_with(
            lambda ts: WaveScheduler(window_size=16, executor=SerialExecutor()).run(ts), seed
        )
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_threaded_streams_match_serial(self, seed):
        """Paper-faithful ACS-SW (K scheduler threads) is equivalent too."""
        ref = run_with(lambda ts: run_serial(ts), seed)
        got = run_with(
            lambda ts: ThreadedStreamScheduler(window_size=16, num_streams=4).run(ts), seed
        )
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_dag_baseline_matches_serial(self, seed):
        ref = run_with(lambda ts: run_serial(ts), seed)
        got = run_with(lambda ts: DagRunner().execute(ts), seed)
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    @given(st.integers(0, 10_000), st.integers(1, 33))
    @settings(max_examples=25, deadline=None)
    def test_property_any_seed_any_window(self, seed, window):
        ref = run_with(lambda ts: run_serial(ts), seed, n_tasks=24, n_buffers=6)
        got = run_with(
            lambda ts: WaveScheduler(window_size=window).run(ts), seed, n_tasks=24, n_buffers=6
        )
        np.testing.assert_allclose(got, ref, rtol=1e-6)


class TestSchedulerBehaviour:
    def test_window_one_is_serial(self):
        _, _, tasks = build_stream(0, 20, 5)
        report = WaveScheduler(window_size=1).run(tasks)
        assert all(len(w) == 1 for w in report.waves)
        assert report.exec_stats["dispatches"] == 20

    def test_independent_stream_fuses_to_one_wave(self):
        """Fully independent tasks inside one window => a single wide wave."""
        pool = BufferPool()
        ins = [pool.alloc((D,), np.float32, value=jnp.ones(D)) for _ in range(8)]
        outs = [pool.alloc((D,), np.float32, value=jnp.zeros(D)) for _ in range(8)]
        tasks = []
        for i in range(8):
            r, w = default_segments((ins[i], ins[i]), (outs[i],))
            tasks.append(
                Task(opcode="axpy", fn=_axpy, inputs=(ins[i], ins[i]), outputs=(outs[i],),
                     read_segments=r, write_segments=w)
            )
        report = WaveScheduler(window_size=32).run(tasks)
        assert len(report.waves) == 1
        assert report.exec_stats["max_wave_width"] == 8
        assert report.exec_stats["dispatches"] == 1  # fused: 8 kernels, 1 launch

    def test_wave_cache_hits_across_runs(self):
        """Recurring wave signatures reuse compiled programs (A2)."""
        executor = FusedWaveExecutor()
        for _ in range(3):
            pool = BufferPool()
            ins = [pool.alloc((D,), np.float32, value=jnp.ones(D)) for _ in range(4)]
            outs = [pool.alloc((D,), np.float32, value=jnp.zeros(D)) for _ in range(4)]
            tasks = []
            for i in range(4):
                r, w = default_segments((ins[i], ins[i]), (outs[i],))
                tasks.append(
                    Task(opcode="mul", fn=_mul, inputs=(ins[i], ins[i]), outputs=(outs[i],),
                         read_segments=r, write_segments=w)
                )
            WaveScheduler(window_size=32, executor=executor).run(tasks)
        assert executor.stats.compiles == 1  # one compile, reused across runs
        assert executor.stats.dispatches == 3

    def test_max_wave_caps_width(self):
        _, _, tasks = build_stream(7, 30, 30)  # mostly independent
        report = WaveScheduler(window_size=32, max_wave=4).run(tasks)
        assert report.exec_stats["max_wave_width"] <= 4

    def test_report_occupancy_proxy_bounds(self):
        _, _, tasks = build_stream(0, 30, 8)
        r = WaveScheduler(window_size=32).run(tasks)
        assert 0.0 < r.occupancy_proxy() <= 1.0
