"""The ``benchmarks/run.py --smoke --json=PATH`` artifact is what CI
uploads as the machine-readable perf trajectory — if its schema drifts (or
the writer silently stops emitting rows), the upload goes stale without
any test noticing. Two layers:

* a fast in-process test drives ``run.main()`` over a stub section and
  validates the full artifact schema (keys, row types, flag echo,
  timings);
* a ``slow``-lane test runs the REAL ``--smoke`` leg in a subprocess and
  checks every smoke section produced rows — the exact artifact CI
  uploads.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _validate_schema(payload, expect_sections=None):
    """The BENCH_*.json contract: top-level keys, row shape, non-empty
    results, a timing per executed section."""
    assert set(payload) == {"flags", "sections", "timings_seconds", "results"}
    assert isinstance(payload["flags"], dict)
    assert isinstance(payload["sections"], list) and payload["sections"]
    assert isinstance(payload["timings_seconds"], dict)
    assert set(payload["timings_seconds"]) == set(payload["sections"])
    for t in payload["timings_seconds"].values():
        assert isinstance(t, (int, float)) and t >= 0
    assert isinstance(payload["results"], list) and payload["results"]
    emitted_sections = set()
    for row in payload["results"]:
        assert set(row) == {"section", "metric", "value"}, row
        assert isinstance(row["section"], str) and row["section"]
        assert isinstance(row["metric"], str) and row["metric"]
        assert isinstance(row["value"], (int, float, str, bool)), row
        emitted_sections.add(row["section"])
    if expect_sections is not None:
        for name in expect_sections:
            assert any(s == name or s.startswith(name) for s in emitted_sections), (
                f"section {name!r} emitted no rows; emitted: {sorted(emitted_sections)}")


class _StubSection:
    """Stands in for a bench module: emits a few typed rows."""

    @staticmethod
    def main():
        from benchmarks.common import emit

        emit("stub", "int_metric", 3)
        emit("stub", "float_metric", 1.25)
        emit("stub", "str_metric", "a|b")


def test_json_artifact_schema_fast(tmp_path, monkeypatch):
    import benchmarks.run as run
    from benchmarks import common

    path = tmp_path / "bench.json"
    monkeypatch.setattr(run, "SECTIONS", {"stub": _StubSection})
    monkeypatch.setattr(run, "SMOKE_SECTIONS", ("stub",))
    monkeypatch.setattr(common, "RESULTS", [])
    monkeypatch.setattr(common, "OPTIONS", {})
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--smoke", "--window=8", f"--json={path}"])
    run.main()
    payload = json.loads(path.read_text())
    _validate_schema(payload, expect_sections=["stub"])
    assert payload["flags"]["smoke"] == "1"
    assert payload["flags"]["window"] == "8"
    assert payload["sections"] == ["stub"]
    assert len(payload["results"]) == 3


def test_json_flag_requires_path(monkeypatch):
    import benchmarks.run as run

    monkeypatch.setattr(sys, "argv", ["run.py", "--json="])
    with pytest.raises(SystemExit, match="--json expects a path"):
        run.main()


def _bench_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


# CLI section name -> emitted row-section prefix, where they differ (the
# paper-figure sections emit under their table/figure name).
EMITTED_PREFIX = {"depcheck": "table2_depcheck", "window_size": "fig29_window"}


def _emitted_names(cli_sections):
    return [EMITTED_PREFIX.get(n, n) for n in cli_sections]


def test_smoke_sections_cover_dependency_engine():
    """The smoke set must keep exercising the scoreboard counters: the
    depcheck probe-vs-scan section and the window_size large-window leg."""
    import benchmarks.run as run

    assert "depcheck" in run.SMOKE_SECTIONS
    assert "window_size" in run.SMOKE_SECTIONS


@pytest.mark.slow  # runs the real smoke benchmark leg (~1-2 min)
def test_smoke_json_artifact_real(tmp_path):
    """End-to-end: the exact command CI runs must produce a schema-valid,
    non-empty artifact covering every smoke section — including the
    scoreboard dependency-engine counters the artifact now carries."""
    import benchmarks.run as run

    path = tmp_path / "bench-smoke.json"
    # subprocess budget stays below the slow lane's --timeout=300 per-test
    # ceiling (ci.yml), so a hung benchmark fails through TimeoutExpired
    # with captured stderr instead of pytest-timeout killing the test
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", f"--json={path}"],
        cwd=REPO_ROOT, env=_bench_env(), capture_output=True, text=True,
        timeout=270,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(path.read_text())
    _validate_schema(payload,
                     expect_sections=_emitted_names(run.SMOKE_SECTIONS))
    assert payload["sections"] == list(run.SMOKE_SECTIONS)
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    # probe-vs-pairwise accounting (Table II honesty) and its gates. The
    # w64 crossover and the 2.0x-growth gate are emitted but asserted
    # with margin here: w64 wins by only ~1.5x under smoke-sized iters,
    # so a loaded CI runner could flip it with no code regression — the
    # w128 win (>2x margin) and a 3x growth ceiling (window x4) are the
    # noise-robust forms of the same claims.
    assert ("table2_depcheck", "scoreboard_beats_scan_w64") in metrics
    assert metrics[("table2_depcheck", "scoreboard_beats_scan_w128")] == 1
    assert metrics[("table2_depcheck", "scoreboard_growth_64_to_256")] < 3.0
    assert ("table2_depcheck", "w256_s10_scoreboard_ns") in metrics
    # the window=256 configuration through the real sim + dyn streams
    assert any(s == "fig29_window" and "w256" in m for s, m in metrics)
    assert ("fig29_window", "ant_w256_probes_per_insert") in metrics
    assert ("fig29_window", "instanas_w256_plan_us_per_task") in metrics
    # the device section rides in the same smoke run: its executor
    # equivalence and one-dispatch gates must hold on THIS host too
    _assert_device_gates(payload)


@pytest.mark.slow  # runs the real --window=256 smoke leg (~1-2 min)
def test_smoke_json_artifact_w256_leg(tmp_path):
    """The second CI bench command: every --window-consuming section must
    accept a 256-wide window and still emit a schema-valid artifact (the
    dependency-engine sections sweep window sizes internally and are
    covered by the first leg)."""
    path = tmp_path / "bench-smoke-w256.json"
    sections = ["device", "frontier", "serving"]
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--window=256",
         *sections, f"--json={path}"],
        cwd=REPO_ROOT, env=_bench_env(), capture_output=True, text=True,
        timeout=270,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(path.read_text())
    _validate_schema(payload, expect_sections=_emitted_names(sections))
    assert payload["flags"]["window"] == "256"


# Structural gates the committed device artifact must hold (1 = pass):
# every executor mode bit-identical to serial with ONE dispatch per
# stream, the ready-queue session draining the recurring workload with
# O(1) host syncs, and the forced-Pallas leg actually taking the fast
# path. No timing gates — walls and speedups are host-load-dependent and
# only warned on by benchmarks/compare.py.
DEVICE_GATES = {
    "device_sim_cheetah": ("device_wave_matches_serial",
                           "device_frontier_matches_serial",
                           "device_loop_matches_serial"),
    "device_dyn_routing": ("device_wave_matches_serial",
                           "device_frontier_matches_serial",
                           "device_loop_matches_serial"),
    "device_session_recurring": ("session_matches_serial",
                                 "loop_session_matches_serial",
                                 "loop_session_host_syncs_O1",
                                 "session_fewer_dispatches_than_per_stream"),
    "device_loop_pallas": ("interpreter_matches_serial", "pallas_used",
                           "pallas_matches_serial",
                           "pallas_matches_interpreter"),
}


def _assert_device_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    for section, gates in DEVICE_GATES.items():
        for gate in gates:
            assert metrics.get((section, gate)) == 1, (
                f"device gate {section},{gate} failed: "
                f"{ {m: v for (s, m), v in metrics.items() if s == section} }")
    for leg in ("device_sim_cheetah", "device_dyn_routing"):
        for mode in ("wave", "frontier", "loop"):
            assert metrics[(leg, f"device_{mode}_dispatches")] == 1, (
                f"{leg} device_{mode} must advance the whole stream in ONE "
                f"dispatch, got {metrics[(leg, f'device_{mode}_dispatches')]}")
        assert metrics[(leg, "device_loop_executor")] in (
            "interpreter", "pallas")
    # the evidence behind the O(1) verdict, not just the bit
    assert metrics[("device_session_recurring", "loop_session_host_syncs")] <= 2
    assert metrics[("device_session_recurring",
                    "loop_session_loop_dispatches")] >= 1


def test_committed_bench_device_json():
    """The repo-root BENCH_device.json (regenerated by the CI device bench
    step) must stay schema-valid with every executor-equivalence and
    one-dispatch gate green."""
    path = os.path.join(REPO_ROOT, "BENCH_device.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["device"])
    assert payload["sections"] == ["device"]
    assert payload["flags"].get("smoke") == "1"
    _assert_device_gates(payload)


def _assert_depcheck_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    # noise-robust forms only (see test_smoke_json_artifact_real): the
    # w128/w256 wins carry >2x margin; growth gets a 3x ceiling.
    assert metrics[("table2_depcheck", "scoreboard_beats_scan_w128")] == 1
    assert metrics[("table2_depcheck", "scoreboard_beats_scan_w256")] == 1
    assert metrics[("table2_depcheck", "scoreboard_sublinear_64_to_256")] == 1
    assert metrics[("table2_depcheck", "scoreboard_growth_64_to_256")] < 3.0
    assert ("table2_depcheck", "w256_s10_scoreboard_ns") in metrics
    assert ("table2_depcheck", "w256_s10_probes_per_insert") in metrics


def test_committed_bench_depcheck_json():
    """The repo-root BENCH_depcheck.json must stay schema-valid with the
    dependency-engine scaling gates green."""
    path = os.path.join(REPO_ROOT, "BENCH_depcheck.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["table2_depcheck"])
    assert payload["sections"] == ["depcheck"]
    assert payload["flags"].get("smoke") == "1"
    _assert_depcheck_gates(payload)


# Structural gates the committed frontier artifact must hold (1 = pass):
# no timing gates — speedups are host-load-dependent — only the plan-shape
# and overlap-structure claims: syncs << dispatches (§II-D), more than one
# group genuinely in flight, and frontier plans at least as dense as waves.
FRONTIER_COMPARE_GATES = ("frontier_fewer_syncs_than_dispatches",
                          "frontier_overlap_used")


def _assert_frontier_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    for section in ("frontier_sim_cheetah", "frontier_dyn_dynamic_routing"):
        for gate in FRONTIER_COMPARE_GATES:
            assert metrics.get((section, gate)) == 1, (
                f"frontier gate {section},{gate} failed: "
                f"{ {m: v for (s, m), v in metrics.items() if s == section} }")
        # the evidence behind the verdicts
        assert metrics[(section, "frontier_blocking_syncs")] * 4 <= \
            metrics[(section, "frontier_dispatches")]
        assert metrics[(section, "frontier_max_inflight_groups")] > 1
        assert (section, "frontier_vs_best_barrier") in metrics
    assert metrics.get(
        ("frontier_sim_cheetah", "frontier_density_beats_wave")) == 1
    assert ("frontier_sim_cheetah", "frontier_plan_active_fraction") in metrics
    assert ("frontier_sim_cheetah", "wave_plan_active_fraction") in metrics


def test_committed_bench_frontier_json():
    """The repo-root BENCH_frontier.json (regenerated by the CI bench-smoke
    step) must stay schema-valid with the sync-overhead and plan-density
    gates green."""
    path = os.path.join(REPO_ROOT, "BENCH_frontier.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["frontier"])
    assert payload["sections"] == ["frontier"]
    assert payload["flags"].get("smoke") == "1"
    _assert_frontier_gates(payload)


# Structural gates the committed serving artifact must hold: the live
# session beats continuous batching on p95, and the mesh-sharded window
# leg (DESIGN §12) sustains >=2.5x single-window capacity at equal-or-
# better tail latency, with the win attributable to retrace elimination.
# The d2d and overlap gates pin the transfer layer: the device-to-device
# path bit-identical to serial/staged with zero mesh-transfer host syncs
# and a mode-invariant byte audit, and the overlapped drain pump at
# sequential-or-better capacity while genuinely overlapping shards.
MESH_GATES = ("mesh_n4_beats_single_2p5x", "mesh_n4_p95_within_single",
              "mesh_n4_fewer_compiles",
              "mesh_d2d_matches_serial", "mesh_d2d_matches_staged",
              "mesh_d2d_transfer_host_syncs_O1",
              "mesh_d2d_bytes_matches_staged",
              "mesh_overlap_capacity_within_sequential",
              "mesh_overlap_p95_within_sequential",
              "mesh_overlap_drains_used")


def _assert_serving_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    assert metrics.get(("serving", "session_beats_batch_p95")) == 1, (
        f"serving p95 gate failed: "
        f"{ {m: v for (s, m), v in metrics.items() if s == 'serving'} }")
    for gate in MESH_GATES:
        assert metrics.get(("mesh_scaling", gate)) == 1, (
            f"mesh gate {gate!r} failed: "
            f"{ {m: v for (s, m), v in metrics.items() if s == 'mesh_scaling'} }")
    # the evidence behind the verdicts: capacity ratio, cross-device edge
    # count, and per-shard host-sync accounting must all be carried
    assert metrics[("mesh_scaling", "mesh_n4_capacity_ratio")] >= 2.5
    assert ("mesh_scaling", "cross_shard_edges") in metrics
    assert ("mesh_scaling", "sub_epoch_barriers") in metrics
    assert metrics[("mesh_scaling", "n_devices")] >= 1
    for i in range(4):
        assert ("mesh_scaling", f"shard{i}_host_syncs") in metrics
        assert ("mesh_scaling", f"shard{i}_compiled_programs") in metrics
    # the transfer-layer evidence: the serving leg's link must have
    # selected d2d on forced host devices, the overlapped pump must have
    # had >1 shard in flight, and the d2d differential must carry its
    # host-sync and byte columns (the staged control shows the nonzero
    # sync count d2d eliminates)
    assert metrics[("mesh_scaling", "transfer_mode")] == "d2d"
    assert metrics[("mesh_scaling", "drain_overlap")] > 1
    assert metrics[("mesh_scaling", "d2d_mesh_transfer_host_syncs")] == 0
    assert metrics[("mesh_scaling", "staged_mesh_transfer_host_syncs")] > 0
    assert metrics[("mesh_scaling", "d2d_transfer_bytes")] == \
        metrics[("mesh_scaling", "staged_transfer_bytes")] > 0
    assert metrics[("mesh_scaling", "d2d_moves")] > 0


def test_committed_bench_serving_json():
    """The repo-root BENCH_serving.json (regenerated by the CI multi-device
    lane under forced host devices) must stay schema-valid with the
    serving-p95 and mesh-scaling gates green."""
    path = os.path.join(REPO_ROOT, "BENCH_serving.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["serving", "mesh_scaling"])
    assert payload["sections"] == ["serving", "mesh_scaling"]
    assert payload["flags"].get("smoke") == "1"
    _assert_serving_gates(payload)


def _assert_window_size_metrics(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    # the full window sweep must be present for the smoke env/net pair,
    # with the scoreboard evidence columns (probes vs budgeted checks)
    from benchmarks.bench_window_size import WINDOWS

    for name in ("ant", "instanas"):
        for w in WINDOWS:
            for col in ("plan_us_per_task", "probes_per_insert",
                        "checks_per_insert"):
                assert ("fig29_window", f"{name}_w{w}_{col}") in metrics, (
                    f"missing fig29_window,{name}_w{w}_{col}")
        assert ("fig29_window", f"{name}_w256_pairwise_us_per_task") in metrics
    assert ("fig29_window", "sim_mean_gain") in metrics
    assert ("fig29_window", "sim_mean_gain_w256") in metrics


def test_committed_bench_window_size_json():
    """The repo-root BENCH_window_size.json must stay schema-valid and
    keep carrying the large-window scoreboard evidence columns."""
    path = os.path.join(REPO_ROOT, "BENCH_window_size.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=_emitted_names(["window_size"]))
    assert payload["sections"] == ["window_size"]
    assert payload["flags"].get("smoke") == "1"
    _assert_window_size_metrics(payload)


# Structural gates the committed QoS artifact must hold (DESIGN §13): the
# preempting plane keeps the interactive-class tail within 2x the unloaded
# floor at no aggregate-throughput cost, preemption never changes a token,
# aging un-starves a flooded-out low-priority tenant, priority buckets
# reorder the window's READY head, and the mixed-priority hazard stream
# stays bit-identical through the loop lowering and the mesh session.
QOS_GATES = ("qos_high_p99_within_2x_unloaded",
             "qos_throughput_within_fairness",
             "qos_tokens_matches_fairness",
             "qos_aging_beats_flood_drain",
             "qos_priority_beats_fifo",
             "qos_loop_matches_serial",
             "qos_mesh_matches_serial")


def _assert_qos_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    for gate in QOS_GATES:
        assert metrics.get(("qos", gate)) == 1, (
            f"qos gate {gate!r} failed: "
            f"{ {m: v for (s, m), v in metrics.items() if s == 'qos'} }")
    # the evidence behind the verdicts: pooled per-class tails, the paired
    # median ratios the timing gates judge, and a real preemption count
    for col in ("unloaded_high_p99_ms", "fairness_high_p99_ms",
                "fairness_high_p99_9_ms", "qos_high_p99_ms",
                "qos_high_p99_9_ms", "qos_high_p99_vs_unloaded_median_ratio",
                "qos_vs_fairness_tokens_median_ratio"):
        assert ("qos", col) in metrics, f"missing qos,{col}"
    assert metrics[("qos", "qos_preemptions")] >= 1
    assert metrics[("qos", "n_devices")] >= 1


def test_committed_bench_qos_json():
    """The repo-root BENCH_qos.json (regenerated by the CI multi-device
    lane under forced host devices) must stay schema-valid with every
    QoS-plane gate green."""
    path = os.path.join(REPO_ROOT, "BENCH_qos.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["qos"])
    assert payload["sections"] == ["qos"]
    assert payload["flags"].get("smoke") == "1"
    _assert_qos_gates(payload)


# -- benchmarks/compare.py: the committed-vs-fresh trajectory driver -------

def _payload(rows):
    return {"flags": {"smoke": "1"}, "sections": ["s"],
            "timings_seconds": {"s": 0.1},
            "results": [{"section": "s", "metric": m, "value": v}
                        for m, v in rows]}


def test_compare_gate_regression_fails():
    from benchmarks.compare import compare_payloads

    committed = _payload([("loop_matches_serial", 1), ("wall_s", 1.0)])
    fresh = _payload([("loop_matches_serial", 0), ("wall_s", 1.0)])
    failures, warnings, infos = compare_payloads(committed, fresh)
    assert any("gate regressed" in f for f in failures)
    assert not warnings


def test_compare_missing_metric_fails():
    from benchmarks.compare import compare_payloads

    committed = _payload([("dispatches", 1), ("wall_s", 1.0)])
    fresh = _payload([("wall_s", 1.0)])
    failures, _, _ = compare_payloads(committed, fresh)
    assert any("missing from fresh run" in f for f in failures)


def test_compare_numeric_drift_warns_not_fails():
    from benchmarks.compare import compare_payloads

    committed = _payload([("wall_s", 1.0), ("dispatches", 4)])
    fresh = _payload([("wall_s", 10.0), ("dispatches", 4)])
    failures, warnings, _ = compare_payloads(committed, fresh, rtol=0.5)
    assert not failures
    assert any("numeric drift" in w for w in warnings)
    # within tolerance -> clean
    failures, warnings, _ = compare_payloads(
        _payload([("wall_s", 1.0)]), _payload([("wall_s", 1.2)]), rtol=0.5)
    assert not failures and not warnings


def test_compare_gate_detection_is_name_based():
    """A counter that happens to equal 1 (host_syncs) is numeric, never a
    gate: 1 -> 0 on it must not fail; a new metric and a 0 -> 1 gate flip
    are info."""
    from benchmarks.compare import compare_payloads, is_gate

    assert is_gate("loop_matches_serial", 1)
    assert is_gate("pallas_used", 0)
    assert not is_gate("host_syncs", 1)
    assert not is_gate("active_fraction", 1.0)
    committed = _payload([("host_syncs", 1), ("beats_scan", 0)])
    fresh = _payload([("host_syncs", 0), ("beats_scan", 1),
                      ("new_col", 7)])
    failures, warnings, infos = compare_payloads(committed, fresh)
    assert not failures
    # host_syncs 1 -> 0 is numeric drift (a warning), never a gate failure
    assert warnings == [
        "numeric drift beyond rtol=0.5: s,host_syncs committed=1 fresh=0"]
    assert any("gate improved" in i for i in infos)
    assert any("new metric" in i for i in infos)


def test_compare_main_exit_codes(tmp_path):
    from benchmarks import compare

    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_payload([("x_matches_serial", 1)])))
    b.write_text(json.dumps(_payload([("x_matches_serial", 1)])))
    assert compare.main([str(a), str(b)]) == 0
    b.write_text(json.dumps(_payload([("x_matches_serial", 0)])))
    assert compare.main([str(a), str(b), "--rtol=0.9"]) == 1
    with pytest.raises(SystemExit, match="usage"):
        compare.main([str(a)])
    with pytest.raises(SystemExit, match="unknown flag"):
        compare.main([str(a), str(b), "--bogus"])


# The lifetime gates the soak section must hold (1 = pass); asserted both
# on the committed artifact and on the live slow-lane run.
SOAK_GATES = ("slab_flat", "plan_cache_bounded", "rows_recycled", "compacted",
              "rss_bounded", "p95_stable", "bookkeeping_bounded",
              "matches_serial", "counterfactual_grows")


def _assert_soak_gates(payload):
    metrics = {(r["section"], r["metric"]): r["value"]
               for r in payload["results"]}
    for gate in SOAK_GATES:
        assert metrics.get(("soak", gate)) == 1, (
            f"soak gate {gate!r} failed: "
            f"{ {m: v for (s, m), v in metrics.items() if s == 'soak'} }")
    # the artifact carries the evidence, not just the verdicts
    assert ("soak", "slab_bytes_per_phase") in metrics
    assert ("soak", "counterfactual_slab_bytes_per_phase") in metrics
    assert metrics[("soak", "arena_recycled_rows")] > 0
    assert metrics[("soak", "arena_compactions")] >= 1


def test_committed_bench_soak_json():
    """The repo-root BENCH_soak.json (regenerated by the CI soak step) must
    stay schema-valid with every lifetime gate green — committing an
    artifact with a failed gate is committing a known leak."""
    path = os.path.join(REPO_ROOT, "BENCH_soak.json")
    with open(path) as fh:
        payload = json.load(fh)
    _validate_schema(payload, expect_sections=["soak"])
    assert payload["sections"] == ["soak"]
    assert payload["flags"].get("smoke") == "1"
    _assert_soak_gates(payload)


@pytest.mark.slow  # runs the real soak smoke leg (~30s)
def test_smoke_soak_json_artifact_real(tmp_path):
    """End-to-end: the exact CI soak command must produce a schema-valid
    artifact with every lifetime gate green on THIS host."""
    path = tmp_path / "bench-soak.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "soak",
         f"--json={path}"],
        cwd=REPO_ROOT, env=_bench_env(), capture_output=True, text=True,
        timeout=270,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(path.read_text())
    _validate_schema(payload, expect_sections=["soak"])
    _assert_soak_gates(payload)
