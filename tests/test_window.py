"""Scheduling-window mechanics (paper §III-C/D, Fig 14/15)."""

import numpy as np
import pytest

from repro.core import (
    BufferPool,
    SchedulingWindow,
    Task,
    TaskState,
)
from repro.core.task import default_segments


def make_task(pool, reads, writes, opcode="op"):
    """reads/writes: lists of Buffer (full-range segments)."""
    r, w = default_segments(reads, writes)
    return Task(
        opcode=opcode,
        fn=lambda *xs: xs[0] if xs else None,
        inputs=tuple(reads),
        outputs=tuple(writes),
        read_segments=r,
        write_segments=w,
    )


@pytest.fixture
def pool():
    return BufferPool()


def bufs(pool, n, d=4):
    return [pool.alloc((d,), np.float32, value=np.zeros(d, np.float32)) for _ in range(n)]


class TestWindowBasics:
    def test_independent_tasks_all_ready(self, pool):
        bs = bufs(pool, 6)
        w = SchedulingWindow(size=8)
        tasks = [make_task(pool, [bs[2 * i]], [bs[2 * i + 1]]) for i in range(3)]
        w.submit_all(tasks)
        assert len(w.ready_tasks()) == 3

    def test_chain_serializes(self, pool):
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=8)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])  # RAW on b
        w.submit_all([t1, t2])
        ready = w.ready_tasks()
        assert ready == [t1]
        w.mark_executing(t1)
        w.retire(t1)
        assert w.ready_tasks() == [t2]

    def test_waw_serializes(self, pool):
        a, b = bufs(pool, 2)
        w = SchedulingWindow(size=8)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [a], [b])
        w.submit_all([t1, t2])
        assert w.ready_tasks() == [t1]

    def test_window_caps_residency(self, pool):
        bs = bufs(pool, 20)
        w = SchedulingWindow(size=4)
        tasks = [make_task(pool, [bs[i]], [bs[i + 10]]) for i in range(10)]
        w.submit_all(tasks)
        assert w.resident() == 4
        assert len(w.fifo) == 6

    def test_retire_refills_from_fifo(self, pool):
        bs = bufs(pool, 20)
        w = SchedulingWindow(size=2)
        tasks = [make_task(pool, [bs[i]], [bs[i + 10]]) for i in range(4)]
        w.submit_all(tasks)
        t = w.ready_tasks()[0]
        w.mark_executing(t)
        w.retire(t)
        assert w.resident() == 2  # refilled
        assert w.stats.retired == 1

    def test_fifo_order_preserves_program_order_dependencies(self, pool):
        """A task never enters the window before an older task it depends on
        has either entered or retired (FIFO insertion order)."""
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=1)  # degenerate: serial
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])
        t3 = make_task(pool, [a], [c])  # WAW with t2 on c
        w.submit_all([t1, t2, t3])
        order = []
        while not w.drained():
            ready = w.ready_tasks()
            assert len(ready) == 1  # window=1 degenerates to serial
            t = ready[0]
            w.mark_executing(t)
            w.retire(t)
            order.append(t.tid)
        assert order == [t1.tid, t2.tid, t3.tid]

    def test_mark_executing_requires_ready(self, pool):
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=4)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])
        w.submit_all([t1, t2])
        with pytest.raises(RuntimeError):
            w.mark_executing(t2)  # still PENDING

    def test_stats_dep_check_count(self, pool):
        bs = bufs(pool, 8)
        w = SchedulingWindow(size=8)
        tasks = [make_task(pool, [bs[i]], [bs[i + 4]]) for i in range(4)]
        w.submit_all(tasks)
        # k-th insertion checks against k resident tasks: 0+1+2+3
        assert w.stats.dep_checks == 6

    def test_invalid_window_size(self):
        with pytest.raises(ValueError):
            SchedulingWindow(size=0)
