"""Window invariants promised by window.py's docstring but previously
untested: serial degeneracy at size 1, residency cap, retire-state
validation, no-deadlock on full-window streams, and the incremental
retire_many path matching single retires."""

import numpy as np
import pytest
from _prophelper import given, settings, st

from repro.core import BufferPool, SchedulingWindow, Task, TaskState
from repro.core.task import default_segments


def make_task(pool, reads, writes, opcode="op", priority=1):
    r, w = default_segments(reads, writes)
    return Task(
        opcode=opcode,
        fn=lambda *xs: xs[0] if xs else None,
        inputs=tuple(reads),
        outputs=tuple(writes),
        read_segments=r,
        write_segments=w,
        priority=priority,
    )


def bufs(pool, n, d=4):
    return [pool.alloc((d,), np.float32, value=np.zeros(d, np.float32)) for _ in range(n)]


def random_stream(seed, n_tasks, n_buffers):
    """Random read/write pattern over a shared pool — dense hazards."""
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    bs = bufs(pool, n_buffers)
    tasks = []
    for _ in range(n_tasks):
        i0, i1 = rng.randint(n_buffers), rng.randint(n_buffers)
        o = rng.randint(n_buffers)
        tasks.append(make_task(pool, [bs[i0], bs[i1]], [bs[o]]))
    return tasks


def drain(window):
    """Drive the window to empty; returns retire order. Raises on stall."""
    order = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall: no READY kernels but window non-empty")
        t = ready[0]
        window.mark_executing(t)
        window.retire(t)
        order.append(t.tid)
    return order


class TestSerialDegeneracy:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_window_one_is_program_order(self, seed):
        tasks = random_stream(seed, 20, 4)
        w = SchedulingWindow(size=1)
        w.submit_all(tasks)
        assert drain(w) == [t.tid for t in tasks]

    def test_window_one_single_ready_at_a_time(self):
        tasks = random_stream(0, 10, 3)
        w = SchedulingWindow(size=1)
        w.submit_all(tasks)
        while not w.drained():
            ready = w.ready_tasks()
            assert len(ready) == 1
            w.mark_executing(ready[0])
            w.retire(ready[0])


class TestRetireValidation:
    def test_retire_pending_raises(self):
        pool = BufferPool()
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=4)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])  # RAW on b -> PENDING
        w.submit_all([t1, t2])
        with pytest.raises(RuntimeError):
            w.retire(t2)

    def test_retire_ready_but_not_executing_raises(self):
        pool = BufferPool()
        a, b = bufs(pool, 2)
        w = SchedulingWindow(size=4)
        t1 = make_task(pool, [a], [b])
        w.submit_all([t1])
        with pytest.raises(RuntimeError):
            w.retire(t1)  # READY, never marked EXECUTING

    def test_retire_unknown_task_raises(self):
        pool = BufferPool()
        a, b = bufs(pool, 2)
        w = SchedulingWindow(size=4)
        stranger = make_task(pool, [a], [b])
        with pytest.raises(RuntimeError):
            w.retire(stranger)

    def test_double_retire_raises(self):
        pool = BufferPool()
        a, b = bufs(pool, 2)
        w = SchedulingWindow(size=4)
        t1 = make_task(pool, [a], [b])
        w.submit_all([t1])
        w.mark_executing(t1)
        w.retire(t1)
        with pytest.raises(RuntimeError):
            w.retire(t1)


class TestResidencyCap:
    @given(st.integers(0, 10_000), st.integers(1, 9))
    @settings(max_examples=25, deadline=None)
    def test_property_max_resident_never_exceeds_size(self, seed, size):
        tasks = random_stream(seed, 30, 5)
        w = SchedulingWindow(size=size)
        w.submit_all(tasks)
        drain(w)
        assert w.stats.max_resident <= size
        assert w.stats.inserted == 30
        assert w.stats.retired == 30


class TestNoDeadlock:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_full_window_stream_never_stalls(self, seed):
        """Dense-hazard stream longer than the window: there is always at
        least one READY kernel until drained (docstring's no-deadlock
        claim — dependencies only point newer -> older)."""
        tasks = random_stream(seed, 40, 3)  # 3 buffers: nearly total order
        w = SchedulingWindow(size=8)
        w.submit_all(tasks)
        drain(w)  # raises on stall
        assert w.drained()

    def test_conservative_chain_fills_window_and_drains(self):
        """Every task conflicts with every other: window full of one READY
        + PENDINGs, still drains serially."""
        pool = BufferPool()
        (shared,) = bufs(pool, 1)
        tasks = [make_task(pool, [shared], [shared]) for _ in range(12)]
        w = SchedulingWindow(size=4)
        w.submit_all(tasks)
        assert drain(w) == [t.tid for t in tasks]


class TestRetireMany:
    def test_matches_sequential_retires(self):
        for seed in range(3):
            tasks_a = random_stream(seed, 24, 6)
            wa = SchedulingWindow(size=8)
            wa.submit_all(tasks_a)
            order_a = []
            while not wa.drained():
                ready = wa.ready_tasks()
                for t in ready:
                    wa.mark_executing(t)
                wa.retire_many(ready)
                order_a.extend(t.tid for t in ready)

            tasks_b = random_stream(seed, 24, 6)
            wb = SchedulingWindow(size=8)
            wb.submit_all(tasks_b)
            order_b = []
            while not wb.drained():
                ready = wb.ready_tasks()
                for t in ready:
                    wb.mark_executing(t)
                for t in ready:
                    wb.retire(t)
                order_b.extend(t.tid for t in ready)

            # tid sequences differ (fresh Task objects) but relative order
            # within each stream must be identical.
            pos_a = {tid: i for i, tid in enumerate(t.tid for t in tasks_a)}
            pos_b = {tid: i for i, tid in enumerate(t.tid for t in tasks_b)}
            assert [pos_a[t] for t in order_a] == [pos_b[t] for t in order_b]

    def test_retire_many_validates_states(self):
        pool = BufferPool()
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=4)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [a], [c])
        w.submit_all([t1, t2])
        w.mark_executing(t1)
        with pytest.raises(RuntimeError):
            w.retire_many([t1, t2])  # t2 not EXECUTING

    def test_ready_tasks_oldest_first_after_partial_retire(self):
        pool = BufferPool()
        a, b, c, d, e = bufs(pool, 5)
        w = SchedulingWindow(size=8)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])  # waits on t1
        t3 = make_task(pool, [d], [e])  # independent
        w.submit_all([t1, t2, t3])
        assert [t.tid for t in w.ready_tasks()] == [t1.tid, t3.tid]
        w.mark_executing(t1)
        w.retire(t1)
        # t2 woke up; ordering must remain program order (t2 before t3)
        assert [t.tid for t in w.ready_tasks()] == [t2.tid, t3.tid]


class TestReadyOrdering:
    """The READY index is kept ordered *incrementally* (sorted insert on
    wake, append on fresh insert) — ready_tasks() must report oldest-first
    program order at every step without a per-poll sort."""

    @given(st.integers(0, 10_000), st.integers(1, 9))
    @settings(max_examples=25, deadline=None)
    def test_property_ready_always_program_order(self, seed, size):
        import random as pyrandom

        tasks = random_stream(seed, 30, 4)
        pos = {t.tid: i for i, t in enumerate(tasks)}
        w = SchedulingWindow(size=size)
        w.submit_all(tasks)
        rng = pyrandom.Random(seed)
        while not w.drained():
            ready = w.ready_tasks()
            assert ready, "stall"
            positions = [pos[t.tid] for t in ready]
            assert positions == sorted(positions), "ready not oldest-first"
            # the incremental index itself must already be sorted (no
            # lazy re-sort hiding inside ready_tasks)
            assert w._ready == sorted(w._ready)
            # retire a RANDOM ready task so wakes land mid-index: a woken
            # dependent can be older than a later-inserted READY task
            t = ready[rng.randrange(len(ready))]
            w.mark_executing(t)
            w.retire(t)

    def test_wake_bisects_into_place_between_ready_peers(self):
        pool = BufferPool()
        a, b, c, d, e, f, g = bufs(pool, 7)
        w = SchedulingWindow(size=8)
        t1 = make_task(pool, [a], [b])
        t2 = make_task(pool, [b], [c])  # waits on t1
        t3 = make_task(pool, [d], [e])  # independent, READY at insert
        t4 = make_task(pool, [f], [g])  # independent, READY at insert
        w.submit_all([t1, t2, t3, t4])
        w.mark_executing(t3)  # launch the middle READY task first
        w.mark_executing(t1)
        w.retire(t1)  # wakes t2, whose seq is between none-left and t4
        assert [t.tid for t in w.ready_tasks()] == [t2.tid, t4.tid]


class TestPriorityOrdering:
    """DESIGN §13: the READY index keys on (priority bucket, seq, tid) —
    urgent buckets first, bit-identical program order within a bucket,
    and priority can never reorder *dependent* work."""

    def test_urgent_fresh_insert_jumps_ahead_of_background_ready(self):
        pool = BufferPool()
        bs = bufs(pool, 8)
        w = SchedulingWindow(size=8)
        low = [make_task(pool, [bs[2 * i]], [bs[2 * i + 1]], priority=2)
               for i in range(3)]
        w.submit_all(low)
        urgent = make_task(pool, [bs[6]], [bs[7]], priority=0)
        w.submit(urgent)  # arrives LAST, must list FIRST
        assert [t.tid for t in w.ready_tasks()] == \
            [urgent.tid] + [t.tid for t in low]
        assert w._ready == sorted(w._ready)

    def test_program_order_preserved_within_a_bucket(self):
        pool = BufferPool()
        bs = bufs(pool, 12)
        w = SchedulingWindow(size=16)
        tasks = [make_task(pool, [bs[2 * i]], [bs[2 * i + 1]],
                           priority=(0 if i % 2 else 2))
                 for i in range(6)]
        w.submit_all(tasks)
        got = [t.tid for t in w.ready_tasks()]
        want = ([t.tid for t in tasks if t.priority == 0]
                + [t.tid for t in tasks if t.priority == 2])
        assert got == want

    def test_woken_dependent_bisects_into_its_bucket(self):
        pool = BufferPool()
        a, b, c, d, e, f, g = bufs(pool, 7)
        w = SchedulingWindow(size=8)
        t1 = make_task(pool, [a], [b], priority=2)
        t2 = make_task(pool, [b], [c], priority=0)  # urgent, waits on t1
        t3 = make_task(pool, [d], [e], priority=0)  # urgent, READY
        t4 = make_task(pool, [f], [g], priority=2)  # background, READY
        w.submit_all([t1, t2, t3, t4])
        assert [t.tid for t in w.ready_tasks()] == [t3.tid, t1.tid, t4.tid]
        w.mark_executing(t1)
        w.retire(t1)
        # t2 wakes into bucket 0 — its seq (1) is older than t3's (2), so
        # it bisects AHEAD of t3 within the urgent bucket, and the whole
        # bucket stays ahead of background t4
        assert [t.tid for t in w.ready_tasks()] == [t2.tid, t3.tid, t4.tid]
        assert w._ready == sorted(w._ready)

    def test_priority_never_reorders_dependent_chain(self):
        """An urgent task RAW-dependent on background work stays PENDING:
        priority jumps the READY queue, never the dependency graph."""
        pool = BufferPool()
        a, b, c = bufs(pool, 3)
        w = SchedulingWindow(size=4)
        lo = make_task(pool, [a], [b], priority=2)
        hi = make_task(pool, [b], [c], priority=0)  # reads lo's write
        w.submit_all([lo, hi])
        assert [t.tid for t in w.ready_tasks()] == [lo.tid]

    @given(st.integers(0, 10_000), st.integers(1, 9))
    @settings(max_examples=25, deadline=None)
    def test_property_bucket_order_and_in_bucket_program_order(self, seed,
                                                              size):
        import random as pyrandom

        rng = np.random.RandomState(seed)
        tasks = random_stream(seed, 30, 4)
        for t in tasks:
            t.priority = int(rng.randint(0, 3))
        pos = {t.tid: i for i, t in enumerate(tasks)}
        prio = {t.tid: t.priority for t in tasks}
        w = SchedulingWindow(size=size)
        w.submit_all(tasks)
        pyr = pyrandom.Random(seed)
        while not w.drained():
            ready = w.ready_tasks()
            assert ready, "stall"
            keys = [(prio[t.tid], pos[t.tid]) for t in ready]
            assert keys == sorted(keys), "ready not bucket-then-program order"
            assert w._ready == sorted(w._ready)
            t = ready[pyr.randrange(len(ready))]
            w.mark_executing(t)
            w.retire(t)

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_single_class_index_identical_to_seq_order(self, seed):
        """With one priority class (the default), the bucketed index must
        be bit-identical to the old (seq, tid) index: same ready order at
        every step as sorting by program position alone."""
        tasks = random_stream(seed, 24, 4)
        pos = {t.tid: i for i, t in enumerate(tasks)}
        w = SchedulingWindow(size=6)
        w.submit_all(tasks)
        while not w.drained():
            ready = w.ready_tasks()
            positions = [pos[t.tid] for t in ready]
            assert positions == sorted(positions)
            t = ready[0]
            w.mark_executing(t)
            w.retire(t)
