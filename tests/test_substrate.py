"""Substrate tests: data pipeline determinism/resume, checkpoint
atomicity + restart, trainer fault tolerance, optimizer, compression,
ACS-scheduled continuous-batching server."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.configs import ARCHS
from repro.data import DataCursor, TokenPipeline
from repro.models import init_params
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    ef_int8_compress,
    ef_int8_decompress,
    topk_compress,
    wsd_schedule,
)
from repro.runtime import ContinuousBatchingServer, Trainer, TrainerConfig


class TestDataPipeline:
    def test_deterministic_across_instances(self):
        a = TokenPipeline(1000, 16, 4, seed=7).next_batch()
        b = TokenPipeline(1000, 16, 4, seed=7).next_batch()
        np.testing.assert_array_equal(a[0], b[0])

    def test_shards_differ(self):
        a = TokenPipeline(1000, 16, 4, seed=7, n_shards=2, shard=0).next_batch()
        b = TokenPipeline(1000, 16, 4, seed=7, n_shards=2, shard=1).next_batch()
        assert not np.array_equal(a[0], b[0])

    def test_seek_resumes_exactly(self):
        p = TokenPipeline(1000, 16, 4, seed=7)
        for _ in range(5):
            p.next_batch()
        cursor = DataCursor(p.cursor.step, p.cursor.shard)
        sixth = p.next_batch()
        q = TokenPipeline(1000, 16, 4, seed=7)
        q.seek(cursor)
        np.testing.assert_array_equal(q.next_batch()[0], sixth[0])

    def test_labels_are_shifted_inputs(self):
        x, y = TokenPipeline(1000, 16, 4, seed=0).next_batch()
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), jnp.zeros(2)]}
        save_tree(tree, tmp_path / "ck", extras={"cursor": {"step": 3, "shard": 0}})
        like = jax.tree.map(jnp.zeros_like, tree)
        restored, extras = restore_tree(like, tmp_path / "ck")
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        assert extras["cursor"]["step"] == 3

    def test_shape_mismatch_rejected(self, tmp_path):
        save_tree({"a": jnp.ones(3)}, tmp_path / "ck")
        with pytest.raises(ValueError):
            restore_tree({"a": jnp.ones(4)}, tmp_path / "ck")

    def test_manager_latest_and_gc(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=2)
        for step in (1, 2, 3, 4):
            m.save(step, {"w": jnp.full(2, step)})
        assert m.latest_step() == 4
        dirs = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(dirs) == 2  # gc kept last 2
        restored, _ = m.restore_latest({"w": jnp.zeros(2)})
        np.testing.assert_array_equal(np.asarray(restored["w"]), [4, 4])


class TestOptim:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw_init(params)
        for _ in range(120):
            grads = {"w": 2 * params["w"]}
            params, state = adamw_update(params, grads, state,
                                         jnp.asarray(0.05), weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full(4, 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        assert total == pytest.approx(1.0, rel=1e-4)

    def test_schedules(self):
        cos = cosine_schedule(1.0, warmup=10, total=100)
        assert float(cos(jnp.asarray(0))) == 0.0
        assert float(cos(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
        wsd = wsd_schedule(1.0, warmup=10, stable=50, decay=40)
        assert float(wsd(jnp.asarray(30))) == pytest.approx(1.0)
        assert float(wsd(jnp.asarray(100))) == pytest.approx(0.01, rel=1e-2)

    def test_ef_int8_roundtrip_error_feedback(self):
        rng = np.random.RandomState(0)
        g = {"w": jnp.asarray(rng.randn(64).astype(np.float32))}
        err = {"w": jnp.zeros(64)}
        q, s, err = ef_int8_compress(g, err)
        deq = ef_int8_decompress(q, s)
        # error feedback: g = deq + err exactly
        np.testing.assert_allclose(
            np.asarray(deq["w"] + err["w"]), np.asarray(g["w"]), rtol=1e-5
        )
        assert q["w"].dtype == jnp.int8

    def test_topk_keeps_largest(self):
        g = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0])}
        kept = topk_compress(g, frac=0.5)
        np.testing.assert_array_equal(
            np.asarray(kept["w"]), np.asarray([0.0, -5.0, 0.0, 3.0])
        )


@pytest.fixture(scope="module")
def tiny_cfg():
    import dataclasses
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=32, d_ff=64, vocab=128,
                               n_heads=2, n_kv_heads=1, head_dim=16)


class TestTrainerFaultTolerance:
    def test_loss_decreases(self, tiny_cfg, tmp_path):
        t = Trainer(tiny_cfg, TrainerConfig(seq_len=16, batch=4, total_steps=60,
                                            checkpoint_every=30, lr=5e-3),
                    tmp_path / "ck")
        metrics = t.run()
        first = np.mean([m["loss"] for m in metrics[:10]])
        last = np.mean([m["loss"] for m in metrics[-10:]])
        assert last < first, (first, last)

    def test_crash_restart_resumes_exactly(self, tiny_cfg, tmp_path):
        tc = TrainerConfig(seq_len=16, batch=4, total_steps=40,
                           checkpoint_every=10, lr=5e-3)
        # uninterrupted run
        ref = Trainer(tiny_cfg, tc, tmp_path / "a").run()

        # crash at step 25, then restart from the step-19 checkpoint
        t1 = Trainer(tiny_cfg, tc, tmp_path / "b", fail_at_step=25)
        with pytest.raises(RuntimeError, match="injected failure"):
            t1.run()
        t2 = Trainer(tiny_cfg, tc, tmp_path / "b")
        assert t2.start_step == 20  # resumed after last checkpoint
        resumed = t2.run()

        ref_tail = {m["step"]: m["loss"] for m in ref if m["step"] >= 20}
        res_tail = {m["step"]: m["loss"] for m in resumed}
        for step, loss in res_tail.items():
            assert loss == pytest.approx(ref_tail[step], rel=1e-4), step

    def test_grad_compression_still_learns(self, tiny_cfg, tmp_path):
        t = Trainer(tiny_cfg, TrainerConfig(seq_len=16, batch=4, total_steps=60,
                                            checkpoint_every=60, lr=5e-3,
                                            grad_compression=True),
                    tmp_path / "ck")
        metrics = t.run()
        assert np.mean([m["loss"] for m in metrics[-10:]]) < np.mean(
            [m["loss"] for m in metrics[:10]]
        )

    def test_straggler_hook_fires(self, tiny_cfg, tmp_path):
        import time

        seen = []
        t = Trainer(tiny_cfg, TrainerConfig(seq_len=16, batch=4, total_steps=20,
                                            checkpoint_every=20,
                                            straggler_factor=1.5),
                    tmp_path / "ck", on_straggler=lambda s, r: seen.append(s))
        orig = t.pipeline.next_batch

        def slow_batch():
            if t.pipeline.cursor.step == 15:
                time.sleep(0.5)
            return orig()

        t.pipeline.next_batch = slow_batch
        t.run()
        assert 15 in t.straggler_steps or seen  # watchdog saw the slow step


class TestContinuousBatchingServer:
    def test_serves_requests_through_acs(self, tiny_cfg):
        params = init_params(tiny_cfg, jax.random.PRNGKey(0), tp_size=1)
        server = ContinuousBatchingServer(tiny_cfg, params, max_slots=2,
                                          max_len=32)
        rng = np.random.RandomState(0)
        reqs = [server.submit(rng.randint(0, tiny_cfg.vocab, 5), max_new=3)
                for _ in range(4)]
        done = server.run_until_drained()
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        for r in done:
            assert len(r.generated) == 3

    def test_prefill_overlaps_decode_wave(self, tiny_cfg):
        """A newly admitted request's prefill shares a wave with the
        in-flight decode (disjoint slots => same ACS wave)."""
        params = init_params(tiny_cfg, jax.random.PRNGKey(0), tp_size=1)
        server = ContinuousBatchingServer(tiny_cfg, params, max_slots=2,
                                          max_len=32)
        rng = np.random.RandomState(1)
        server.submit(rng.randint(0, tiny_cfg.vocab, 5), max_new=4)
        server.step()          # prefill req 1
        server.submit(rng.randint(0, tiny_cfg.vocab, 5), max_new=4)
        server.step()          # decode req1 || prefill req2
        waves = server.report_log[-1]
        assert waves["tasks_this_run"] == 2
        assert waves["waves_this_run"] == 1  # both in ONE wave
