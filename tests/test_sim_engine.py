"""The Brax-like physics engine as an ACS workload (paper §II-B, §VI-A)."""

import numpy as np
import pytest

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.sim import ENVIRONMENTS, PhysicsEngine, make_env


@pytest.mark.parametrize("env", ["ant", "cheetah"])
def test_engine_steps_and_stays_finite(env):
    eng = PhysicsEngine(make_env(env), n_envs=8, group_size=4, seed=0)
    stream = TaskStream()
    for _ in range(3):
        eng.emit_step(stream)
        WaveScheduler(window_size=32).run(stream.tasks[-200:] if False else stream.tasks)
        stream = TaskStream()  # drain per step
    snap = eng.state_snapshot()
    assert snap.shape == (8, eng.spec.n_bodies, 6)
    assert np.all(np.isfinite(snap))


@pytest.mark.slow  # multi-step 8-env run (~20s): stress lane
def test_acs_matches_serial_execution():
    """ACS scheduling of the physics stream is bit-compatible with serial."""
    def run(scheduler_fn):
        eng = PhysicsEngine(make_env("ant"), n_envs=8, group_size=4, seed=3)
        for _ in range(4):
            stream = TaskStream()
            eng.emit_step(stream)
            scheduler_fn(stream.tasks)
        return eng.state_snapshot()

    ref = run(lambda ts: run_serial(ts))
    got = run(lambda ts: WaveScheduler(window_size=32).run(ts))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=1e-6)


@pytest.mark.slow  # 6 full grasp steps (~35s, the suite's slowest): stress lane
def test_input_dependence_of_contact_kernels():
    """The active-contact set (and so the task stream) varies with state —
    the paper's defining property of these workloads."""
    eng = PhysicsEngine(make_env("grasp"), n_envs=8, group_size=4, seed=0)
    counts = []
    for _ in range(6):
        stream = TaskStream()
        eng.emit_step(stream)
        counts.append(len(stream.tasks))
        WaveScheduler(window_size=32).run(stream.tasks)
    assert len(set(counts)) > 1, f"stream should vary with state, got {counts}"


def test_waves_expose_cross_group_parallelism():
    eng = PhysicsEngine(make_env("walker2d"), n_envs=16, group_size=4, seed=0)
    stream = TaskStream()
    eng.emit_step(stream)
    report = WaveScheduler(window_size=32).run(stream.tasks)
    serial = len(stream.tasks)
    assert report.exec_stats["dispatches"] < serial / 2, (
        "fused waves should need far fewer dispatches than one-per-kernel"
    )
    assert report.exec_stats["max_wave_width"] >= 4


def test_all_five_paper_environments_construct():
    for name, spec in ENVIRONMENTS.items():
        eng = PhysicsEngine(spec, n_envs=4, group_size=4, seed=0)
        stream = TaskStream()
        eng.emit_step(stream)
        assert len(stream.tasks) > spec.n_joints
