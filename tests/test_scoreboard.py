"""Interval-scoreboard properties: the refactor gate is exact equality
with the pairwise oracle (`segments.window_upstreams`, the seed window's
whole-window scan) over random segment streams with insert/retire
interleaving — plus structural invariants (claims leave with their task,
boundaries stay O(live claims)) and `SegmentSet.coalesced()` canonical-form
checks."""

import collections

import numpy as np
import pytest
from _prophelper import given, settings, st

from repro.core import IntervalScoreboard, Segment, SegmentSet, SchedulingWindow
from repro.core.segments import (
    any_overlap,
    pairwise_window_replay,
    window_upstreams,
)


def mkset(rng, n, span=1 << 12, max_size=64):
    """Dense-hazard segment set: small address span forces overlaps."""
    return SegmentSet([
        Segment(int(rng.randint(0, span)), int(rng.randint(0, max_size)))
        for _ in range(n)
    ])


def oracle_upstreams(reads, writes, store, tids):
    mask = window_upstreams(
        reads, writes,
        [store[t][0] for t in tids],
        [store[t][1] for t in tids],
    )
    return {t for t, hit in zip(tids, mask) if hit}


class TestOracleEquality:
    @given(st.integers(0, 10_000), st.integers(2, 24))
    @settings(max_examples=30, deadline=None)
    def test_property_upstreams_match_pairwise_oracle(self, seed, cap):
        """Random interleaved insert/retire stream: every insertion's
        upstream set equals the all-pairs scan over the live residents."""
        rng = np.random.RandomState(seed)
        sb = IntervalScoreboard()
        live = collections.deque()
        store = {}
        for tid in range(120):
            if live and (len(live) >= cap or rng.rand() < 0.4):
                # retire out of FIFO order too: scoreboard order freedom
                idx = rng.randint(len(live)) if rng.rand() < 0.3 else 0
                old = live[idx]
                del live[idx]
                sb.retire(old)
                del store[old]
            reads = mkset(rng, rng.randint(1, 6))
            writes = mkset(rng, rng.randint(1, 6))
            got = sb.insert(tid, reads, writes)
            expect = oracle_upstreams(reads, writes, store, list(store))
            assert got == expect, (tid, sorted(got), sorted(expect))
            store[tid] = (reads, writes)
            live.append(tid)

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_property_probe_is_insert_without_claims(self, seed):
        rng = np.random.RandomState(seed)
        sb = IntervalScoreboard()
        store = {}
        for tid in range(20):
            r, w = mkset(rng, 3), mkset(rng, 3)
            sb.insert(tid, r, w)
            store[tid] = (r, w)
        r, w = mkset(rng, 4), mkset(rng, 4)
        before = len(sb)
        got = sb.probe(r, w)
        assert got == oracle_upstreams(r, w, store, list(store))
        assert len(sb) == before  # probe registered nothing

    def test_waw_chain_reports_every_resident_writer(self):
        """The exactness reason for writer SETS (module docstring): two
        resident writers of one interval must BOTH be upstream of a
        reader, exactly as the pairwise scan reports."""
        sb = IntervalScoreboard()
        seg = SegmentSet([Segment(0, 64)])
        empty = SegmentSet()
        assert sb.insert(1, empty, seg) == set()
        assert sb.insert(2, empty, seg) == {1}       # WAW
        assert sb.insert(3, seg, empty) == {1, 2}    # RAW on both writers
        sb.retire(2)
        # a would-be writer sees the surviving writer AND the reader
        assert sb.probe(empty, seg) == {1, 3}
        # a would-be reader sees only the surviving writer (RAR: no hazard)
        assert sb.probe(seg, empty) == {1}


class TestInsertRetireInvariants:
    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_property_full_retire_empties_structure(self, seed):
        rng = np.random.RandomState(seed)
        sb = IntervalScoreboard()
        tids = list(range(40))
        for tid in tids:
            sb.insert(tid, mkset(rng, 4), mkset(rng, 4))
        order = list(rng.permutation(tids))
        for tid in order:
            sb.retire(tid)
        assert len(sb) == 0
        assert sb.boundaries == 0  # coalescing reclaimed every cell
        assert sb.probe(mkset(rng, 4), mkset(rng, 4)) == set()

    def test_retire_removes_only_own_claims(self):
        sb = IntervalScoreboard()
        a = SegmentSet([Segment(0, 100)])
        b = SegmentSet([Segment(50, 100)])  # overlaps a
        empty = SegmentSet()
        sb.insert(1, empty, a)
        sb.insert(2, empty, b)
        sb.retire(1)
        assert sb.probe(a, empty) == {2}  # b's claim survives intact

    def test_duplicate_insert_raises(self):
        sb = IntervalScoreboard()
        s = SegmentSet([Segment(0, 8)])
        sb.insert(7, s, s)
        with pytest.raises(ValueError):
            sb.insert(7, s, s)

    def test_retire_unknown_raises(self):
        sb = IntervalScoreboard()
        with pytest.raises(KeyError):
            sb.retire(99)

    def test_empty_segments_claim_nothing(self):
        sb = IntervalScoreboard()
        hollow = SegmentSet([Segment(10, 0), Segment(500, 0)])
        assert sb.insert(1, hollow, hollow) == set()
        assert sb.boundaries == 0
        # a real overlap query across those addresses sees no claims
        assert sb.probe(SegmentSet([Segment(0, 1000)]),
                        SegmentSet([Segment(0, 1000)])) == set()

    def test_probe_counter_counts_cells(self):
        sb = IntervalScoreboard()
        sb.insert(1, SegmentSet(), SegmentSet([Segment(0, 64)]))
        before = sb.probe_cells
        sb.probe(SegmentSet([Segment(0, 8)]), SegmentSet())
        assert sb.probe_cells > before

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_property_boundaries_stay_bounded_by_live_claims(self, seed):
        """Long rolling stream: structure size tracks LIVE claims, not
        stream length — the invariant unbounded sessions rely on."""
        rng = np.random.RandomState(seed)
        sb = IntervalScoreboard()
        live = collections.deque()
        for tid in range(300):
            if len(live) >= 16:
                sb.retire(live.popleft())
            sb.insert(tid, mkset(rng, 3, span=1 << 28),
                      mkset(rng, 3, span=1 << 28))
            live.append(tid)
            # <= 2 boundaries per coalesced segment, <= 6 segments/task
            assert sb.boundaries <= len(live) * 12


class TestWindowBitIdentity:
    """The window's schedule through the scoreboard must be bit-identical
    to a pairwise-oracle window replay (same fill/wave/retire loop, deps
    from `window_upstreams`)."""

    @staticmethod
    def _tasks(seed, n_tasks, n_buffers):
        from repro.core import BufferPool
        from repro.core.task import Task, default_segments

        rng = np.random.RandomState(seed)
        pool = BufferPool()
        bufs = [pool.alloc((4,), np.float32, value=np.zeros(4, np.float32))
                for _ in range(n_buffers)]
        tasks = []
        for _ in range(n_tasks):
            reads = [bufs[rng.randint(n_buffers)], bufs[rng.randint(n_buffers)]]
            writes = [bufs[rng.randint(n_buffers)]]
            r, w = default_segments(reads, writes)
            tasks.append(Task(opcode="op", fn=lambda x, y: x,
                              inputs=tuple(reads), outputs=tuple(writes),
                              read_segments=r, write_segments=w))
        return tasks

    @given(st.integers(0, 10_000), st.integers(1, 12))
    @settings(max_examples=15, deadline=None)
    def test_property_wave_schedule_matches_oracle_replay(self, seed, size):
        tasks = self._tasks(seed, 40, 5)
        window = SchedulingWindow(size=size)
        window.submit_all(tasks)
        waves = []
        while not window.drained():
            ready = window.ready_tasks()
            assert ready
            for t in ready:
                window.mark_executing(t)
            window.retire_many(ready)
            waves.append([t.tid for t in ready])
        assert waves == pairwise_window_replay(tasks, size)
        # The scoreboard's work tracks the task's own segments, not the
        # residents: each task here touches 3 whole-buffer segments over
        # 5 buffers, so probed cells per insertion stay bounded by a
        # small constant REGARDLESS of window size (a regression to
        # per-resident or per-row probing would blow through this).
        assert window.stats.scoreboard_probes <= 12 * len(tasks)
        assert window.stats.inserted == len(tasks)


class TestCoalesced:
    def test_merges_adjacent_and_overlapping(self):
        s = SegmentSet([Segment(10, 10), Segment(0, 10), Segment(15, 20)])
        c = s.coalesced()
        assert [(x.start, x.end) for x in c] == [(0, 35)]

    def test_drops_empty_segments(self):
        s = SegmentSet([Segment(5, 0), Segment(20, 4), Segment(90, 0)])
        assert [(x.start, x.end) for x in s.coalesced()] == [(20, 24)]

    def test_canonical_input_returns_self(self):
        s = SegmentSet([Segment(0, 4), Segment(8, 4)])
        assert s.coalesced() is s

    def test_cached(self):
        s = SegmentSet([Segment(4, 8), Segment(0, 8)])
        assert s.coalesced() is s.coalesced()

    @given(st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_property_preserves_every_overlap_verdict(self, seed):
        """Coalescing must not change the covered address set: any probe
        set overlaps the original iff it overlaps the coalesced form."""
        rng = np.random.RandomState(seed)
        s = mkset(rng, rng.randint(0, 8), span=256, max_size=32)
        c = s.coalesced()
        # canonical form: sorted, strictly disjoint (gaps survive), non-empty
        assert all(a.size > 0 for a in c)
        pairs = list(c)
        for i in range(len(pairs) - 1):
            assert pairs[i].end < pairs[i + 1].start
        for _ in range(20):
            probe = [Segment(int(rng.randint(0, 300)), int(rng.randint(0, 16)))]
            assert any_overlap(probe, list(s)) == any_overlap(probe, pairs)
