"""ThreadedStreamScheduler stress: the paper-faithful K-thread ACS-SW was
only exercised at small scale (4 streams, 40 tasks). Here: 8+ scheduler
threads racing over a 200-task stream with dense shared read/write
segments, asserting full drain and serial equivalence."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import BufferPool, Task, ThreadedStreamScheduler, run_serial
from repro.core.task import default_segments

pytestmark = pytest.mark.slow  # stress lane: excluded from tier-1

D = 4


def _axpy(x, y):
    return 1.5 * x + y + 1.0


def _mul(x, y):
    return x * y - 0.5


OPS = {"axpy": _axpy, "mul": _mul}


def build_stream(seed: int, n_tasks: int, n_buffers: int):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        i0, i1 = rng.randint(n_buffers), rng.randint(n_buffers)
        o = rng.randint(n_buffers)
        ins = (buffers[i0], buffers[i1])
        outs = (buffers[o],)
        r, w = default_segments(ins, outs)
        tasks.append(
            Task(opcode=op, fn=OPS[op], inputs=ins, outputs=outs, read_segments=r, write_segments=w)
        )
    return buffers, tasks


def final_values(buffers):
    return np.stack([np.asarray(b.value) for b in buffers])


class TestThreadedStress:
    @pytest.mark.parametrize("num_streams", [8, 12])
    def test_large_stream_drains_and_matches_serial(self, num_streams):
        seed = 42
        bufs, tasks = build_stream(seed, 200, 10)
        run_serial(tasks)
        ref = final_values(bufs)

        bufs2, tasks2 = build_stream(seed, 200, 10)
        report = ThreadedStreamScheduler(
            window_size=32, num_streams=num_streams
        ).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)
        assert report.exec_stats["tasks_run"] == 200
        assert report.window_stats["retired"] == 200
        assert sorted(t for wave in report.waves for t in wave) == sorted(
            t.tid for t in tasks2
        )

    def test_more_streams_than_parallelism(self):
        """16 threads fighting over a 3-buffer stream (nearly total order):
        threads must spin-yield without deadlock or dropped retires."""
        seed = 7
        bufs, tasks = build_stream(seed, 120, 3)
        run_serial(tasks)
        ref = final_values(bufs)

        bufs2, tasks2 = build_stream(seed, 120, 3)
        report = ThreadedStreamScheduler(window_size=16, num_streams=16).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)
        assert report.window_stats["retired"] == 120

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_repeated_runs_stable(self, seed):
        """Thread interleavings vary run to run; results must not."""
        bufs, tasks = build_stream(seed, 80, 6)
        run_serial(tasks)
        ref = final_values(bufs)
        bufs2, tasks2 = build_stream(seed, 80, 6)
        ThreadedStreamScheduler(window_size=32, num_streams=8).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)
