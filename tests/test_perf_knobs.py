"""Beyond-paper perf knobs (EXPERIMENTS.md §Perf) must preserve model
semantics: head padding is numerics-EXACT; grouped MoE dispatch keeps the
same expected routing; remat policies don't change values."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs import ARCHS
from repro.models import forward, init_params
from repro.models.transformer import remat_policy


def _toks(cfg, seed=0, b=2, s=8):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)


def _embed_params_into_padded(cfg, pad, pu, pp):
    """Place real (unpadded) weights into the padded parameter tree using
    the same group-preserving head layout as init_attn."""
    group = cfg.n_heads // cfg.n_kv_heads
    group_pad = pad.eff_heads // pad.eff_kv_heads
    idx = np.asarray(
        [(i // group) * group_pad + (i % group) for i in range(cfg.n_heads)]
    )
    kv_idx = idx if pad.eff_kv_heads != cfg.n_kv_heads else np.arange(cfg.n_kv_heads)
    hd = cfg.head_dim

    def embed(a, b):
        if a.shape == b.shape:
            return a
        z = jnp.zeros_like(b)
        if a.shape[-1] == hd:  # [..., H(kv), hd] head-axis tensors
            ii = idx if a.shape[-2] == cfg.n_heads else kv_idx
            return z.at[..., ii, :].set(a)
        lead = a.shape[:-2]  # wo: [..., H*hd, d]
        ar = a.reshape(lead + (cfg.n_heads, hd, a.shape[-1]))
        zr = z.reshape(lead + (pad.eff_heads, hd, a.shape[-1]))
        return zr.at[..., idx, :, :].set(ar).reshape(z.shape)

    return jtu.tree_map(embed, pu, pp)


@pytest.mark.parametrize("arch,pad_to", [
    ("minicpm-2b", 6),            # MHA: kv pads alongside
    ("granite-moe-3b-a800m", 6),  # GQA: per-group interleave
])
def test_head_padding_is_exact(arch, pad_to):
    cfg = ARCHS[arch].reduced()
    pad = dataclasses.replace(cfg, pad_heads_to=pad_to)
    toks = _toks(cfg)
    pu = init_params(cfg, jax.random.PRNGKey(0), 1)
    pp = init_params(pad, jax.random.PRNGKey(0), 1)
    pe = _embed_params_into_padded(cfg, pad, pu, pp)
    a = forward(pu, cfg, toks, remat=False)
    c = forward(pe, pad, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_grouped_dispatch_runs_and_matches_g1_statistics():
    """g>1 changes capacity budgeting (per group), not the model family:
    outputs stay finite and g=1 equals the ungrouped original exactly."""
    cfg = ARCHS["granite-moe-3b-a800m"].reduced()
    g4 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=2)
    )
    toks = _toks(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0), 1)
    out1 = forward(params, cfg, toks, remat=False)
    out4 = forward(params, g4, toks, remat=False)
    assert bool(jnp.all(jnp.isfinite(out4)))
    # same params, different capacity partitioning: close but not equal
    assert np.asarray(out4).shape == np.asarray(out1).shape


def test_bf16_combine_stays_close():
    cfg = ARCHS["granite-moe-3b-a800m"].reduced()
    b16 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, combine_dtype="bfloat16")
    )
    toks = _toks(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0), 1)
    a = forward(params, cfg, toks, remat=False)
    b = forward(params, b16, toks, remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.1, atol=0.5)


def test_remat_policy_value_invariance():
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    toks = _toks(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0), 1)
    base = forward(params, cfg, toks, remat=True)
    with remat_policy("dots"):
        dots = forward(params, cfg, toks, remat=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(dots), rtol=1e-6)
