"""Property-test shim: `hypothesis` when available, seeded `random` otherwise.

The test suite's property tests only use a narrow hypothesis surface —
``given``/``settings`` decorators and the ``integers``/``lists``/``builds``
strategies. When hypothesis is installed we re-export the real thing
(shrinking, example databases, the works). When it is not (the common case
in hermetic containers), a tiny deterministic stand-in runs each property
against ``max_examples`` pseudo-random draws seeded from the test's
qualified name, so failures reproduce across runs and machines.

Usage (drop-in for the three import lines the suite used):

    from _prophelper import given, settings, st
"""

from __future__ import annotations

import random
import zlib

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback: seeded-random property driver
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A draw(rng) -> value closure with hypothesis-ish repr."""

        def __init__(self, draw, name="strategy"):
            self._draw = draw
            self._name = name

        def draw(self, rng):
            return self._draw(rng)

        def __repr__(self):
            return self._name

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value),
                f"floats({min_value}, {max_value})",
            )

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options), "sampled_from(...)")

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw, f"lists({elements!r})")

        @staticmethod
        def builds(target, *args, **kwargs):
            def draw(rng):
                a = [s.draw(rng) for s in args]
                k = {key: s.draw(rng) for key, s in kwargs.items()}
                return target(*a, **k)

            return _Strategy(draw, f"builds({getattr(target, '__name__', target)!r})")

        @staticmethod
        def tuples(*args):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in args), "tuples(...)"
            )

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        """Records max_examples on the test fn for ``given`` to pick up."""

        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        """Run the wrapped test against N deterministic random draws.

        Seeded from the test's qualified name (crc32), so every run and
        every machine replays the same example sequence; a failing draw's
        arguments are attached to the raised exception.
        """

        def deco(fn):
            import functools
            import inspect

            max_examples = getattr(fn, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*call_args, **call_kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for example in range(max_examples):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*call_args, *drawn, **call_kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"property falsified on example {example} "
                            f"(seed {seed}): args={drawn!r}"
                        ) from exc

            # Hide the drawn parameters from pytest's fixture resolution
            # (the trailing len(strategies) params are filled by draws).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            wrapper.__signature__ = sig.replace(
                parameters=params[: len(params) - len(strategies)]
            )
            wrapper.hypothesis_shim = True
            return wrapper

        return deco
