"""THE differential matrix: every execution policy in the public registries
— batch schedulers (``SCHEDULER_NAMES``) and live sessions
(``SESSION_NAMES``) — must be observationally equivalent to ``run_serial``
on the same streams, across three stream families:

* ``sim``       — the physics engine's irregular kernel stream (row-view
                  aliasing, input-dependent contacts, variable arity);
* ``dyn``       — the dynamic-routing DNN stream (mixed shape classes,
                  deep dependency chains);
* ``mixed_tag`` — two tagged tenant streams interleaved over shared
                  buffers (RAW/WAR/WAW hazards across tenants), the live
                  serving shape.

Any new scheduler or session is covered by adding its name to the registry
in ``core/scheduler.py`` — this module parametrizes over the registries,
not over a hand-maintained list. Sessions are additionally fed
*interleaved* (random submit chunks with polls in between), the §III-D
live-FIFO pattern.

The factory functions themselves are also under test: unknown names and
plan modes must fail loudly with the valid choices in the message.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BufferPool,
    PLAN_MODES,
    SCHEDULER_NAMES,
    SESSION_NAMES,
    Task,
    TaskStream,
    make_scheduler,
    make_session,
    run_serial,
)
from repro.core.task import default_segments
from repro.core.wrapper import AcsKernel
from repro.kernels.ops import LOOP_BRANCHES

D = 4
WINDOW = 16


# ---------------------------------------------------------------------------
# Stream builders: each returns (snapshot_fn, tasks). snapshot_fn reads the
# final observable state as one ndarray AFTER the tasks ran.
# ---------------------------------------------------------------------------

def _build_sim(seed=0):
    from repro.sim import ENVIRONMENTS, PhysicsEngine

    eng = PhysicsEngine(ENVIRONMENTS["cheetah"], n_envs=2, group_size=1,
                        seed=seed)
    stream = TaskStream()
    eng.emit_batch(stream, 1)
    return eng.state_snapshot, stream.tasks


def _build_dyn(seed=0):
    from repro.dyn import WORKLOADS

    init_fn, build_fn, _ = WORKLOADS["dynamic_routing"]
    rng = np.random.RandomState(seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)
    params = init_fn(0)
    stream = TaskStream()
    out = build_fn(params, stream, x)
    return (lambda: np.asarray(out.value)), stream.tasks


# The ready-queue switch-branch fns (kernels/ops.py): shared objects, so
# the device registry's switch table and these streams can never diverge.
_axpy = LOOP_BRANCHES["axpy"]
_mul = LOOP_BRANCHES["mul"]


def _build_mixed_tag(seed=0):
    """Two tenants launch kernels into tagged streams over a SHARED buffer
    pool, interleaved in program order — cross-tenant RAW/WAR/WAW hazards
    must serialize exactly as the serial baseline does."""
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    bufs = [
        pool.alloc((D,), np.float32,
                   value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(6)
    ]
    kernels = {"axpy": AcsKernel(name="axpy_mixed", fn=_axpy),
               "mul": AcsKernel(name="mul_mixed", fn=_mul)}
    streams = {"tenantA": TaskStream(tag="tenantA"),
               "tenantB": TaskStream(tag="tenantB")}
    tasks = []
    for _ in range(24):
        tag = "tenantA" if rng.rand() < 0.5 else "tenantB"
        kern = kernels["axpy" if rng.rand() < 0.5 else "mul"]
        ins = (bufs[rng.randint(6)], bufs[rng.randint(6)])
        outs = (bufs[rng.randint(6)],)
        tasks.append(kern.launch(streams[tag], inputs=ins, outputs=outs))
    snapshot = lambda: np.stack([np.asarray(b.value) for b in bufs])
    return snapshot, tasks


STREAMS = {"sim": _build_sim, "dyn": _build_dyn, "mixed_tag": _build_mixed_tag}

_REF_CACHE = {}


def _ref(stream_name):
    """Serial-baseline snapshot, computed once per stream family."""
    if stream_name not in _REF_CACHE:
        snap, tasks = STREAMS[stream_name]()
        run_serial(tasks)
        _REF_CACHE[stream_name] = snap()
    return _REF_CACHE[stream_name]


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------

class TestSchedulerMatrix:
    @pytest.mark.parametrize("stream_name", sorted(STREAMS))
    @pytest.mark.parametrize("policy", SCHEDULER_NAMES)
    def test_matches_serial(self, policy, stream_name):
        ref = _ref(stream_name)
        snap, tasks = STREAMS[stream_name]()
        run = make_scheduler(policy, window_size=WINDOW)
        report = run(tasks)
        np.testing.assert_array_equal(snap(), ref)
        assert report.exec_stats["tasks_run"] == len(tasks)


class TestSessionMatrix:
    @pytest.mark.parametrize("stream_name", sorted(STREAMS))
    @pytest.mark.parametrize("kind", SESSION_NAMES)
    def test_interleaved_feed_matches_serial(self, kind, stream_name):
        ref = _ref(stream_name)
        snap, tasks = STREAMS[stream_name]()
        session = make_session(kind, window_size=WINDOW)
        rng = np.random.RandomState(7)
        i = 0
        while i < len(tasks):
            k = 1 + rng.randint(6)
            session.submit(tasks[i: i + k])
            i += k
            if rng.rand() < 0.6:
                session.poll()
        report = session.close()
        np.testing.assert_array_equal(snap(), ref)
        assert report.window_stats["retired"] == len(tasks)
        assert sum(len(w) for w in report.waves) == len(tasks)
        if stream_name == "mixed_tag":
            # tagged tenant accounting must cover every task exactly once
            assert sum(session.retired_by_tag.values()) == len(tasks)
            assert set(session.retired_by_tag) == {"tenantA", "tenantB"}


# ---------------------------------------------------------------------------
# plan_mode="loop": the ready-queue epoch executor (DESIGN §2 A3) is a
# plan-mode axis on the "device" registry entries, not a registry name —
# covered here explicitly on the same three stream families, batch and
# interleaved-live.
# ---------------------------------------------------------------------------

class TestLoopModeMatrix:
    @pytest.mark.parametrize("stream_name", sorted(STREAMS))
    def test_scheduler_matches_serial(self, stream_name):
        ref = _ref(stream_name)
        snap, tasks = STREAMS[stream_name]()
        run = make_scheduler("device", window_size=WINDOW, plan_mode="loop")
        report = run(tasks)
        np.testing.assert_array_equal(snap(), ref)
        assert report.exec_stats["tasks_run"] == len(tasks)

    @pytest.mark.parametrize("stream_name", sorted(STREAMS))
    def test_interleaved_feed_matches_serial(self, stream_name):
        ref = _ref(stream_name)
        snap, tasks = STREAMS[stream_name]()
        session = make_session("device", window_size=WINDOW,
                               plan_mode="loop")
        rng = np.random.RandomState(11)
        i = 0
        while i < len(tasks):
            k = 1 + rng.randint(6)
            session.submit(tasks[i: i + k])
            i += k
            if rng.rand() < 0.6:
                session.poll()
        report = session.close()
        np.testing.assert_array_equal(snap(), ref)
        assert report.window_stats["retired"] == len(tasks)
        stats = session.session_stats()
        assert stats["plan_mode"] == "loop"
        assert stats["loop_dispatches"] >= 1

    def test_mid_epoch_admission_preserves_program_order(self):
        """Retirement callbacks on a RAW chain must fire in program order
        even when later chain links are admitted mid-flight (after polls
        already drained earlier epochs): the ready queue decides execution
        order on device, but the observable retire order is the chain
        order."""
        pool = BufferPool()
        buf = pool.alloc((D,), np.float32, value=jnp.zeros(D, np.float32))
        other = pool.alloc((D,), np.float32, value=jnp.ones(D, np.float32))
        session = make_session("device", window_size=8, plan_mode="loop")
        retired_order = []
        session.add_retire_listener(lambda t: retired_order.append(t.tid))

        def chain_task(k):
            fn = _axpy if k % 2 == 0 else _mul
            ins, outs = (buf, other), (buf,)
            r, w = default_segments(ins, outs)
            return Task(opcode="axpy" if k % 2 == 0 else "mul", fn=fn,
                        inputs=ins, outputs=outs,
                        read_segments=r, write_segments=w)

        tasks = [chain_task(k) for k in range(18)]
        # admit in three slices with polls between: slice 2 arrives while
        # slice 1's epoch has already drained, slice 3 mid-session
        session.submit(tasks[:6])
        session.poll()
        session.submit(tasks[6:11])
        session.poll()
        session.submit(tasks[11:])
        session.close()
        assert retired_order == [t.tid for t in tasks]
        # serial equivalence of the final chain value (opcode names must
        # stay distinct per fn — executor jit caches key on opcode)
        pool2 = BufferPool()
        buf2 = pool2.alloc((D,), np.float32, value=jnp.zeros(D, np.float32))
        other2 = pool2.alloc((D,), np.float32, value=jnp.ones(D, np.float32))

        def ref_task(k):
            fn = _axpy if k % 2 == 0 else _mul
            ins, outs = (buf2, other2), (buf2,)
            r, w = default_segments(ins, outs)
            return Task(opcode="axpy" if k % 2 == 0 else "mul", fn=fn,
                        inputs=ins, outputs=outs,
                        read_segments=r, write_segments=w)

        run_serial([ref_task(k) for k in range(18)])
        np.testing.assert_array_equal(np.asarray(buf.value),
                                      np.asarray(buf2.value))


# ---------------------------------------------------------------------------
# QoS bit-identity (DESIGN §13): priority stamping buckets the window's
# READY index, so it may only reorder provably independent work — every
# session kind and batch policy must reproduce the serial snapshot of the
# SAME stream exactly when one tenant is stamped urgent and the other
# background. The serial reference is the unstamped mixed_tag ref: if
# priorities changed any value anywhere, these legs would diverge.
# ---------------------------------------------------------------------------

class TestQosMatrix:
    @staticmethod
    def _build_qos(seed=0):
        snap, tasks = _build_mixed_tag(seed)
        for t in tasks:  # tenantA urgent, tenantB background
            t.priority = 0 if t.stream_tag == "tenantA" else 2
        return snap, tasks

    @pytest.mark.parametrize("kind", SESSION_NAMES)
    def test_priority_stamped_feed_matches_serial(self, kind):
        ref = _ref("mixed_tag")
        snap, tasks = self._build_qos()
        session = make_session(kind, window_size=WINDOW)
        rng = np.random.RandomState(23)
        i = 0
        while i < len(tasks):
            k = 1 + rng.randint(6)
            session.submit(tasks[i: i + k])
            i += k
            if rng.rand() < 0.6:
                session.poll()
        report = session.close()
        np.testing.assert_array_equal(snap(), ref)
        assert report.window_stats["retired"] == len(tasks)

    @pytest.mark.parametrize("policy", SCHEDULER_NAMES)
    def test_priority_stamped_batch_matches_serial(self, policy):
        ref = _ref("mixed_tag")
        snap, tasks = self._build_qos()
        run = make_scheduler(policy, window_size=WINDOW)
        report = run(tasks)
        np.testing.assert_array_equal(snap(), ref)
        assert report.exec_stats["tasks_run"] == len(tasks)

    def test_priority_stamped_loop_mode_matches_serial(self):
        """plan_mode="loop" drains epochs in program order regardless of
        priority (§2-A3 correctness is priority-oblivious by design)."""
        ref = _ref("mixed_tag")
        snap, tasks = self._build_qos()
        session = make_session("device", window_size=WINDOW,
                               plan_mode="loop")
        session.submit(tasks)
        report = session.close()
        np.testing.assert_array_equal(snap(), ref)
        assert report.window_stats["retired"] == len(tasks)


# ---------------------------------------------------------------------------
# Factory validation: unknown names / plan modes fail loudly, naming the
# valid choices (both registries).
# ---------------------------------------------------------------------------

class TestFactoryValidation:
    def test_make_scheduler_unknown_name_lists_choices(self):
        with pytest.raises(ValueError) as ei:
            make_scheduler("warp-drive")
        for name in SCHEDULER_NAMES:
            assert name in str(ei.value)

    def test_make_session_unknown_name_lists_choices(self):
        with pytest.raises(ValueError) as ei:
            make_session("warp-drive")
        for name in SESSION_NAMES:
            assert name in str(ei.value)

    def test_make_scheduler_bad_plan_mode_lists_choices(self):
        with pytest.raises(ValueError) as ei:
            make_scheduler("device", plan_mode="bogus")
        for mode in PLAN_MODES:
            assert mode in str(ei.value)

    def test_make_session_bad_plan_mode_lists_choices(self):
        with pytest.raises(ValueError) as ei:
            make_session("device", plan_mode="bogus")
        for mode in PLAN_MODES:
            assert mode in str(ei.value)

    def test_device_session_ctor_rejects_bad_plan_mode(self):
        from repro.core import DeviceSession

        with pytest.raises(ValueError, match="plan_mode"):
            DeviceSession(plan_mode="bogus")

    def test_device_runner_ctor_rejects_bad_plan_mode(self):
        from repro.core import DeviceWindowRunner

        with pytest.raises(ValueError, match="plan_mode"):
            DeviceWindowRunner(plan_mode="bogus")

    @pytest.mark.parametrize("name", SESSION_NAMES)
    def test_every_registered_session_opens(self, name):
        session = make_session(name, window_size=4)
        assert not session.closed
        session.close()


# ---------------------------------------------------------------------------
# Row-lifecycle equivalence: device-session results must stay bit-identical
# to the serial baseline ACROSS a compaction epoch (rows move, cached plans
# invalidate, surviving device values gather in place).
# ---------------------------------------------------------------------------

class TestCompactionEpochEquivalence:
    def _universe(self, n=8):
        rng = np.random.RandomState(21)
        pool = BufferPool()
        bufs = [pool.alloc((D,), np.float32,
                           value=jnp.asarray(rng.randn(D).astype(np.float32)))
                for _ in range(n)]
        return pool, bufs

    def _phase_tasks(self, bufs, pairs):
        from repro.core.task import default_segments

        tasks = []
        for i, j in pairs:
            r, w = default_segments((bufs[i], bufs[j]), (bufs[j],))
            tasks.append(Task(opcode="axpy_c", fn=_axpy,
                              inputs=(bufs[i], bufs[j]), outputs=(bufs[j],),
                              read_segments=r, write_segments=w))
        return tasks

    def test_device_session_bit_identical_across_compaction(self):
        pairs1 = [(0, 1), (2, 3), (4, 5), (6, 7), (1, 2)]
        pairs2 = [(0, 1), (1, 0), (0, 1)]

        def run(mk_session):
            pool, bufs = self._universe()
            s = mk_session()
            s.submit(self._phase_tasks(bufs, pairs1))
            s.flush()
            # requests 2..7 "finish": their rows die, waste crosses 6/8
            for b in bufs[2:]:
                if hasattr(s, "release_buffer"):
                    s.release_buffer(b)
            # phase 2 recycles rows and (device) compacts before executing
            extra = [pool.alloc((D,), np.float32, value=jnp.full(D, 9.0 + k))
                     for k in range(3)]
            live = bufs[:2] + extra
            s.submit(self._phase_tasks(live, pairs2))
            s.submit(self._phase_tasks(live, [(2, 3), (3, 4), (4, 2)]))
            report = s.close()
            return np.stack([np.asarray(b.value) for b in live]), s

        ref, _ = run(lambda: make_session("serial"))
        got, dev = run(lambda: make_session(
            "device", window_size=16))
        np.testing.assert_array_equal(got, ref)
        assert dev.arena.compactions >= 1, "compaction epoch never happened"
        assert dev.session_stats()["plan_cache_invalidations"] >= 1


# ---------------------------------------------------------------------------
# Mesh-sharded window (DESIGN §12): the registry entry covers the default
# shard count above; here the shard axis is explicit — 1/2/4 logical
# shards must stay bit-identical to serial on every stream family (the
# admission plane may only move provably independent work between shards),
# the placement policy must obey its own RAW rule, and a subprocess leg
# forces REAL multiple host devices (XLA fixes the device count at first
# use, so it can't be varied in-process).
# ---------------------------------------------------------------------------

class TestMeshMatrix:
    @pytest.mark.parametrize("stream_name", sorted(STREAMS))
    @pytest.mark.parametrize("n_shards", [1, 2, 4])
    def test_interleaved_feed_matches_serial(self, n_shards, stream_name):
        from repro.core import MeshDeviceSession

        ref = _ref(stream_name)
        snap, tasks = STREAMS[stream_name]()
        session = MeshDeviceSession(window_size=WINDOW, n_shards=n_shards)
        rng = np.random.RandomState(13)
        i = 0
        while i < len(tasks):
            k = 1 + rng.randint(6)
            session.submit(tasks[i: i + k])
            i += k
            if rng.rand() < 0.6:
                session.poll()
        report = session.close()
        np.testing.assert_array_equal(snap(), ref)
        assert report.window_stats["retired"] == len(tasks)
        stats = session.session_stats()
        assert stats["plan_mode"] == "mesh"
        assert stats["n_shards"] == n_shards
        assert len(stats["per_shard"]) == n_shards
        if n_shards == 1:
            # one shard can never stage a cross-shard edge
            assert stats["cross_shard_edges"] == 0

    def test_placement_respects_same_epoch_raw_upstream(self):
        """Placement property: a task whose reads RAW-depend on a writer
        placed in the SAME admission epoch must land on one of those
        writers' shards — dependent chains never split across devices."""
        from repro.core import MeshDeviceSession
        from repro.core.scoreboard import IntervalScoreboard

        snap, tasks = STREAMS["mixed_tag"]()
        session = MeshDeviceSession(window_size=WINDOW, n_shards=4)
        checked = []
        orig = session._place_epoch

        def spy(order):
            shard_of = orig(order)
            sb = IntervalScoreboard()
            for t in order:
                raw = sb.probe_writers(t.read_segments)
                sb.insert(t.tid, t.read_segments, t.write_segments)
                same_epoch = [u for u in raw if u in shard_of and u != t.tid]
                if same_epoch:
                    checked.append((t.tid, shard_of[t.tid],
                                    {shard_of[u] for u in same_epoch}))
            return shard_of

        session._place_epoch = spy
        session.submit(tasks)
        session.close()
        assert checked, "stream produced no same-epoch RAW pairs"
        for tid, shard, upstream_shards in checked:
            assert shard in upstream_shards, (
                f"task {tid} placed on shard {shard}, RAW upstreams on "
                f"{sorted(upstream_shards)}")

    @pytest.mark.parametrize("n_dev", [2, 4])
    def test_forced_multi_device_matches_serial(self, n_dev, tmp_path):
        """Real per-device shards: a subprocess forces N host platform
        devices, runs the hazard-heavy stream through a mesh with one
        shard per device, and must reproduce the serial snapshot exactly."""
        import os
        import subprocess
        import sys as _sys

        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        script = f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import BufferPool, MeshDeviceSession, run_serial, TaskStream
from repro.core.wrapper import AcsKernel
from repro.kernels.ops import LOOP_BRANCHES

assert len(jax.devices()) == {n_dev}, jax.devices()
D = 4

def build(seed):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    bufs = [pool.alloc((D,), np.float32,
                       value=jnp.asarray(rng.randn(D).astype(np.float32)))
            for _ in range(6)]
    kern = {{"axpy": AcsKernel(name="axpy_fd", fn=LOOP_BRANCHES["axpy"]),
             "mul": AcsKernel(name="mul_fd", fn=LOOP_BRANCHES["mul"])}}
    stream = TaskStream()
    tasks = []
    for _ in range(24):
        k = kern["axpy" if rng.rand() < 0.5 else "mul"]
        ins = (bufs[rng.randint(6)], bufs[rng.randint(6)])
        outs = (bufs[rng.randint(6)],)
        tasks.append(k.launch(stream, inputs=ins, outputs=outs))
    return bufs, tasks

def chains(seed):
    # N independent 2-buffer chains + neighbour joins: guarantees
    # cross-shard edges once placement spreads the chains out.
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    ch = [[pool.alloc((D,), np.float32,
                      value=jnp.asarray(rng.randn(D).astype(np.float32)))
           for _ in range(2)] for _ in range({n_dev})]
    kern = {{"axpy": AcsKernel(name="axpy_fd", fn=LOOP_BRANCHES["axpy"]),
             "mul": AcsKernel(name="mul_fd", fn=LOOP_BRANCHES["mul"])}}
    stream = TaskStream()
    tasks = []
    for r in range(6):
        for c in range({n_dev}):
            a, b = ch[c]
            tasks.append(kern["axpy"].launch(stream, inputs=(a, b),
                                             outputs=(a,)))
            tasks.append(kern["mul"].launch(stream, inputs=(a, b),
                                            outputs=(b,)))
        if r % 2 == 1:
            for c in range({n_dev}):
                other = ch[(c + 1) % {n_dev}][0]
                a = ch[c][0]
                tasks.append(kern["axpy"].launch(stream, inputs=(other, a),
                                                 outputs=(a,)))
    return [b for pair in ch for b in pair], tasks

def run_mesh(build_fn, seed, **kw):
    bufs, tasks = build_fn(seed)
    sess = MeshDeviceSession(window_size=16, n_shards={n_dev}, **kw)
    sess.submit(tasks)
    sess.close()
    return (np.stack([np.asarray(b.value) for b in bufs]),
            sess.session_stats())

def mesh_transfer_syncs(stats):
    return sum(s.get("host_syncs_by_tag", {{}}).get("mesh-transfer", 0)
               for s in stats["per_shard"])

bufs, tasks = build(3)
run_serial(tasks)
ref = np.stack([np.asarray(b.value) for b in bufs])

got, stats = run_mesh(build, 3)
np.testing.assert_array_equal(got, ref)
assert stats["n_devices"] == {n_dev}, stats["n_devices"]
assert stats["n_shards"] == {n_dev}

# d2d differential on REAL separate devices: the chain stream forces
# cross-shard edges; forced d2d must stay bit-identical to serial and
# forced staged while moving every edge as a peer copy — zero
# mesh-transfer host syncs.
bufs, tasks = chains(7)
run_serial(tasks)
chain_ref = np.stack([np.asarray(b.value) for b in bufs])
staged_got, staged = run_mesh(chains, 7, transfer_mode="staged")
d2d_got, d2d = run_mesh(chains, 7, transfer_mode="d2d")
np.testing.assert_array_equal(staged_got, chain_ref)
np.testing.assert_array_equal(d2d_got, chain_ref)
assert d2d["transfer_mode"] == "d2d", d2d["transfer_mode"]
assert d2d["cross_shard_edges"] > 0
assert d2d["d2d_moves"] > 0 and d2d["staged_moves"] == 0, (
    d2d["d2d_moves"], d2d["staged_moves"], d2d["d2d_fallbacks"])
assert mesh_transfer_syncs(d2d) == 0, mesh_transfer_syncs(d2d)
assert mesh_transfer_syncs(staged) > 0
assert d2d["transfers"]["bytes"] == staged["transfers"]["bytes"]
# the auto probe must also discover p2p on forced host devices
auto_got, auto = run_mesh(chains, 7)
np.testing.assert_array_equal(auto_got, chain_ref)
assert auto["transfer_mode"] == "d2d", auto["transfer_mode"]
assert auto["drain_overlap"] >= 2, auto["drain_overlap"]

print("MESH_FORCED_OK", stats["cross_shard_edges"],
      stats["sub_epoch_barriers"], d2d["d2d_moves"])
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev}")
        env["PYTHONPATH"] = os.path.join(repo, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        proc = subprocess.run([_sys.executable, "-c", script], cwd=repo,
                              env=env, capture_output=True, text=True,
                              timeout=150)
        assert proc.returncode == 0, (proc.stdout + proc.stderr)[-2000:]
        assert "MESH_FORCED_OK" in proc.stdout
