"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward + one train-grad step + prefill/decode, asserting shapes and
finiteness — required by the assignment for each of the 10 archs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    pad_vocab,
    prefill,
)
from repro.models.transformer import FRONTEND_DIMS

B, S = 2, 16
ALL = sorted(ARCHS)


def make_inputs(cfg, rng, s=S):
    if cfg.frontend:
        return jnp.asarray(
            rng.randn(B, s, FRONTEND_DIMS[cfg.frontend]).astype(np.float32)
        )
    return jnp.asarray(rng.randint(0, cfg.vocab, (B, s)), jnp.int32)


@pytest.fixture(scope="module")
def smoke(request):
    cache = {}

    def get(name):
        if name not in cache:
            cfg = ARCHS[name].reduced()
            params = init_params(cfg, jax.random.PRNGKey(0), tp_size=1)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(smoke, name):
    cfg, params = smoke(name)
    rng = np.random.RandomState(0)
    logits = forward(params, cfg, make_inputs(cfg, rng))
    assert logits.shape == (B, S, pad_vocab(cfg.vocab))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL)
def test_train_grad_step(smoke, name):
    cfg, params = smoke(name)
    rng = np.random.RandomState(1)
    inputs = make_inputs(cfg, rng)
    labels = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, inputs, labels))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("name", ALL)
def test_prefill_then_decode(smoke, name):
    cfg, params = smoke(name)
    rng = np.random.RandomState(2)
    prompt = make_inputs(cfg, rng, s=8)
    cache = init_cache(cfg, B, max_len=32)
    logits, cache = prefill(params, cfg, prompt, cache)
    assert logits.shape == (B, 1, pad_vocab(cfg.vocab))
    tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1)[:, None]
    if cfg.frontend:
        tok = jnp.asarray(rng.randn(B, 1, FRONTEND_DIMS[cfg.frontend]), jnp.float32)
    logits2, cache = decode_step(params, cfg, tok, cache, jnp.asarray(8, jnp.int32))
    assert logits2.shape == (B, 1, pad_vocab(cfg.vocab))
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward logits
    (KV-cache correctness) for a dense GQA arch."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(1), tp_size=1)
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 6)), jnp.int32)
    full = forward(params, cfg, toks, remat=False)

    cache = init_cache(cfg, 1, max_len=16)
    logits, cache = prefill(params, cfg, toks[:, :3], cache)
    np.testing.assert_allclose(
        np.asarray(logits[0, 0]), np.asarray(full[0, 2]), rtol=2e-3, atol=2e-3
    )
    for i in range(3, 6):
        step_logits, cache = decode_step(
            params, cfg, toks[:, i : i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(step_logits[0, 0]), np.asarray(full[0, i]),
            rtol=2e-3, atol=2e-3,
        )


def test_decode_matches_forward_recurrent():
    """Same check through the RG-LRU/Mamba state-cache path."""
    for arch in ("recurrentgemma-2b", "falcon-mamba-7b"):
        cfg = ARCHS[arch].reduced()
        params = init_params(cfg, jax.random.PRNGKey(2), tp_size=1)
        rng = np.random.RandomState(4)
        toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 6)), jnp.int32)
        full = forward(params, cfg, toks, remat=False)
        cache = init_cache(cfg, 1, max_len=16)
        logits, cache = prefill(params, cfg, toks[:, :3], cache)
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full[0, 2]),
            rtol=2e-3, atol=2e-3, err_msg=arch,
        )
        for i in range(3, 6):
            step_logits, cache = decode_step(
                params, cfg, toks[:, i : i + 1], cache, jnp.asarray(i, jnp.int32)
            )
            np.testing.assert_allclose(
                np.asarray(step_logits[0, 0]), np.asarray(full[0, i]),
                rtol=2e-3, atol=2e-3, err_msg=f"{arch} step {i}",
            )


def test_moe_routing_is_input_dependent():
    """Different tokens route to different experts (the ACS connection)."""
    cfg = ARCHS["granite-moe-3b-a800m"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(3), tp_size=1)
    rng = np.random.RandomState(5)
    a = forward(params, cfg, make_inputs(cfg, rng))
    b = forward(params, cfg, make_inputs(cfg, rng))
    assert not np.allclose(np.asarray(a), np.asarray(b))
