"""Stateful session fuzz: random interleavings of ``submit`` / ``poll`` /
``drive`` / ``flush`` / ``close`` against every session kind must

* preserve serial-order results (final buffer contents == ``run_serial``
  over exactly the submitted prefix, in submission order);
* never deadlock (``flush``/``close`` terminate — the per-test timeout is
  the tripwire when `pytest-timeout` is installed);
* keep ``drained()`` / ``idle()`` / ``backlog`` consistent with the window
  invariants at every step: an open session is never ``drained()``, a
  flushed session is idle with zero outstanding, a closed session is
  drained and refuses further input.

Runs through the ``tests/_prophelper.py`` shim: real hypothesis when
installed, the seeded-random driver otherwise — either way the action
scripts are deterministic per test name.
"""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax.numpy as jnp

from repro.core import BufferPool, SESSION_NAMES, Task, make_session, run_serial
from repro.core.task import default_segments
from repro.kernels.ops import LOOP_BRANCHES

D = 4
N_TASKS = 24
N_BUFFERS = 5

SUBMIT, POLL, DRIVE, FLUSH, CLOSE = range(5)
# Submission-biased action mix; CLOSE appears once per script at most
# (subsequent CLOSE draws assert the double-close error path).
ACTION_WEIGHTS = (SUBMIT, SUBMIT, SUBMIT, POLL, DRIVE, FLUSH, CLOSE)

# The shared ready-queue switch-branch fns (kernels/ops.py): identity with
# the registry's switch table keeps the device_loop kind eligible for the
# Pallas fast path's lowering checks.
OPS = {"axpy": LOOP_BRANCHES["axpy"], "mul": LOOP_BRANCHES["mul"]}

# Session kinds under fuzz: every registry name, plus the device session
# re-planned through the ready-queue epoch executor (a plan-mode axis on
# "device", not a registry name).
FUZZ_KINDS = tuple(SESSION_NAMES) + ("device_loop",)


def _make_fuzz_session(kind, window_size=4):
    if kind == "device_loop":
        return make_session("device", window_size=window_size,
                            plan_mode="loop")
    return make_session(kind, window_size=window_size)


def build_stream(seed):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    bufs = [
        pool.alloc((D,), np.float32,
                   value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(N_BUFFERS)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(N_TASKS):
        op = names[rng.randint(len(names))]
        ins = (bufs[rng.randint(N_BUFFERS)], bufs[rng.randint(N_BUFFERS)])
        outs = (bufs[rng.randint(N_BUFFERS)],)
        r, w = default_segments(ins, outs)
        tasks.append(Task(opcode=op, fn=OPS[op], inputs=ins, outputs=outs,
                          read_segments=r, write_segments=w))
    return bufs, tasks


def _final(bufs):
    return np.stack([np.asarray(b.value) for b in bufs])


def _check_open_invariants(session):
    """Window invariants that must hold at EVERY step while input is open.
    Taken under the session lock so threaded workers can't race the
    reads."""
    with session._lock:
        assert not session.window.drained()  # open input => never drained
        backlog = session.window.backlog()
        assert backlog == session.backlog()
        assert session.window.idle() == (backlog == 0)
        # submitted - retired must equal FIFO + resident: retirement and
        # window removal are one atomic step in every session kind
        assert session.outstanding == backlog


def _run_script(kind, seed, script):
    bufs, tasks = build_stream(seed)
    session = _make_fuzz_session(kind)
    cursor = 0
    report = None
    for code, arg in script:
        action = ACTION_WEIGHTS[code]
        if session.closed:
            if action is SUBMIT and cursor < len(tasks):
                with pytest.raises(RuntimeError):
                    session.submit(tasks[cursor])
            elif action is CLOSE:
                with pytest.raises(RuntimeError):
                    session.close()
            # poll/drive/flush after close are harmless no-ops
            elif action is POLL:
                session.poll()  # may drain retirees from the closing flush
                assert session.poll() == []  # ...but only once
            elif action is FLUSH:
                session.flush()
            continue
        if action is SUBMIT:
            chunk = tasks[cursor: cursor + arg]
            if not chunk:
                continue
            depth = session.submit(chunk)
            cursor += len(chunk)
            assert depth >= 1  # the just-submitted work is outstanding
        elif action is POLL:
            session.poll()
        elif action is DRIVE:
            session.drive()
        elif action is FLUSH:
            session.flush()
            with session._lock:
                assert session.outstanding == 0
                assert session.window.idle()
        else:  # CLOSE
            report = session.close()
        if not session.closed:
            _check_open_invariants(session)

    if not session.closed:
        report = session.close()
    # closed and complete: drained, nothing outstanding, loud re-close
    assert session.window.drained()
    assert session.outstanding == 0
    with pytest.raises(RuntimeError):
        session.close()
    assert report.window_stats["retired"] == cursor
    assert sum(len(w) for w in report.waves) == cursor

    # serial-order equivalence over exactly the submitted prefix
    ref_bufs, ref_tasks = build_stream(seed)
    run_serial(ref_tasks[:cursor])
    np.testing.assert_array_equal(_final(bufs), _final(ref_bufs))


class TestSessionFuzz:
    @pytest.mark.parametrize("kind", FUZZ_KINDS)
    def test_random_interleavings(self, kind):
        # parametrize composes with the property via an inner closure: the
        # _prophelper shim (and hypothesis) fill ONLY the drawn arguments,
        # so the pytest param never collides with a strategy slot.
        @given(st.integers(0, 10_000),
               st.lists(st.tuples(st.integers(0, len(ACTION_WEIGHTS) - 1),
                                  st.integers(1, 5)),
                        min_size=1, max_size=30))
        @settings(max_examples=8, deadline=None)
        def prop(seed, script):
            _run_script(kind, seed, script)

        prop()

    @pytest.mark.parametrize("kind", FUZZ_KINDS)
    def test_callbacks_fire_once_under_interleaving(self, kind):
        """Retirement observation stays exact under chunked feeding: every
        submitted task's callback fires exactly once, and per-tag counts
        cover the stream."""
        bufs, tasks = build_stream(3)
        for t in tasks:
            t.stream_tag = "fuzz"
        session = _make_fuzz_session(kind)
        seen = []
        i = 0
        rng = np.random.RandomState(11)
        while i < len(tasks):
            k = 1 + rng.randint(4)
            session.submit(tasks[i: i + k],
                           on_retire=lambda t: seen.append(t.tid))
            i += k
            if rng.rand() < 0.5:
                session.poll()
        session.close()
        assert sorted(seen) == sorted(t.tid for t in tasks)
        assert session.retired_by_tag == {"fuzz": len(tasks)}
