"""ACS-HW analogue: device-resident window interpreter (DESIGN.md §2 A3).

Equivalence with the serial baseline + the single-dispatch property that is
the whole point of moving the window onto the device.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BufferPool,
    DeviceOpRegistry,
    DeviceWindowRunner,
    Task,
    plan_waves,
    run_serial,
)
from repro.core.task import default_segments

D = 8


def _axpy(x, y, z):
    return 1.5 * x + y + 1.0


def _mul(x, y, z):
    return x * y - 0.5


OPS = {"axpy": _axpy, "mul": _mul}


def build(seed, n_tasks, n_buffers):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        ins = (buffers[rng.randint(n_buffers)], buffers[rng.randint(n_buffers)])
        outs = (buffers[rng.randint(n_buffers)],)
        r, w = default_segments(ins, outs)
        # device interpreter fns take (x, y, z); serial fn must match arity 2
        fn2 = (lambda f: lambda x, y: f(x, y, None))(OPS[op])
        tasks.append(
            Task(opcode=op, fn=fn2, inputs=ins, outputs=outs, read_segments=r, write_segments=w)
        )
    return pool, buffers, tasks


@pytest.fixture(scope="module")
def registry():
    reg = DeviceOpRegistry()
    for name, fn in OPS.items():
        reg.register(name, fn)
    return reg


class TestDeviceWindowRunner:
    @pytest.mark.parametrize("seed", [0, 1, 5])
    def test_matches_serial(self, registry, seed):
        _, ref_bufs, ref_tasks = build(seed, 30, 6)
        run_serial(ref_tasks)
        ref = np.stack([np.asarray(b.value) for b in ref_bufs])

        _, dev_bufs, dev_tasks = build(seed, 30, 6)
        runner = DeviceWindowRunner(registry, window_size=16)
        report = runner.execute(dev_tasks, dev_bufs)
        got = np.stack([np.asarray(b.value) for b in dev_bufs])

        np.testing.assert_allclose(got, ref, rtol=1e-5)
        assert report.exec_stats["dispatches"] == 1  # whole stream, one launch

    def test_single_dispatch_vs_serial_dispatch_count(self, registry):
        _, bufs, tasks = build(2, 50, 8)
        runner = DeviceWindowRunner(registry, window_size=32)
        report = runner.execute(tasks, bufs)
        assert report.exec_stats["dispatches"] == 1
        assert report.exec_stats["tasks_run"] == 50

    def test_compiled_plan_reused_across_inputs(self, registry):
        """Same wave-plan shape across different inputs => no recompilation:
        the CUDA-Graph-without-reconstruction property (A2)."""
        runner = DeviceWindowRunner(registry, window_size=16)
        for seed in (0, 1):  # same seed-structure -> same plan shape
            _, bufs, tasks = build(0, 30, 6)
            runner.execute(tasks, bufs)
        assert len(runner._compiled) == 1


class TestPlanWaves:
    def test_plan_respects_dependencies(self, registry):
        _, bufs, tasks = build(3, 24, 5)
        waves = plan_waves(tasks, window_size=16)
        seen = set()
        pos = {}
        for wi, wave in enumerate(waves):
            for t in wave:
                pos[t.tid] = wi
        # every task appears exactly once
        flat = [t.tid for w in waves for t in w]
        assert sorted(flat) == sorted(t.tid for t in tasks)
        # dependencies (recomputed all-pairs) must map to strictly earlier waves
        from repro.core import depends_on

        for j, newer in enumerate(tasks):
            for older in tasks[:j]:
                if depends_on(
                    newer.read_segments, newer.write_segments,
                    older.read_segments, older.write_segments,
                ):
                    assert pos[older.tid] < pos[newer.tid]
