"""ACS-HW analogue: device-resident window interpreter (DESIGN.md §2 A3).

Equivalence with the serial baseline + the single-dispatch property that is
the whole point of moving the window onto the device. The arena path
(mixed shape classes, real workloads) is covered in test_arena.py; this
module keeps the toy universe honest — including the legacy uniform-slab
interpreter and its (now loud) arity limit.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BufferPool,
    DeviceOpRegistry,
    DeviceWindowRunner,
    Task,
    plan_frontier,
    plan_waves,
    run_serial,
)
from repro.core.device_dispatch import MAX_ARITY, compile_wave_plan
from repro.core.task import default_segments

D = 8


def _axpy(x, y, z):
    return 1.5 * x + y + 1.0


def _mul(x, y, z):
    return x * y - 0.5


OPS = {"axpy": _axpy, "mul": _mul}


def build(seed, n_tasks, n_buffers):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        ins = (buffers[rng.randint(n_buffers)], buffers[rng.randint(n_buffers)])
        outs = (buffers[rng.randint(n_buffers)],)
        r, w = default_segments(ins, outs)
        # legacy interpreter fns take (x, y, z); serial fn must match arity 2
        fn2 = (lambda f: lambda x, y: f(x, y, None))(OPS[op])
        tasks.append(
            Task(opcode=op, fn=fn2, inputs=ins, outputs=outs, read_segments=r, write_segments=w)
        )
    return pool, buffers, tasks


@pytest.fixture(scope="module")
def registry():
    reg = DeviceOpRegistry()
    for name, fn in OPS.items():
        reg.register(name, fn)
    return reg


class TestDeviceWindowRunner:
    @pytest.mark.parametrize("seed", [0, 1, 5])
    def test_matches_serial(self, registry, seed):
        _, ref_bufs, ref_tasks = build(seed, 30, 6)
        run_serial(ref_tasks)
        ref = np.stack([np.asarray(b.value) for b in ref_bufs])

        _, dev_bufs, dev_tasks = build(seed, 30, 6)
        runner = DeviceWindowRunner(registry, window_size=16)
        report = runner.execute(dev_tasks, dev_bufs)
        got = np.stack([np.asarray(b.value) for b in dev_bufs])

        np.testing.assert_array_equal(got, ref)
        assert report.exec_stats["dispatches"] == 1  # whole stream, one launch

    def test_single_dispatch_vs_serial_dispatch_count(self, registry):
        _, bufs, tasks = build(2, 50, 8)
        runner = DeviceWindowRunner(registry, window_size=32)
        report = runner.execute(tasks, bufs)
        assert report.exec_stats["dispatches"] == 1
        assert report.exec_stats["tasks_run"] == 50

    def test_compiled_plan_reused_across_inputs(self, registry):
        """Same lowered-program structure across different inputs => no
        recompilation: the CUDA-Graph-without-reconstruction property (A2)."""
        runner = DeviceWindowRunner(registry, window_size=16)
        for shift in (0.0, 1.0):  # same stream structure, different values
            _, bufs, tasks = build(0, 30, 6)
            for b in bufs:
                b.value = b.value + shift
            runner.execute(tasks, bufs)
        assert len(runner._compiled) == 1

    def test_window_stats_come_from_planning_pass(self, registry):
        """The report's window stats are the planning window's real
        counters, not a fresh all-zero container (seed bug)."""
        _, bufs, tasks = build(4, 25, 5)
        report = DeviceWindowRunner(registry, window_size=8).execute(tasks, bufs)
        assert report.window_stats["inserted"] == 25
        assert report.window_stats["retired"] == 25
        assert report.window_stats["dep_checks"] > 0
        assert 1 <= report.window_stats["max_resident"] <= 8

    def test_strict_registry_rejects_unknown_opcode(self):
        reg = DeviceOpRegistry()  # strict, nothing registered
        _, bufs, tasks = build(0, 5, 3)
        with pytest.raises(KeyError, match="not in the device registry"):
            DeviceWindowRunner(reg).execute(tasks, bufs)

    def test_auto_registry_accepts_any_opcode(self):
        _, ref_bufs, ref_tasks = build(6, 20, 5)
        run_serial(ref_tasks)
        ref = np.stack([np.asarray(b.value) for b in ref_bufs])
        _, bufs, tasks = build(6, 20, 5)
        runner = DeviceWindowRunner()  # no registry -> auto-registering
        runner.execute(tasks, bufs)
        got = np.stack([np.asarray(b.value) for b in bufs])
        np.testing.assert_array_equal(got, ref)
        assert "axpy" in runner.registry and "mul" in runner.registry


class TestLegacyUniformPath:
    @pytest.mark.parametrize("seed", [0, 2])
    def test_execute_uniform_matches_serial(self, registry, seed):
        _, ref_bufs, ref_tasks = build(seed, 30, 6)
        run_serial(ref_tasks)
        ref = np.stack([np.asarray(b.value) for b in ref_bufs])

        _, dev_bufs, dev_tasks = build(seed, 30, 6)
        runner = DeviceWindowRunner(registry, window_size=16)
        report = runner.execute_uniform(dev_tasks, dev_bufs)
        got = np.stack([np.asarray(b.value) for b in dev_bufs])

        np.testing.assert_allclose(got, ref, rtol=1e-5)
        assert report.exec_stats["dispatches"] == 1

    def test_over_arity_task_raises(self, registry):
        """Seed bug: the legacy tables silently truncated operand lists at
        MAX_ARITY; now they refuse loudly (the arena path has no limit)."""
        pool = BufferPool()
        bufs = [pool.alloc((D,), np.float32, value=jnp.ones(D)) for _ in range(5)]
        ins = tuple(bufs[:MAX_ARITY + 1])
        outs = (bufs[4],)
        r, w = default_segments(ins, outs)
        task = Task(opcode="axpy", fn=lambda *a: sum(a), inputs=ins,
                    outputs=outs, read_segments=r, write_segments=w)
        with pytest.raises(ValueError, match="legacy uniform-slab path"):
            compile_wave_plan([[task]], registry,
                              {b.name: i for i, b in enumerate(bufs)}, len(bufs))

    def test_multi_output_task_raises(self, registry):
        """The legacy tables hold one out-row per slot; multi-output tasks
        must refuse loudly instead of dropping outputs[1:]."""
        pool = BufferPool()
        bufs = [pool.alloc((D,), np.float32, value=jnp.ones(D)) for _ in range(4)]
        ins = (bufs[0], bufs[1])
        outs = (bufs[2], bufs[3])
        r, w = default_segments(ins, outs)
        task = Task(opcode="axpy", fn=lambda x, y: (x + y, x - y), inputs=ins,
                    outputs=outs, read_segments=r, write_segments=w)
        with pytest.raises(ValueError, match="exactly one"):
            compile_wave_plan([[task]], registry,
                              {b.name: i for i, b in enumerate(bufs)}, len(bufs))

    def test_fnless_registration_blocks_branches(self):
        reg = DeviceOpRegistry()
        reg.register("real_kernel")  # fn-less: arena-only opcode
        with pytest.raises(ValueError, match="legacy uniform path"):
            _ = reg.branches


class TestPlanModes:
    def test_plan_respects_dependencies(self, registry):
        _, bufs, tasks = build(3, 24, 5)
        waves = plan_waves(tasks, window_size=16)
        pos = {}
        for wi, wave in enumerate(waves):
            for t in wave:
                pos[t.tid] = wi
        # every task appears exactly once
        flat = [t.tid for w in waves for t in w]
        assert sorted(flat) == sorted(t.tid for t in tasks)
        # dependencies (recomputed all-pairs) must map to strictly earlier waves
        from repro.core import depends_on

        for j, newer in enumerate(tasks):
            for older in tasks[:j]:
                if depends_on(
                    newer.read_segments, newer.write_segments,
                    older.read_segments, older.write_segments,
                ):
                    assert pos[older.tid] < pos[newer.tid]

    def test_plan_frontier_respects_dependencies(self, registry):
        _, bufs, tasks = build(3, 24, 5)
        groups = plan_frontier(tasks, window_size=16)
        pos = {}
        for gi, group in enumerate(groups):
            for t in group:
                pos[t.tid] = gi
        flat = [t.tid for g in groups for t in g]
        assert sorted(flat) == sorted(t.tid for t in tasks)
        from repro.core import depends_on

        for j, newer in enumerate(tasks):
            for older in tasks[:j]:
                if depends_on(
                    newer.read_segments, newer.write_segments,
                    older.read_segments, older.write_segments,
                ):
                    assert pos[older.tid] < pos[newer.tid]

    def test_return_window_exposes_planning_stats(self):
        _, _, tasks = build(1, 20, 5)
        waves, window = plan_waves(tasks, window_size=8, return_window=True)
        assert window.stats.inserted == 20
        assert window.stats.retired == 20
        assert sum(len(w) for w in waves) == 20
