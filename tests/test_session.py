"""Live-fed scheduler sessions (DESIGN.md §10): interleaved submit/poll
must be observationally equivalent to the serial baseline for every
session policy; the window's open/drain semantics must distinguish "empty
but session open" from "closed and complete"; and window size 1 must
degenerate to serial even under live feeding.

Streams are generated like test_scheduler_equivalence: random reads/writes
over a shared pool with non-commutative arithmetic, so any illegal reorder
changes the result.
"""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax.numpy as jnp

from repro.core import (
    BufferPool,
    SESSION_NAMES,
    SchedulingWindow,
    Task,
    TaskStream,
    make_session,
    run_serial,
)
from repro.core.task import default_segments
from repro.core.wrapper import AcsKernel

D = 4


def _axpy(x, y):
    return 1.5 * x + y + 1.0


def _mul(x, y):
    return x * y - 0.5


def _neg(x, y):
    return -x + 0.25 * y


OPS = {"axpy": _axpy, "mul": _mul, "neg": _neg}


def build_stream(seed: int, n_tasks: int, n_buffers: int):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        i0, i1 = rng.randint(n_buffers), rng.randint(n_buffers)
        o = rng.randint(n_buffers)
        ins = (buffers[i0], buffers[i1])
        outs = (buffers[o],)
        r, w = default_segments(ins, outs)
        tasks.append(
            Task(opcode=op, fn=OPS[op], inputs=ins, outputs=outs,
                 read_segments=r, write_segments=w)
        )
    return pool, buffers, tasks


def final_values(buffers):
    return np.stack([np.asarray(b.value) for b in buffers])


def serial_ref(seed, n_tasks=30, n_buffers=6):
    _, buffers, tasks = build_stream(seed, n_tasks, n_buffers)
    run_serial(tasks)
    return final_values(buffers)


def feed_interleaved(session, tasks, seed, poll_prob=0.7):
    """Submit in random-sized chunks with polls in between — the live-FIFO
    pattern of paper §III-D."""
    rng = np.random.RandomState(seed)
    i = 0
    while i < len(tasks):
        k = 1 + rng.randint(5)
        session.submit(tasks[i : i + k])
        i += k
        if rng.rand() < poll_prob:
            session.poll()
    return session.close()


class TestInterleavedEquivalence:
    @pytest.mark.parametrize("kind", SESSION_NAMES)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_serial(self, kind, seed):
        ref = serial_ref(seed)
        _, buffers, tasks = build_stream(seed, 30, 6)
        report = feed_interleaved(make_session(kind, window_size=8), tasks, seed)
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)
        assert report.window_stats["retired"] == 30
        assert sum(len(w) for w in report.waves) == 30

    @given(st.integers(0, 10_000), st.integers(1, 17))
    @settings(max_examples=10, deadline=None)
    def test_property_any_seed_any_window(self, seed, window):
        ref = serial_ref(seed, n_tasks=18, n_buffers=5)
        _, buffers, tasks = build_stream(seed, 18, 5)
        feed_interleaved(make_session("wave", window_size=window), tasks, seed)
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)

    def test_window_one_live_feed_degenerates_to_serial(self):
        ref = serial_ref(3)
        _, buffers, tasks = build_stream(3, 30, 6)
        report = feed_interleaved(make_session("wave", window_size=1), tasks, 3)
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)
        assert all(len(w) == 1 for w in report.waves)
        assert [w[0] for w in report.waves] == [t.tid for t in tasks]  # program order

    def test_threaded_idle_workers_wake_on_late_submission(self):
        """Workers parked on the condition variable (no spin) must pick up
        work submitted long after the window went idle."""
        ref = serial_ref(5)
        _, buffers, tasks = build_stream(5, 30, 6)
        s = make_session("threaded", window_size=8, num_streams=3)
        s.submit(tasks[:10])
        s.flush()  # window idles; workers park
        assert s.outstanding == 0 and not s.window.drained()
        s.submit(tasks[10:])
        report = s.close()
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)
        assert report.exec_stats["dispatches"] == 30

    def test_frontier_executor_rejects_second_live_session(self):
        """One live session per executor: opening a session over a ledger
        holding another session's in-flight groups must fail loudly, not
        steal (and mis-retire) those groups."""
        from repro.core import FrontierSession, GroupExecutor

        ex = GroupExecutor()
        pool = BufferPool()
        a = pool.alloc((D,), np.float32, value=jnp.ones(D))
        b = pool.alloc((D,), np.float32, value=jnp.zeros(D))
        r, w = default_segments((a, a), (b,))
        task = Task(opcode="axpy", fn=_axpy, inputs=(a, a), outputs=(b,),
                    read_segments=r, write_segments=w)
        ex.launch([task])  # group now on the in-flight ledger
        with pytest.raises(RuntimeError):
            FrontierSession(executor=ex)
        ex.sync_oldest()  # drained ledger: a new session may open
        FrontierSession(executor=ex)

    def test_frontier_inflight_survives_submissions(self):
        """Groups launched before a submission retire normally after it —
        the executor's in-flight ledger is session-lifetime state."""
        ref = serial_ref(7)
        _, buffers, tasks = build_stream(7, 30, 6)
        s = make_session("frontier", window_size=8, max_inflight=4)
        s.submit(tasks[:12])
        s.poll()  # stages groups
        s.poll()  # launches: groups now in flight
        s.submit(tasks[12:])  # feed while in flight
        report = s.close()
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)
        assert sum(len(g.tids) for g in report.groups) == 30


class TestDrainedVsClosed:
    def test_open_empty_is_idle_not_drained(self):
        w = SchedulingWindow(4)
        assert w.drained()  # batch default: input closed from birth
        w.open_input()
        assert w.idle() and not w.drained()
        w.close_input()
        assert w.drained()

    def test_live_window_with_work_is_neither(self):
        _, _, tasks = build_stream(0, 3, 3)
        w = SchedulingWindow(4)
        w.open_input()
        w.submit(tasks[0])
        assert not w.idle() and not w.drained()
        t = w.ready_tasks()[0]
        w.mark_executing(t)
        w.retire(t)
        assert w.idle() and not w.drained()
        w.close_input()
        assert w.drained()

    def test_submit_after_close_raises(self):
        _, _, tasks = build_stream(0, 2, 2)
        s = make_session("wave", window_size=4)
        s.submit(tasks[0])
        s.close()
        with pytest.raises(RuntimeError):
            s.submit(tasks[1])
        with pytest.raises(RuntimeError):
            s.close()  # double close


class TestRetirementObservation:
    def test_callbacks_fire_once_per_task_in_retire_order(self):
        _, _, tasks = build_stream(2, 12, 4)
        s = make_session("serial")
        seen = []
        s.submit(tasks, on_retire=lambda t: seen.append(t.tid))
        s.close()
        assert seen == [t.tid for t in tasks]  # serial: program order, once each

    def test_ticket_and_late_callback(self):
        _, _, tasks = build_stream(2, 4, 3)
        s = make_session("wave", window_size=4)
        s.submit(tasks)
        tk = s.ticket(tasks[0])
        assert not tk.done()
        s.flush()
        assert tk.done()
        late = []
        s.on_task_retired(tasks[1], lambda t: late.append(t.tid))  # already retired
        assert late == [tasks[1].tid]
        s.close()

    def test_submit_reports_backlog_depth(self):
        pool = BufferPool()
        ins = [pool.alloc((D,), np.float32, value=jnp.ones(D)) for _ in range(5)]
        outs = [pool.alloc((D,), np.float32, value=jnp.zeros(D)) for _ in range(5)]
        tasks = []
        for i in range(5):
            r, w = default_segments((ins[i], ins[i]), (outs[i],))
            tasks.append(Task(opcode="axpy", fn=_axpy, inputs=(ins[i], ins[i]),
                              outputs=(outs[i],), read_segments=r, write_segments=w))
        s = make_session("wave", window_size=2)
        depth = s.submit(tasks)  # 2 resident + 3 queued in the input FIFO
        assert depth == 5
        assert s.backlog() == 5
        assert s.window.fifo_depth() == 3
        s.close()


class TestLiveTaskStream:
    def test_sink_feeds_session_and_tags_tasks(self):
        """AcsKernel.launch into a sink-ed stream lands in the live window
        immediately — the wrapper-to-window path of Fig 16/17, open-loop."""
        s = make_session("wave", window_size=4)
        pool = BufferPool()
        a = pool.alloc((D,), np.float32, value=jnp.ones(D))
        b = pool.alloc((D,), np.float32, value=jnp.zeros(D))
        stream = TaskStream(sink=s, tag="tenant0")
        kern = AcsKernel(name="axpy_live_test", fn=_axpy)
        task = kern.launch(stream, inputs=(a, a), outputs=(b,))
        assert s.backlog() == 1  # submitted by push, no explicit submit call
        assert task.stream_tag == "tenant0"
        s.close()
        assert s.retired_by_tag == {"tenant0": 1}
        np.testing.assert_allclose(np.asarray(b.value), 1.5 + 1.0 + 1.0)

    def test_bad_sink_rejected(self):
        with pytest.raises(TypeError):
            TaskStream(sink=object())


class TestDeviceSessionObservation:
    """The persistent device window keeps values device-resident between
    epochs; retirement observers must still see host-fresh values."""

    def _one_task(self):
        pool = BufferPool()
        x = pool.alloc((D,), np.float32, value=jnp.ones(D))
        y = pool.alloc((D,), np.float32, value=jnp.zeros(D))
        r, w = default_segments((x, x), (y,))
        task = Task(opcode="axpy", fn=_axpy, inputs=(x, x), outputs=(y,),
                    read_segments=r, write_segments=w)
        return y, task

    def test_ticket_holder_observes_fresh_value_at_poll(self):
        """Regression: a ticketed task is a retirement observer — its
        output must be synced back before the ticket fires, exactly like
        callback watchers."""
        y, task = self._one_task()
        s = make_session("device", window_size=4)
        s.submit(task)
        tk = s.ticket(task)
        s.poll()
        assert tk.done()
        np.testing.assert_allclose(np.asarray(y.value), 1.5 + 1.0 + 1.0)
        s.close()

    def test_late_observers_also_see_fresh_values(self):
        """Regression: observers registered AFTER an unwatched epoch
        retired the task (the fire-immediately paths) must sync first —
        a late callback or ticket reads the same values an early one
        would."""
        y, task = self._one_task()
        s = make_session("device", window_size=4)
        s.submit(task)
        s.poll()  # unwatched epoch: sync deferred
        seen = []
        s.on_task_retired(task, lambda t: seen.append(np.asarray(y.value).copy()))
        assert len(seen) == 1
        np.testing.assert_allclose(seen[0], 1.5 + 1.0 + 1.0)
        tk = s.ticket(task)
        assert tk.done()
        np.testing.assert_allclose(np.asarray(y.value), 1.5 + 1.0 + 1.0)
        s.close()

    def test_unwatched_values_require_sync(self):
        """Documented contract: without an observer, an epoch defers the
        host sync; ``sync()`` (or flush/close) makes direct reads safe."""
        y, task = self._one_task()
        s = make_session("device", window_size=4)
        s.submit(task)
        s.poll()
        assert s.session_stats()["host_syncs"] == 0  # deferred
        s.sync()
        assert s.session_stats()["host_syncs"] == 1
        np.testing.assert_allclose(np.asarray(y.value), 1.5 + 1.0 + 1.0)
        s.close()

    def test_runner_session_shares_registry(self):
        """DeviceWindowRunner.session() mirrors the other schedulers'
        session() handles: same opcode registry, fresh per-session arena,
        serial-equivalent results."""
        from repro.core import DeviceWindowRunner

        ref = serial_ref(4)
        _, buffers, tasks = build_stream(4, 30, 6)
        runner = DeviceWindowRunner(window_size=8, plan_mode="frontier")
        s = runner.session()
        assert s.registry is runner.registry
        assert s.plan_mode == "frontier"
        report = feed_interleaved(s, tasks, 4)
        np.testing.assert_allclose(final_values(buffers), ref, rtol=1e-6)
        assert report.window_stats["retired"] == 30


class TestBufferPoolFree:
    def test_free_releases_name_without_recycling_addresses(self):
        pool = BufferPool()
        a = pool.alloc((D,), np.float32, name="x", value=jnp.ones(D))
        pool.free("x")
        assert "x" not in pool
        b = pool.alloc((D,), np.float32, name="x", value=jnp.ones(D))
        assert b.base > a.base  # bump pointer stays monotone
        with pytest.raises(KeyError):
            pool.free("never-allocated")

    def test_free_hooks_fire_with_the_buffer(self):
        pool = BufferPool()
        seen = []
        pool.add_free_hook(seen.append)
        b = pool.alloc((D,), np.float32, name="hooked", value=jnp.ones(D))
        pool.free("hooked")
        assert seen == [b]


class TestHistoryLimit:
    """Bounded session-lifetime bookkeeping (history_limit=N): schedule
    traces rotate, yet retirement observation stays exact for every tid
    ever retired."""

    def test_traces_rotate_but_counters_stay_exact(self):
        _, buffers, tasks = build_stream(11, 30, 6)
        s = make_session("wave", window_size=4, history_limit=5)
        for t in tasks:
            s.submit(t)
            s.poll()
        report = s.close()
        assert len(s.waves) <= 5
        assert report.window_stats["retired"] == 30
        np.testing.assert_allclose(final_values(buffers), serial_ref(11),
                                   rtol=1e-6)

    def test_fire_immediately_survives_tid_eviction(self):
        """A callback/ticket registered long after retirement must still
        fire immediately even when the tid was rotated out of the live
        retired set into the evicted intervals."""
        _, _, tasks = build_stream(12, 40, 6)
        s = make_session("wave", window_size=4, history_limit=4)
        for t in tasks:
            s.submit(t)
            s.poll()
        assert len(s._retired_tids) <= 4  # rotated
        fired = []
        s.on_task_retired(tasks[0], lambda t: fired.append(t.tid))
        assert fired == [tasks[0].tid]
        assert s.ticket(tasks[0]).done()
        for t in tasks:  # exact membership for every tid ever retired
            assert s._is_retired(t.tid)
        unseen = Task(opcode="axpy", fn=_axpy, inputs=(), outputs=(),
                      read_segments=(), write_segments=())
        assert not s._is_retired(unseen.tid)
        s.close()

    def test_evicted_intervals_stay_merged(self):
        """Monotone tid eviction collapses into O(1) intervals, not one
        entry per evicted tid."""
        _, _, tasks = build_stream(13, 50, 6)
        s = make_session("wave", window_size=4, history_limit=4)
        for t in tasks:
            s.submit(t)
            s.poll()
        assert len(s._retired_evicted) <= 2
        s.close()

    def test_rejects_nonpositive_limit(self):
        with pytest.raises(ValueError, match="history_limit"):
            make_session("wave", history_limit=0)

    def test_device_session_epoch_log_rotates(self):
        s = make_session("device", window_size=4, history_limit=3)
        for seed in range(5):
            _, _, tasks = build_stream(seed, 4, 3)
            s.submit(tasks)
            s.poll()
        assert len(s.epoch_log) <= 3
        assert s.session_stats()["epochs"] == 5
        s.close()


class TestDeviceSessionRecycling:
    """Arena row lifecycle through the live device session: release feeds
    the free-list, recurring traffic recycles rows (bounded slabs, plan
    cache hits stay valid), and compaction invalidates exactly the moved
    structure keys."""

    def _phase(self, session, pool, n=4, value=1.0):
        """One request-like burst: fresh buffers, a 2-task chain, flush to
        retire; returns the buffers (caller releases them)."""
        bufs = [pool.alloc((D,), np.float32, value=jnp.full(D, value + i))
                for i in range(n)]
        chain = []
        for src, dst in ((0, 2), (2, 3)):
            r, w = default_segments((bufs[src], bufs[1]), (bufs[dst],))
            chain.append(Task(opcode="axpy", fn=_axpy,
                              inputs=(bufs[src], bufs[1]),
                              outputs=(bufs[dst],),
                              read_segments=r, write_segments=w))
        session.submit(chain)
        session.flush()
        return bufs

    def test_release_bounds_rows_and_cache_under_recurring_traffic(self):
        from repro.core import DeviceSession

        s = DeviceSession(window_size=8)
        pool = BufferPool()
        rows_after = []
        for phase in range(8):
            bufs = self._phase(s, pool, value=float(phase))
            for b in bufs:
                assert s.release_buffer(b)
            rows_after.append(sum(len(s.arena.rows(c))
                                  for c in range(s.arena.n_classes())))
        stats = s.session_stats()
        # slab never grows past the first phase's footprint
        assert rows_after[-1] == rows_after[0]
        assert stats["arena_recycled_rows"] > 0
        assert stats["slab_bytes"] == rows_after[0] * 8 * 4  # padded rows
        # recycled rows repeat structure keys: the cache stays bounded and
        # hot instead of growing one entry per phase
        assert stats["plan_cache_entries"] <= 2
        assert stats["plan_cache_hits"] >= 5
        s.close()

    def test_without_release_rows_grow_monotonically(self):
        """The pre-fix behavior, kept as the contrast leg: no release, one
        leaked row per buffer per phase."""
        from repro.core import DeviceSession

        s = DeviceSession(window_size=8)
        pool = BufferPool()
        for phase in range(4):
            self._phase(s, pool, value=float(phase))
        assert s.arena.live_rows() == 4 * 4
        assert s.session_stats()["plan_cache_entries"] == 4
        s.close()

    def test_compaction_invalidates_exactly_moved_classes(self):
        """Two shape classes; compacting one must drop only ITS cached
        plans — the other class's entry survives and keeps hitting — and
        surviving values stay bit-exact (device-side gather)."""
        from repro.core import DeviceSession

        s = DeviceSession(window_size=8, compact_min_rows=8,
                          compact_waste=0.5)
        pool = BufferPool()
        # class A: (D,) rows
        a = [pool.alloc((D,), np.float32, value=jnp.full(D, 1.0 + i))
             for i in range(8)]
        # class B: (2, D) rows — a distinct padded shape class
        b = [pool.alloc((2, D), np.float32, value=jnp.full((2, D), 50.0 + i))
             for i in range(2)]

        def task_over(ins, outs):
            r, w = default_segments(ins, outs)
            return Task(opcode="axpy", fn=_axpy, inputs=ins, outputs=outs,
                        read_segments=r, write_segments=w)

        # epoch 1: class-A-only plan touching all 8 A rows (pairwise)
        s.submit([task_over((a[i], a[i + 1]), (a[i + 1],))
                  for i in range(0, 8, 2)])
        s.flush()
        s.submit(task_over((b[0], b[1]), (b[1],)))
        s.flush()  # epoch 2: class-B-only plan
        keys_before = set(s._plan_cache.keys())
        assert len(keys_before) == 2
        # kill 6 of 8 class-A rows -> waste 6/8 >= 0.5; class B untouched
        for buf in a[2:]:
            assert s.release_buffer(buf)
        # next epoch compacts class A first, then executes
        s.submit(task_over((b[0], b[1]), (b[1],)))  # same B structure
        s.flush()
        stats = s.session_stats()
        assert stats["arena_compactions"] == 1
        assert stats["arena_generation"] == 1
        assert stats["plan_cache_invalidations"] == 1  # the class-A entry
        surviving = keys_before & set(s._plan_cache.keys())
        assert len(surviving) == 1  # class-B entry survived...
        assert stats["plan_cache_hits"] >= 1  # ...and kept hitting
        # values across the compaction stay bit-exact
        s.sync()
        np.testing.assert_array_equal(
            np.asarray(a[1].value),
            np.asarray(_axpy(jnp.full(D, 1.0), jnp.full(D, 2.0))))
        expected_b1 = _axpy(jnp.full((2, D), 50.0),
                            _axpy(jnp.full((2, D), 50.0),
                                  jnp.full((2, D), 51.0)))
        np.testing.assert_array_equal(np.asarray(b[1].value),
                                      np.asarray(expected_b1))
        s.close()

    def test_plan_cache_lru_cap(self):
        from repro.core import DeviceSession

        s = DeviceSession(window_size=8, plan_cache_limit=2)
        pool = BufferPool()
        bufs = [pool.alloc((D,), np.float32, value=jnp.ones(D))
                for _ in range(6)]
        # three structurally distinct single-task epochs
        for ins, outs in (((bufs[0], bufs[1]), (bufs[1],)),
                          ((bufs[2], bufs[3]), (bufs[3],)),
                          ((bufs[4], bufs[5]), (bufs[5],))):
            r, w = default_segments(ins, outs)
            s.submit(Task(opcode="axpy", fn=_axpy, inputs=ins, outputs=outs,
                          read_segments=r, write_segments=w))
            s.poll()
        stats = s.session_stats()
        assert stats["plan_cache_entries"] == 2
        assert stats["plan_cache_evictions"] == 1
        s.close()
