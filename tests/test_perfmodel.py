"""Device-model sanity: the modeled orderings the paper reports must hold
(serial < ACS-SW < ACS-HW for parallel small-kernel streams; CUDAGraph
beaten by ACS on dynamic graphs due to construction cost, competitive on
static)."""

import numpy as np

from repro.core import BufferPool, Task, TaskStream, WaveScheduler
from repro.core.perfmodel import (
    RTX3060_LIKE,
    kernel_ctas,
    kernel_time_us,
    shelf_makespan,
    simulate,
)
from repro.core.device_dispatch import plan_waves
from repro.core.task import default_segments
from repro.sim import PhysicsEngine, make_env


def make_sim_stream(steps=3):
    eng = PhysicsEngine(make_env("ant"), n_envs=16, group_size=4, seed=0)
    stream = TaskStream()
    for _ in range(steps):
        eng.emit_step(stream)
    return stream.tasks


class TestShelf:
    def test_single_item(self):
        span, busy = shelf_makespan([(4, 2.0)], units=8)
        assert span == 2.0 and busy == 8.0

    def test_two_fit_side_by_side(self):
        span, _ = shelf_makespan([(4, 2.0), (4, 3.0)], units=8)
        assert span == 3.0

    def test_overflow_makes_second_shelf(self):
        span, _ = shelf_makespan([(6, 2.0), (6, 3.0)], units=8)
        assert span == 5.0


class TestPolicyOrdering:
    def test_orderings_on_simulation_stream(self):
        tasks = make_sim_stream()
        waves = plan_waves(tasks, window_size=32)
        serial = simulate([[t] for t in tasks], RTX3060_LIKE, "serial")
        sw = simulate(waves, RTX3060_LIKE, "acs_sw")
        hw = simulate(waves, RTX3060_LIKE, "acs_hw")
        assert sw["time_us"] < serial["time_us"], "ACS-SW must beat serial"
        assert hw["time_us"] < sw["time_us"], "ACS-HW must beat ACS-SW"
        # occupancy improves (paper Fig 24)
        assert hw["occupancy"] > serial["occupancy"]

    def test_cudagraph_construction_cost_dominates_dynamic(self):
        """With per-input construction (Fig 9), CUDAGraph loses to ACS-HW."""
        tasks = make_sim_stream()
        waves = plan_waves(tasks, window_size=32)
        hw = simulate(waves, RTX3060_LIKE, "acs_hw")
        # construction ~ measured at ~47% of baseline runtime in the paper
        serial = simulate([[t] for t in tasks], RTX3060_LIKE, "serial")
        construct = 0.47 * serial["time_us"]
        cg = simulate(waves, RTX3060_LIKE, "cudagraph", construct_us=construct)
        assert cg["time_us"] > hw["time_us"]

    def test_cudagraph_amortized_static_competitive(self):
        tasks = make_sim_stream()
        waves = plan_waves(tasks, window_size=32)
        hw = simulate(waves, RTX3060_LIKE, "acs_hw")
        cg = simulate(waves, RTX3060_LIKE, "cudagraph", construct_us=0.0)
        assert cg["time_us"] <= hw["time_us"] * 1.05


class TestKernelModel:
    def test_small_kernel_hits_latency_floor(self):
        pool = BufferPool()
        a = pool.alloc((4,), np.float32, value=np.zeros(4, np.float32))
        b = pool.alloc((4,), np.float32, value=np.zeros(4, np.float32))
        r, w = default_segments((a,), (b,))
        t = Task(opcode="x", fn=lambda v: v, inputs=(a,), outputs=(b,),
                 read_segments=r, write_segments=w, cost_flops=4, cost_bytes=32)
        assert kernel_time_us(t, RTX3060_LIKE) == RTX3060_LIKE.min_kernel_us
        assert kernel_ctas(t, RTX3060_LIKE) == 1
