"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracles,
swept over shapes and dtypes, plus hypothesis property tests."""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import (
    apply_wave,
    flash_attention,
    grouped_matmul,
    lru_scan,
    wave_elementwise,
)
from repro.kernels import ref

RNG = np.random.RandomState(0)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.randn(*shape).astype(dtype))


TOL = {np.float32: dict(rtol=2e-5, atol=2e-5), np.float16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,hkv,sq,sk,d", [
        (1, 2, 2, 32, 32, 16),    # MHA
        (2, 4, 2, 48, 48, 32),    # GQA 2:1, non-pow2 seq (padding path)
        (1, 8, 1, 16, 64, 8),     # MQA, cross Sq != Sk
    ])
    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_shapes_dtypes_causal(self, b, h, hkv, sq, sk, d, dtype):
        q, k, v = randn(b, h, sq, d, dtype=dtype), randn(b, hkv, sk, d, dtype=dtype), randn(b, hkv, sk, d, dtype=dtype)
        off = sk - sq
        out = flash_attention(q, k, v, q_offset=off, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, q_offset=off)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **TOL[dtype])

    @pytest.mark.parametrize("window", [8, 17])
    def test_local_window(self, window):
        q, k, v = randn(1, 2, 40, 16), randn(1, 2, 40, 16), randn(1, 2, 40, 16)
        out = flash_attention(q, k, v, window=window, block_q=8, block_k=8)
        expect = ref.attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        q, k, v = randn(1, 2, 32, 16), randn(1, 2, 32, 16), randn(1, 2, 32, 16)
        out = flash_attention(q, k, v, softcap=10.0, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, softcap=10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)

    def test_decode_single_query(self):
        q, k, v = randn(2, 4, 1, 16), randn(2, 2, 128, 16), randn(2, 2, 128, 16)
        out = flash_attention(q, k, v, q_offset=127, block_q=1, block_k=32)
        expect = ref.attention_ref(q, k, v, q_offset=127)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)

    def test_noncausal(self):
        q, k, v = randn(1, 2, 24, 16), randn(1, 2, 24, 16), randn(1, 2, 24, 16)
        out = flash_attention(q, k, v, causal=False, block_q=8, block_k=8)
        expect = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)

    @given(st.integers(1, 3), st.integers(0, 2), st.integers(3, 6), st.integers(2, 5))
    @settings(max_examples=10, deadline=None)
    def test_property_gqa_any_shape(self, b, hkv_log, sq_log, d_log):
        hkv = 2 ** hkv_log
        h = hkv * 2
        sq = 2 ** sq_log
        d = 2 ** d_log
        q, k, v = randn(b, h, sq, d), randn(b, hkv, sq, d), randn(b, hkv, sq, d)
        out = flash_attention(q, k, v, block_q=8, block_k=8)
        expect = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5)


class TestGroupedMatmul:
    @pytest.mark.parametrize("g,k,n,bm,tiles", [
        (2, 16, 16, 8, (0, 1)),
        (4, 32, 48, 8, (0, 0, 1, 2, 2, 3)),
        (8, 64, 24, 16, (0, 2, 2, 4, 7)),   # n not multiple of block_n
    ])
    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_shapes_dtypes(self, g, k, n, bm, tiles, dtype):
        tiles = jnp.asarray(tiles, jnp.int32)
        m = len(tiles) * bm
        x = randn(m, k, dtype=dtype)
        w = randn(g, k, n, dtype=dtype)
        out = grouped_matmul(x, w, tiles, block_m=bm, block_n=16)
        expect = ref.grouped_matmul_ref(x, w, tiles, block_m=bm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **TOL[dtype])

    @given(st.integers(1, 6), st.integers(1, 4))
    @settings(max_examples=10, deadline=None)
    def test_property_random_tiling(self, n_tiles, g):
        tiles = jnp.asarray(np.random.RandomState(n_tiles).randint(0, g, n_tiles), jnp.int32)
        bm, k, n = 8, 16, 16
        x = randn(n_tiles * bm, k)
        w = randn(g, k, n)
        out = grouped_matmul(x, w, tiles, block_m=bm, block_n=16)
        expect = ref.grouped_matmul_ref(x, w, tiles, block_m=bm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


class TestLruScan:
    @pytest.mark.parametrize("b,s,d,chunk", [
        (1, 16, 8, 4),
        (2, 33, 16, 8),   # padding path (s % chunk != 0)
        (3, 64, 4, 64),   # single chunk
    ])
    def test_shapes(self, b, s, d, chunk):
        a = jnp.asarray(RNG.uniform(0.5, 0.99, (b, s, d)).astype(np.float32))
        x = randn(b, s, d)
        h0 = randn(b, d)
        out = lru_scan(a, x, h0, chunk=chunk)
        expect = ref.lru_scan_ref(a, x, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)

    def test_identity_decay_keeps_state(self):
        b, s, d = 1, 8, 4
        a = jnp.ones((b, s, d))
        x = jnp.zeros((b, s, d))
        h0 = randn(b, d)
        out = lru_scan(a, x, h0, chunk=4)
        np.testing.assert_allclose(np.asarray(out[:, -1]), np.asarray(h0), rtol=1e-6)

    @given(st.integers(1, 3), st.integers(1, 40), st.integers(1, 8))
    @settings(max_examples=10, deadline=None)
    def test_property_matches_scan(self, b, s, d):
        rng = np.random.RandomState(s * 7 + d)
        a = jnp.asarray(rng.uniform(0.0, 1.0, (b, s, d)).astype(np.float32))
        x = jnp.asarray(rng.randn(b, s, d).astype(np.float32))
        h0 = jnp.asarray(rng.randn(b, d).astype(np.float32))
        out = lru_scan(a, x, h0, chunk=8)
        expect = ref.lru_scan_ref(a, x, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5)


_BRANCHES = (
    lambda x, y: x + y,
    lambda x, y: x * y,
    lambda x, y: jnp.maximum(x, y),
)


class TestWaveElementwise:
    def test_single_wave_matches_ref(self):
        slab = randn(6, 16)
        desc = jnp.asarray([[0, 0, 1, 4], [1, 2, 3, 5]], jnp.int32)
        rows = wave_elementwise(slab, desc, branches=_BRANCHES)
        expect = ref.wave_elementwise_ref(
            slab, np.asarray(desc[:, 0]), np.asarray(desc[:, 1:3]),
            np.asarray(desc[:, 3]), _BRANCHES,
        )
        got = apply_wave(slab, desc, rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-6)

    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_property_random_waves(self, seed):
        rng = np.random.RandomState(seed)
        r, d, s = 8, 8, 5
        slab = jnp.asarray(rng.randn(r, d).astype(np.float32))
        ops = rng.randint(0, len(_BRANCHES), s)
        ins = rng.randint(0, r, (s, 2))
        outs = rng.choice(r, s, replace=False)  # unique out rows (window invariant)
        desc = jnp.asarray(np.concatenate([ops[:, None], ins, outs[:, None]], axis=1), jnp.int32)
        rows = wave_elementwise(slab, desc, branches=_BRANCHES)
        got = apply_wave(slab, desc, rows)
        expect = ref.wave_elementwise_ref(slab, ops, ins, outs, _BRANCHES)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-6)
