"""The mesh cross-shard transfer layer (DESIGN §12): ShardLink's d2d and
host-staged paths, the ShardTransferTable byte audit, write-owner
invalidation, the narrowed late-observer sync, and the overlapped drain
pump. Everything here runs in-process on logical shards (4 shards over
however many devices the host exposes) — the forced-REAL-multi-device
legs live in test_differential_matrix.py's subprocess tests.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import BufferPool, TaskStream, run_serial
from repro.core.mesh_session import MeshDeviceSession, ShardLink
from repro.core.wrapper import AcsKernel
from repro.kernels.ops import LOOP_BRANCHES

D = 8
N_SHARDS = 4


def _kernels():
    return (AcsKernel(name="axpy_xfer", fn=LOOP_BRANCHES["axpy"]),
            AcsKernel(name="mul_xfer", fn=LOOP_BRANCHES["mul"]))


def _cross_shard_stream(pool, seed=0, rounds=6):
    """N independent two-buffer chains (placement spreads them across
    shards) with neighbour-chain joins on odd rounds — every join is a
    cross-shard edge once chains land on different shards."""
    rng = np.random.RandomState(seed)
    axpy, mul = _kernels()
    chains = [
        [pool.alloc((D,), np.float32, name=f"c{c}b{k}",
                    value=jnp.asarray(rng.randn(D).astype(np.float32)))
         for k in range(2)]
        for c in range(N_SHARDS)
    ]
    stream = TaskStream()
    tasks = []
    for r in range(rounds):
        for c in range(N_SHARDS):
            a, b = chains[c]
            tasks.append(axpy.launch(stream, inputs=(a, b), outputs=(a,)))
            tasks.append(mul.launch(stream, inputs=(a, b), outputs=(b,)))
        if r % 2 == 1:
            for c in range(N_SHARDS):
                other = chains[(c + 1) % N_SHARDS][0]
                a = chains[c][0]
                tasks.append(axpy.launch(stream, inputs=(other, a),
                                         outputs=(a,)))
    bufs = [b for ch in chains for b in ch]
    return bufs, tasks


def _snap(bufs):
    return np.stack([np.asarray(b.value) for b in bufs])


def _serial_ref(seed=0):
    pool = BufferPool()
    bufs, tasks = _cross_shard_stream(pool, seed=seed)
    run_serial(tasks)
    return _snap(bufs)


def _mesh_transfer_syncs(stats):
    return sum(s.get("host_syncs_by_tag", {}).get("mesh-transfer", 0)
               for s in stats["per_shard"])


class TestShardLinkAudit:
    """Satellite: the ShardTransferTable byte totals must equal the rows
    actually moved — on both paths, against the link's own move calls."""

    @pytest.mark.parametrize("mode", ["d2d", "staged"])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_table_bytes_match_rows_moved(self, mode, seed):
        pool = BufferPool()
        bufs, tasks = _cross_shard_stream(pool, seed=seed)
        sess = MeshDeviceSession(window_size=32, n_shards=N_SHARDS,
                                 transfer_mode=mode)
        expected = {}
        orig_move = sess.link.move

        def spy(base, owner, dest):
            nbytes = sess._shards[owner].arena.row_nbytes(base)
            used = orig_move(base, owner, dest)
            slot = expected.setdefault(used, {"transfers": 0, "bytes": 0})
            slot["transfers"] += 1
            slot["bytes"] += nbytes
            return used

        sess.link.move = spy
        sess.submit(tasks)
        sess.close()

        table = sess.transfer_table.as_dict()
        assert table["transfers"] > 0, "stream produced no cross-shard moves"
        assert table["by_mode"] == expected
        assert table["transfers"] == sum(v["transfers"]
                                         for v in expected.values())
        assert table["bytes"] == sum(v["bytes"] for v in expected.values())
        # A forced mode must not silently take the other path (the d2d
        # probe degenerates to a same-device put on a 1-device host, so
        # forced d2d has no reason to fall back here).
        assert set(expected) == {mode}
        np.testing.assert_array_equal(_snap(bufs), _serial_ref(seed))

    def test_d2d_eliminates_mesh_transfer_syncs(self):
        """The mechanism behind the bench gate: forced d2d moves every
        cross-shard edge without a single mesh-transfer-tagged host sync;
        forced staged shows the nonzero count d2d eliminates. Both paths
        account identical bytes."""
        results = {}
        for mode in ("staged", "d2d"):
            pool = BufferPool()
            bufs, tasks = _cross_shard_stream(pool)
            sess = MeshDeviceSession(window_size=32, n_shards=N_SHARDS,
                                     transfer_mode=mode)
            sess.submit(tasks)
            sess.close()
            results[mode] = (_snap(bufs), sess.session_stats())

        d2d_vals, d2d = results["d2d"]
        staged_vals, staged = results["staged"]
        assert d2d["transfer_mode"] == "d2d"
        assert staged["transfer_mode"] == "staged"
        assert d2d["d2d_moves"] > 0 and d2d["staged_moves"] == 0
        assert staged["staged_moves"] > 0 and staged["d2d_moves"] == 0
        assert _mesh_transfer_syncs(d2d) == 0
        assert _mesh_transfer_syncs(staged) > 0
        assert d2d["transfers"]["bytes"] == staged["transfers"]["bytes"]
        assert d2d["row_invalidations"] > 0, (
            "cross-shard writes must invalidate superseded replicas")
        np.testing.assert_array_equal(d2d_vals, staged_vals)
        np.testing.assert_array_equal(d2d_vals, _serial_ref())

    def test_link_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="transfer_mode"):
            MeshDeviceSession(window_size=16, n_shards=2,
                              transfer_mode="teleport")
        with pytest.raises(ValueError, match="transfer_mode"):
            ShardLink([], None, mode="bogus")


class TestLateObserverSync:
    """Satellite: a late observer of a retired task must sync only the
    shards owning that task's operands — not sweep every shard."""

    def test_late_observe_syncs_only_owner_shards(self):
        pool = BufferPool()
        bufs, tasks = _cross_shard_stream(pool)
        sess = MeshDeviceSession(window_size=32, n_shards=N_SHARDS)
        sess.submit(tasks)
        sess.flush()

        calls = {i: [] for i in range(N_SHARDS)}
        for i, sh in enumerate(sess._shards):
            def spy(bufs_arg, _orig=sh.sync_buffers, _i=i, **kw):
                calls[_i].append(list(bufs_arg))
                return _orig(bufs_arg, **kw)

            sh.sync_buffers = spy

        # A chain-internal task: both operands live on that chain's shard.
        task = tasks[0]
        owners = {sess._owner[id(b)] for b in
                  tuple(task.inputs) + tuple(task.outputs)
                  if id(b) in sess._owner}
        assert owners, "task operands lost their owner entries"

        fired = []
        sess.on_task_retired(task, fired.append)
        assert fired == [task]

        synced = {i for i, c in calls.items() if c}
        assert synced == owners
        assert len(synced) < N_SHARDS, (
            "late observe swept every shard — the narrowed sync regressed")
        # Each owner shard synced exactly once, with only operand bases.
        operand_ids = {id(b) for b in
                       tuple(task.inputs) + tuple(task.outputs)}
        for i in synced:
            assert len(calls[i]) == 1
            assert {id(b) for b in calls[i][0]} <= operand_ids
        sess.close()


class TestOverlappedDrain:
    def test_overlap_bit_identical_and_actually_overlaps(self):
        ref = _serial_ref()
        stats = {}
        for overlap in (True, False):
            pool = BufferPool()
            bufs, tasks = _cross_shard_stream(pool)
            sess = MeshDeviceSession(window_size=32, n_shards=N_SHARDS,
                                     overlap_drains=overlap)
            sess.submit(tasks)
            sess.close()
            np.testing.assert_array_equal(_snap(bufs), ref)
            stats[overlap] = sess.session_stats()
        assert stats[True]["overlap_drains"] is True
        assert stats[True]["drain_overlap"] >= 2, (
            "overlapped pump never had two shards in flight at once")
        assert stats[False]["overlap_drains"] is False
        assert stats[False]["drain_overlap"] == 0

    def test_stall_error_reports_per_shard_outstanding(self):
        """Satellite: the overlapped pump raises only when a full
        round-robin pass (plus one blocking poll) advances nothing, and
        the error carries every pending shard's outstanding count."""
        sess = MeshDeviceSession(window_size=16, n_shards=2)

        class _Stuck:
            outstanding = 3
            inflight_segments = 0

            def launch(self):
                return False

            def poll_inflight(self, block=False):
                return 0

        sess._shards = [_Stuck(), _Stuck()]
        with pytest.raises(RuntimeError) as exc:
            sess._drain_overlapped([0, 1])
        msg = str(exc.value)
        assert "full round-robin pass" in msg
        assert "{0: 3, 1: 3}" in msg

    def test_idle_shard_is_not_a_stall(self):
        """One shard retiring while another is empty must NOT raise: the
        stall check fires only when nothing anywhere advances."""

        class _Draining:
            def __init__(self, segments):
                self.outstanding = segments
                self.inflight_segments = segments

            def launch(self):
                return self.outstanding > 0

            def poll_inflight(self, block=False):
                if self.outstanding:
                    self.outstanding -= 1
                    self.inflight_segments -= 1
                    return 1
                return 0

        class _Idle:
            outstanding = 0
            inflight_segments = 0

            def launch(self):
                return False

            def poll_inflight(self, block=False):
                return 0

        sess = MeshDeviceSession(window_size=16, n_shards=2)
        sess._shards = [_Draining(3), _Idle()]
        sess._drain_overlapped([0, 1])  # must terminate without raising
        assert sess._shards[0].outstanding == 0
