"""Serving runtime: the live SessionServer and the batch-drain baseline
must produce identical tokens, leak no prompt buffers, observe
co-scheduling (prefill alongside in-flight decode), apply multi-tenant
fairness, and exert backpressure through the bounded admission FIFO."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import ARCHS
from repro.models import init_params
from repro.runtime import (
    AdmissionQueueFull,
    ContinuousBatchingServer,
    SessionServer,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    return dataclasses.replace(cfg, n_layers=1, d_model=32, d_ff=64, vocab=64,
                               n_heads=2, n_kv_heads=1, head_dim=16)


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return init_params(tiny_cfg, jax.random.PRNGKey(0), tp_size=1)


def _prompts(tiny_cfg, n, seed=0, length=5):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, tiny_cfg.vocab, length) for _ in range(n)]


def _no_prompt_buffers(pool):
    return [b.name for b in pool.buffers() if b.name.endswith("_prompt")] == []


class TestSessionServer:
    @pytest.mark.parametrize("scheduler", ["frontier", "wave"])
    def test_requests_finish_with_correct_token_counts(self, tiny_cfg, tiny_params,
                                                       scheduler):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                               scheduler=scheduler)
        reqs = [server.submit(p, max_new=3) for p in _prompts(tiny_cfg, 4)]
        done = server.run_until_drained()
        server.close()
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        for r in done:
            assert len(r.generated) == 3
            assert r.t_finish >= r.t_admit >= r.t_arrival > 0

    def test_tokens_identical_to_batch_server(self, tiny_cfg, tiny_params):
        """Live-window scheduling only reorders provably independent work:
        every request's token sequence must match the per-step drain's."""
        prompts = _prompts(tiny_cfg, 5, seed=1)
        batch = ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=2,
                                         max_len=32)
        for p in prompts:
            batch.submit(p, max_new=3)
        ref = {tuple(r.prompt): r.generated for r in batch.run_until_drained()}

        live = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                             scheduler="frontier")
        for p in prompts:
            live.submit(p, max_new=3)
        got = {tuple(r.prompt): r.generated for r in live.run_until_drained()}
        live.close()
        assert got == ref

    def test_no_prompt_buffer_leak(self, tiny_cfg, tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32)
        for p in _prompts(tiny_cfg, 4):
            server.submit(p, max_new=2)
        server.run_until_drained()
        server.close()
        assert _no_prompt_buffers(server.pool)

    @pytest.mark.parametrize("n_shards", [1, 2])
    def test_mesh_tokens_identical_to_frontier(self, tiny_cfg, tiny_params,
                                               n_shards):
        """Serving through the mesh-sharded window (DESIGN §12): token
        sequences must match the frontier session's exactly — decode-chain
        retirement callbacks must observe each intermediate slot value
        even when a whole chain drains inside one sub-epoch — and the
        close stats must carry the per-shard slot-occupancy samples."""
        prompts = _prompts(tiny_cfg, 4, seed=2)
        ref_server = SessionServer(tiny_cfg, tiny_params, max_slots=2,
                                   max_len=32, scheduler="frontier")
        for p in prompts:
            ref_server.submit(p, max_new=3)
        ref = {tuple(r.prompt): r.generated
               for r in ref_server.run_until_drained()}
        ref_server.close()

        mesh = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                             scheduler="mesh", n_shards=n_shards)
        for p in prompts:
            mesh.submit(p, max_new=3)
        got = {tuple(r.prompt): r.generated
               for r in mesh.run_until_drained()}
        mesh.close()
        entry = mesh.report_log[-1]
        assert got == ref
        assert _no_prompt_buffers(mesh.pool)
        assert entry["shard_slots_mean"], entry
        assert all(v >= 0 for v in entry["shard_slots_mean"].values())

    def test_coscheduling_prefill_with_inflight_decode(self, tiny_cfg, tiny_params):
        """A request arriving mid-decode shares a wave with the in-flight
        decode (wave) — admission into the LIVE window, not a fresh drain."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                               scheduler="wave")
        # task_kinds drops entries at retirement (bounded bookkeeping), so
        # record each retired task's kind through the session listener
        kinds = {}
        server.session.add_retire_listener(
            lambda t: kinds.__setitem__(t.tid, t.opcode))
        prompts = _prompts(tiny_cfg, 2, seed=2)
        server.submit(prompts[0], max_new=4)
        for _ in range(3):
            server.pump()  # request 0 prefilled and decoding
        server.submit(prompts[1], max_new=4)  # arrives mid-decode
        server.run_until_drained()
        report = server.close()
        mixed = [w for w in report.waves
                 if len({kinds[t] for t in w}) > 1]
        assert mixed, "no wave co-scheduled a prefill with the in-flight decode"
        assert not server.task_kinds, "task_kinds must drain with retirements"

    def test_frontier_overlaps_groups(self, tiny_cfg, tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                               scheduler="frontier")
        for p in _prompts(tiny_cfg, 4, seed=3):
            server.submit(p, max_new=3)
        server.run_until_drained()
        report = server.close()
        assert report.max_inflight_groups() > 1

    def test_tenant_fairness_oldest_first_tiebreak(self, tiny_cfg, tiny_params):
        """Tenant B arriving behind A's backlog is admitted as soon as a
        slot frees (fewest-active-slots rule), ahead of older A requests."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32)
        a = [server.submit(p, max_new=2, tenant="A")
             for p in _prompts(tiny_cfg, 4, seed=4)]
        b = server.submit(_prompts(tiny_cfg, 1, seed=5)[0], max_new=2, tenant="B")
        server.run_until_drained()
        server.close()
        assert b.t_admit < a[2].t_admit  # B jumped A's backlog...
        assert a[2].t_admit < a[3].t_admit  # ...but A stays oldest-first

    def test_close_drains_inflight_chains(self, tiny_cfg, tiny_params):
        """Requests still in flight at close() retire during the closing
        flush; one more pump() hands them to the caller."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32)
        req = server.submit(_prompts(tiny_cfg, 1, seed=9)[0], max_new=2)
        server.pump()  # admitted; chain in flight, nothing harvested yet
        server.close()
        done = server.pump()
        assert [r.rid for r in done] == [req.rid]
        assert len(req.generated) == 2

    def test_backpressure_bounded_fifo(self, tiny_cfg, tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1, max_len=32,
                               max_queue=2)
        prompts = _prompts(tiny_cfg, 3, seed=6)
        r0 = server.submit(prompts[0])
        r1 = server.submit(prompts[1])
        assert (r0.queue_depth, r1.queue_depth) == (1, 2)
        with pytest.raises(AdmissionQueueFull):
            server.submit(prompts[2])
        assert server.queue_depth() == 2


class TestBatchServerSatellites:
    def test_batch_server_frees_prompt_buffers(self, tiny_cfg, tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=2,
                                          max_len=32)
        for p in _prompts(tiny_cfg, 3, seed=7):
            server.submit(p, max_new=2)
        server.run_until_drained()
        assert _no_prompt_buffers(server.pool)

    def test_batch_server_backpressure(self, tiny_cfg, tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=1,
                                          max_len=32, max_queue=1)
        server.submit(_prompts(tiny_cfg, 1)[0])
        with pytest.raises(AdmissionQueueFull):
            server.submit(_prompts(tiny_cfg, 1, seed=8)[0])


class TestLifetimeRegressions:
    """ISSUE 6 satellites: round clamping, stale-slot reuse, bounded
    bookkeeping, and the device arena row lifecycle wiring."""

    @pytest.mark.parametrize("server_cls", [SessionServer,
                                            ContinuousBatchingServer])
    def test_overlong_prompt_rejected_at_submit(self, tiny_cfg, tiny_params,
                                                server_cls):
        server = server_cls(tiny_cfg, tiny_params, max_slots=1, max_len=8)
        with pytest.raises(ValueError, match="prompt length"):
            server.submit(np.zeros(8, np.int32))  # max_len - 1 = 7
        server.submit(np.zeros(7, np.int32))  # exactly full cache: accepted

    @pytest.mark.parametrize("server_cls", [SessionServer,
                                            ContinuousBatchingServer])
    def test_negative_max_new_rejected(self, tiny_cfg, tiny_params,
                                       server_cls):
        server = server_cls(tiny_cfg, tiny_params, max_slots=1, max_len=8)
        with pytest.raises(ValueError, match="max_new"):
            server.submit(np.zeros(3, np.int32), max_new=-1)

    def test_max_new_zero_means_zero_rounds_session(self, tiny_cfg,
                                                    tiny_params):
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32)
        req = server.submit(_prompts(tiny_cfg, 1)[0], max_new=0)
        done = server.run_until_drained()
        server.close()
        assert [r.rid for r in done] == [req.rid]
        assert req.generated == []
        assert req.t_finish >= req.t_admit
        assert _no_prompt_buffers(server.pool)

    def test_full_prompt_gets_zero_rounds_session(self, tiny_cfg,
                                                  tiny_params):
        """A prompt filling the cache (len == max_len - 1) must NOT get the
        old forced decode round that pushed pos past max_len."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=1, max_len=8)
        req = server.submit(np.zeros(7, np.int32), max_new=5)
        server.run_until_drained()
        server.close()
        assert req.generated == []
        assert int(server.slots[0].value[2]) == 7  # pos never passed max_len-1

    def test_max_new_zero_means_zero_rounds_batch(self, tiny_cfg,
                                                  tiny_params):
        server = ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=2,
                                          max_len=32)
        req = server.submit(_prompts(tiny_cfg, 1)[0], max_new=0)
        done = server.run_until_drained()
        assert [r.rid for r in done] == [req.rid]
        assert req.generated == []
        assert not server.active and len(server.free) == 2

    def test_stale_slot_not_decoded_before_prefill(self, tiny_cfg,
                                                   tiny_params):
        """Regression: a freed slot kept its last occupant's (token, pos);
        re-granting it made the batch server schedule a decode against the
        stale token in the same step as the new prefill. After the reset,
        the admission step runs exactly the prefill."""
        server = ContinuousBatchingServer(tiny_cfg, tiny_params, max_slots=1,
                                          max_len=32)
        prompts = _prompts(tiny_cfg, 2, seed=8)
        server.submit(prompts[0], max_new=1)
        server.run_until_drained()  # request 0 done; slot 0 holds stale state
        req1 = server.submit(prompts[1], max_new=2)
        server.step()  # admission step for request 1
        assert server.report_log[-1]["tasks_this_run"] == 1  # prefill ONLY
        assert req1.generated == []  # nothing harvested from stale state
        server.run_until_drained()
        assert len(req1.generated) == 2

    def test_bookkeeping_is_bounded(self, tiny_cfg, tiny_params):
        """task_kinds drains with retirements; occupancy samples and the
        report log rotate at history_limit."""
        server = SessionServer(tiny_cfg, tiny_params, max_slots=2, max_len=32,
                               history_limit=4)
        for p in _prompts(tiny_cfg, 6, seed=10):
            server.submit(p, max_new=2)
        server.run_until_drained()
        server.close()
        assert server.task_kinds == {}
        assert len(server.occupancy_samples) <= 4
        assert len(server.report_log) <= 4
        assert server.occupancy_samples.maxlen == 4
        assert len(server.session.waves) <= 4

    def test_device_server_recycles_aux_rows_via_pool_free(self, tiny_cfg,
                                                           tiny_params):
        """pool.free on a device-server buffer releases its arena row (the
        free-hook wiring): recurring aux traffic reuses one bounded row
        set instead of leaking a row per buffer."""
        import jax.numpy as jnp

        from repro.core import Task
        from repro.core.task import default_segments

        server = SessionServer(tiny_cfg, tiny_params, max_slots=1, max_len=16,
                               scheduler="device")
        rows_after = []
        for wave in range(4):
            bufs = [server.pool.alloc((4,), np.float32,
                                      name=f"aux{wave}_{i}",
                                      value=jnp.full(4, float(i + 1)))
                    for i in range(3)]
            r, w = default_segments((bufs[0], bufs[1]), (bufs[2],))
            server.session.submit(
                Task(opcode="aux_axpy", fn=lambda x, y: x + 2.0 * y,
                     inputs=(bufs[0], bufs[1]), outputs=(bufs[2],),
                     read_segments=r, write_segments=w))
            server.session.flush()
            for b in bufs:
                server.pool.free(b.name)
            rows_after.append(server.session.arena.live_rows()
                              + server.session.arena.free_rows())
        assert rows_after[-1] == rows_after[0]  # flat, not 3 rows/wave
        assert server.session.arena.recycled_rows > 0
        server.close()
