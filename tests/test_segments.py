"""Unit + property tests for the segment algebra (paper Algorithm 1)."""

import numpy as np
from _prophelper import given, settings, st

from repro.core import Segment, SegmentSet, any_overlap, depends_on, segments_overlap


def seg(start, size):
    return Segment(start, size)


class TestScalarOverlap:
    def test_disjoint(self):
        assert not segments_overlap(seg(0, 10), seg(10, 10))  # half-open touch
        assert not segments_overlap(seg(0, 10), seg(100, 10))

    def test_identical(self):
        assert segments_overlap(seg(5, 10), seg(5, 10))

    def test_contained(self):
        assert segments_overlap(seg(0, 100), seg(10, 5))
        assert segments_overlap(seg(10, 5), seg(0, 100))

    def test_partial(self):
        assert segments_overlap(seg(0, 10), seg(5, 10))
        assert segments_overlap(seg(5, 10), seg(0, 10))

    def test_empty_segment_never_overlaps(self):
        assert not segments_overlap(seg(5, 0), seg(0, 100))
        assert not segments_overlap(seg(0, 100), seg(5, 0))


segments_strategy = st.lists(
    st.builds(Segment, st.integers(0, 1000), st.integers(0, 64)),
    min_size=0,
    max_size=8,
)


class TestVectorizedMatchesScalar:
    @given(segments_strategy, segments_strategy)
    @settings(max_examples=200, deadline=None)
    def test_intersects_equals_any_overlap(self, xs, ys):
        assert SegmentSet(xs).intersects(SegmentSet(ys)) == any_overlap(xs, ys)

    @given(segments_strategy, segments_strategy)
    @settings(max_examples=100, deadline=None)
    def test_symmetry(self, xs, ys):
        assert SegmentSet(xs).intersects(SegmentSet(ys)) == SegmentSet(ys).intersects(
            SegmentSet(xs)
        )


class TestHazards:
    """RAW / WAR / WAW must each independently create a dependency."""

    def test_raw(self):
        # new reads [0,10); old writes [5,10)
        assert depends_on(
            SegmentSet([seg(0, 10)]),
            SegmentSet([seg(100, 10)]),
            SegmentSet([]),
            SegmentSet([seg(5, 5)]),
        )

    def test_war(self):
        # new writes [0,10); old reads [5,10)
        assert depends_on(
            SegmentSet([]),
            SegmentSet([seg(0, 10)]),
            SegmentSet([seg(5, 10)]),
            SegmentSet([]),
        )

    def test_waw(self):
        assert depends_on(
            SegmentSet([]),
            SegmentSet([seg(0, 10)]),
            SegmentSet([]),
            SegmentSet([seg(0, 10)]),
        )

    def test_rar_is_not_a_hazard(self):
        # both only read the same region: independent.
        assert not depends_on(
            SegmentSet([seg(0, 10)]),
            SegmentSet([seg(100, 4)]),
            SegmentSet([seg(0, 10)]),
            SegmentSet([seg(200, 4)]),
        )

    def test_disjoint_everything(self):
        assert not depends_on(
            SegmentSet([seg(0, 10)]),
            SegmentSet([seg(10, 10)]),
            SegmentSet([seg(20, 10)]),
            SegmentSet([seg(30, 10)]),
        )


class TestSegmentSet:
    def test_union_len(self):
        a = SegmentSet([seg(0, 1), seg(2, 1)])
        b = SegmentSet([seg(4, 1)])
        assert len(a.union(b)) == 3

    def test_iter_roundtrip(self):
        xs = [seg(0, 4), seg(8, 8)]
        assert list(SegmentSet(xs)) == xs

    def test_empty(self):
        assert not SegmentSet([]).intersects(SegmentSet([seg(0, 10)]))
        assert len(SegmentSet()) == 0
