"""AsyncFrontierScheduler correctness: serial equivalence on randomized
irregular streams, dependency-safe retirement order, and the async
properties the design promises (overlapping group lifetimes, blocking
syncs << dispatches)."""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax.numpy as jnp

from repro.core import (
    AsyncFrontierScheduler,
    BufferPool,
    DispatchQueue,
    GroupExecutor,
    Task,
    build_full_dag,
    run_serial,
)
from repro.core.task import default_segments

D = 4


def _axpy(x, y):
    return 1.5 * x + y + 1.0


def _mul(x, y):
    return x * y - 0.5


def _neg(x, y):
    return -x + 0.25 * y


OPS = {"axpy": _axpy, "mul": _mul, "neg": _neg}


def build_stream(seed: int, n_tasks: int, n_buffers: int):
    rng = np.random.RandomState(seed)
    pool = BufferPool()
    buffers = [
        pool.alloc((D,), np.float32, value=jnp.asarray(rng.randn(D).astype(np.float32)))
        for _ in range(n_buffers)
    ]
    tasks = []
    names = list(OPS)
    for _ in range(n_tasks):
        op = names[rng.randint(len(names))]
        i0, i1 = rng.randint(n_buffers), rng.randint(n_buffers)
        o = rng.randint(n_buffers)
        ins = (buffers[i0], buffers[i1])
        outs = (buffers[o],)
        r, w = default_segments(ins, outs)
        tasks.append(
            Task(opcode=op, fn=OPS[op], inputs=ins, outputs=outs, read_segments=r, write_segments=w)
        )
    return pool, buffers, tasks


def final_values(buffers):
    return np.stack([np.asarray(b.value) for b in buffers])


class TestFrontierSerialEquivalence:
    @pytest.mark.parametrize("window", [1, 2, 8, 32])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_serial(self, window, seed):
        _, bufs, tasks = build_stream(seed, 40, 8)
        run_serial(tasks)
        ref = final_values(bufs)
        _, bufs2, tasks2 = build_stream(seed, 40, 8)
        AsyncFrontierScheduler(window_size=window).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)

    @given(st.integers(0, 10_000), st.integers(1, 33), st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_property_any_seed_window_inflight(self, seed, window, inflight):
        _, bufs, tasks = build_stream(seed, 24, 6)
        run_serial(tasks)
        ref = final_values(bufs)
        _, bufs2, tasks2 = build_stream(seed, 24, 6)
        AsyncFrontierScheduler(window_size=window, max_inflight=inflight).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)

    def test_max_group_cap_still_equivalent(self):
        _, bufs, tasks = build_stream(5, 40, 12)
        run_serial(tasks)
        ref = final_values(bufs)
        _, bufs2, tasks2 = build_stream(5, 40, 12)
        report = AsyncFrontierScheduler(window_size=32, max_group=2).run(tasks2)
        np.testing.assert_allclose(final_values(bufs2), ref, rtol=1e-6)
        assert report.exec_stats["max_wave_width"] <= 2


class TestFrontierRetirementOrder:
    @given(st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_property_never_retires_before_upstreams(self, seed):
        """A kernel's retire stamp must come after every true upstream's:
        the frontier may reorder independent kernels only."""
        _, _, tasks = build_stream(seed, 30, 6)
        edges, _ = build_full_dag(tasks)
        report = AsyncFrontierScheduler(window_size=16).run(tasks)
        pos = {tid: i for i, tid in enumerate(report.retire_order())}
        assert len(pos) == len(tasks)  # every task retired exactly once
        for t in tasks:
            for up in edges[t.tid]:
                assert pos[up] < pos[t.tid], (
                    f"task {t.tid} retired before upstream {up}"
                )

    def test_launch_order_respects_dependencies(self):
        _, _, tasks = build_stream(3, 40, 6)
        edges, _ = build_full_dag(tasks)
        report = AsyncFrontierScheduler(window_size=32).run(tasks)
        launch_pos = {}
        for i, group in enumerate(report.waves):
            for tid in group:
                launch_pos[tid] = i
        for t in tasks:
            for up in edges[t.tid]:
                assert launch_pos[up] < launch_pos[t.tid]


class TestFrontierAsyncProperties:
    def test_blocking_syncs_fewer_than_dispatches(self):
        _, _, tasks = build_stream(0, 60, 10)
        report = AsyncFrontierScheduler(window_size=32).run(tasks)
        stats = report.exec_stats
        assert stats["dispatches"] > 0
        assert stats["blocking_syncs"] < stats["dispatches"]

    def test_groups_overlap_on_independent_stream(self):
        """Fully independent heterogeneous tasks: several groups should be
        in flight at once (no wave barrier between them)."""
        pool = BufferPool()
        tasks = []
        for i in range(12):
            op = list(OPS)[i % 3]
            a = pool.alloc((D,), np.float32, value=jnp.ones(D))
            b = pool.alloc((D,), np.float32, value=jnp.zeros(D))
            r, w = default_segments((a, a), (b,))
            tasks.append(
                Task(opcode=op, fn=OPS[op], inputs=(a, a), outputs=(b,),
                     read_segments=r, write_segments=w)
            )
        report = AsyncFrontierScheduler(window_size=32, max_inflight=8).run(tasks)
        assert report.max_inflight_groups() > 1
        assert len(report.groups) == len(report.waves)

    def test_group_trace_stamps_ordered(self):
        _, _, tasks = build_stream(1, 30, 8)
        report = AsyncFrontierScheduler(window_size=16).run(tasks)
        for g in report.groups:
            assert 0.0 <= g.t_launch <= g.t_retire
        assert sum(len(g.tids) for g in report.groups) == 30

    def test_executor_reuse_hits_compile_cache(self):
        ex = GroupExecutor()
        for seed in (0, 0, 0):
            _, _, tasks = build_stream(seed, 20, 5)
            AsyncFrontierScheduler(window_size=16, executor=ex).run(tasks)
        # Same stream shape re-run: compiles stay bounded by distinct
        # (signature, batched) pairs, not by total dispatches.
        assert ex.stats.compiles <= 6
        assert ex.stats.tasks_run == 60

    def test_invalid_max_inflight(self):
        with pytest.raises(ValueError):
            AsyncFrontierScheduler(max_inflight=0)


class TestDispatchQueue:
    def _tasks(self, n):
        pool = BufferPool()
        out = []
        for i in range(n):
            a = pool.alloc((D,), np.float32, value=jnp.ones(D))
            b = pool.alloc((D,), np.float32, value=jnp.zeros(D))
            r, w = default_segments((a, a), (b,))
            out.append(Task(opcode="axpy", fn=_axpy, inputs=(a, a), outputs=(b,),
                            read_segments=r, write_segments=w))
        return out

    def test_stage_dedups_already_queued(self):
        q = DispatchQueue()
        tasks = self._tasks(4)
        assert q.stage(tasks) == 1  # one homogeneous bucket opened
        assert q.stage(tasks) == 0  # all queued already

    def test_stage_coalesces_batchable_siblings(self):
        """A sibling staged on a later scheduler iteration joins the
        existing bucket instead of fragmenting into its own group."""
        q = DispatchQueue()
        ex = GroupExecutor()
        tasks = self._tasks(6)  # all share one signature
        assert q.stage(tasks[:2]) == 1
        assert q.stage(tasks[2:5]) == 0  # merged into the open bucket
        q.flip(ex)
        assert len(q.pop()) == 5

    def test_flip_only_when_front_drained(self):
        q = DispatchQueue()
        ex = GroupExecutor()
        q.stage(self._tasks(2))
        assert q.flip(ex)
        q.stage(self._tasks(2))
        assert not q.flip(ex)  # front still holds the first group
        assert q.pop() is not None
        assert q.flip(ex)  # now the back buffer promotes
        assert q.pop() is not None
        assert q.pop() is None
        assert q.empty()

    def test_max_group_splits(self):
        q = DispatchQueue(max_group=3)
        ex = GroupExecutor()
        assert q.stage(self._tasks(8)) == 1  # one bucket; split at flip
        q.flip(ex)
        sizes = []
        while True:
            g = q.pop()
            if g is None:
                break
            sizes.append(len(g))
        assert sizes == [3, 3, 2]
