"""Dynamic & static DNN workloads: construction, ACS equivalence,
input-dependence of the task stream (paper §II-C)."""

import numpy as np
import pytest

from repro.core import TaskStream, WaveScheduler, run_serial
from repro.dyn import WORKLOADS


def run_workload(name, scheduler_fn, seed=0, input_seed=1):
    init_fn, build_fn, _dynamic = WORKLOADS[name]
    params = init_fn(seed)
    rng = np.random.RandomState(input_seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)
    stream = TaskStream()
    out = build_fn(params, stream, x)
    scheduler_fn(stream.tasks)
    return np.asarray(out.value), stream


ALL = sorted(WORKLOADS)


@pytest.mark.parametrize("name", ALL)
def test_builds_and_runs_finite(name):
    logits, stream = run_workload(name, lambda ts: WaveScheduler(32).run(ts))
    assert np.all(np.isfinite(logits))
    assert len(stream.tasks) >= 10  # many small kernels, as in the paper


@pytest.mark.parametrize("name", ["instanas", "squeezenet", "randwire", "condconv"])
def test_acs_matches_serial(name):
    ref, _ = run_workload(name, lambda ts: run_serial(ts))
    got, _ = run_workload(name, lambda ts: WaveScheduler(32).run(ts))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["instanas", "dynamic_routing"])
def test_dynamic_graphs_vary_with_input(name):
    init_fn, build_fn, dynamic = WORKLOADS[name]
    assert dynamic
    counts = set()
    for input_seed in range(6):
        params = init_fn(0)
        rng = np.random.RandomState(input_seed)
        x = rng.randn(1, 3, 32, 32).astype(np.float32) * (1 + input_seed)
        stream = TaskStream()
        build_fn(params, stream, x)
        counts.add(len(stream.tasks))
    assert len(counts) > 1, f"{name} stream should vary across inputs: {counts}"


@pytest.mark.parametrize("name", ["squeezenet", "nasnet"])
def test_static_graphs_do_not_vary(name):
    init_fn, build_fn, dynamic = WORKLOADS[name]
    assert not dynamic
    counts = set()
    for input_seed in range(4):
        params = init_fn(0)
        rng = np.random.RandomState(input_seed)
        x = rng.randn(1, 3, 32, 32).astype(np.float32)
        stream = TaskStream()
        build_fn(params, stream, x)
        counts.add(len(stream.tasks))
    assert len(counts) == 1


def test_parallel_branches_fuse():
    """SqueezeNet's expand1x1/expand3x3 run in one wave under ACS."""
    _, stream = run_workload("squeezenet", lambda ts: ts)
    report = WaveScheduler(window_size=32).run(stream.tasks)
    assert report.exec_stats["dispatches"] < len(stream.tasks)
    assert report.exec_stats["max_wave_width"] >= 2
