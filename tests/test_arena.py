"""Shape-class slab arena + arena device path on the REAL workloads.

The acceptance bar of DESIGN §2 A3's generalization: the device-resident
window must run the same sim-engine and dynamic-DNN streams the host
schedulers run — mixed shape classes, variable arity, row-view aliasing,
multi-output tasks — bit-identically to the serial baseline, in ONE
dispatch per stream.
"""

import numpy as np
import pytest
from _prophelper import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import (
    BufferPool,
    DeviceOpRegistry,
    DeviceWindowRunner,
    SlabArena,
    Task,
    TaskStream,
    make_scheduler,
    pad_shape,
    row_capacity,
    run_serial,
)
from repro.core.task import default_segments

PLAN_MODES = ("wave", "frontier")

# A few shape classes that exercise padding, collisions, and rank variety.
SHAPES = [(5,), (7,), (8,), (3, 6), (3, 8), (2, 4, 6)]
DTYPES = [np.float32, np.int32]


# ---------------------------------------------------------------------------
# Arena mechanics
# ---------------------------------------------------------------------------

class TestSlabArena:
    def test_pad_shape(self):
        assert pad_shape((5,), 8) == (8,)
        assert pad_shape((3, 6), 8) == (3, 8)
        assert pad_shape((8,), 8) == (8,)
        assert pad_shape((3, 6), 1) == (3, 6)
        assert pad_shape((), 8) == ()

    def test_shape_collision_shares_class(self):
        """(5,) and (7,) pad to (8,) -> same slab, distinct rows, and the
        per-operand true shape survives the round trip."""
        pool = BufferPool()
        a = pool.alloc((5,), np.float32, value=jnp.arange(5, dtype=jnp.float32))
        b = pool.alloc((7,), np.float32, value=jnp.arange(7, dtype=jnp.float32))
        arena = SlabArena(pad_multiple=8)
        ca, ra = arena.add(a)
        cb, rb = arena.add(b)
        assert ca == cb and ra != rb
        assert arena.n_classes() == 1
        slabs = arena.pack()
        # one row per buffer, physical capacity quantized (row_capacity)
        assert slabs[0].shape == (row_capacity(2), 8)
        assert len(arena.rows(0)) == 2
        arena.unpack(slabs)
        np.testing.assert_array_equal(np.asarray(a.value), np.arange(5, dtype=np.float32))
        np.testing.assert_array_equal(np.asarray(b.value), np.arange(7, dtype=np.float32))

    def test_dtype_splits_class(self):
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        f = pool.alloc((8,), np.float32, value=jnp.zeros(8))
        i = pool.alloc((8,), np.int32, value=jnp.zeros(8, jnp.int32))
        assert arena.add(f)[0] != arena.add(i)[0]

    def test_view_addressing_and_byte_view_rejection(self):
        pool = BufferPool()
        buf = pool.alloc((6, 4), np.float32, value=jnp.zeros((6, 4)))
        arena = SlabArena(pad_multiple=8)
        addr = arena.address(buf.row_view(2, 3))
        assert addr.is_view and addr.row_start == 2 and addr.row_count == 3
        assert addr.class_id == arena.add(buf)[0]
        with pytest.raises(ValueError, match="row views"):
            arena.address(buf.view(0, 16))  # raw byte view: no row semantics

    def test_padding_waste_metric(self):
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        arena.add(pool.alloc((6,), np.float32, value=jnp.zeros(6)))
        waste = arena.padding_waste()
        (entry,) = waste.values()
        assert entry["rows"] == 1
        assert entry["padded_elems_per_row"] == 8
        assert entry["used_elems"] == 6
        assert entry["waste_frac"] == 0.25
        assert arena.total_waste_frac() == pytest.approx(0.25)

    @given(st.lists(st.tuples(st.integers(0, len(SHAPES) - 1),
                              st.integers(0, len(DTYPES) - 1)),
                    min_size=1, max_size=12),
           st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_preserves_values_mixed_classes(self, picks, seed):
        """Property: pack -> execute (copy tasks) -> unpack preserves every
        buffer bit-exactly — untouched buffers through padding/slicing, and
        written buffers bit-identical to the serial baseline."""
        rng = np.random.RandomState(seed)

        def build():
            pool = BufferPool()
            bufs = []
            for si, di in picks:
                shape, dtype = SHAPES[si], DTYPES[di]
                val = (rng.randn(*shape) * 8).astype(dtype)
                bufs.append(pool.from_array(jnp.asarray(val)))
            # copy tasks within a shape/dtype class (same true shape)
            tasks = []
            by_key = {}
            for b in bufs:
                by_key.setdefault((tuple(b.shape), str(np.dtype(b.dtype))), []).append(b)
            for group in by_key.values():
                for src, dst in zip(group, group[1:]):
                    r, w = default_segments((src,), (dst,))
                    tasks.append(Task(opcode="copy", fn=lambda x: x + x.dtype.type(1),
                                      inputs=(src,), outputs=(dst,),
                                      read_segments=r, write_segments=w))
            return bufs, tasks

        state = rng.get_state()
        ref_bufs, ref_tasks = build()
        if ref_tasks:
            run_serial(ref_tasks)
        ref = [np.asarray(b.value) for b in ref_bufs]

        rng.set_state(state)
        dev_bufs, dev_tasks = build()
        if dev_tasks:
            DeviceWindowRunner(window_size=8).execute(dev_tasks, dev_bufs)
        else:  # no tasks: pure pack/unpack round trip
            arena = SlabArena()
            for b in dev_bufs:
                arena.add(b)
            arena.unpack(arena.pack())
        for b, r in zip(dev_bufs, ref):
            np.testing.assert_array_equal(np.asarray(b.value), r)


# ---------------------------------------------------------------------------
# Real workload equivalence (the ISSUE acceptance bar)
# ---------------------------------------------------------------------------

def sim_setup(seed=0, n_envs=4, group_size=2, steps=2):
    from repro.sim import ENVIRONMENTS, PhysicsEngine

    eng = PhysicsEngine(ENVIRONMENTS["cheetah"], n_envs=n_envs,
                        group_size=group_size, seed=seed)
    stream = TaskStream()
    eng.emit_batch(stream, steps)
    return eng, stream.tasks


def dyn_setup(seed=0):
    from repro.dyn import WORKLOADS

    init_fn, build_fn, _ = WORKLOADS["dynamic_routing"]
    rng = np.random.RandomState(seed)
    x = rng.randn(1, 3, 32, 32).astype(np.float32)
    params = init_fn(0)
    stream = TaskStream()
    out = build_fn(params, stream, x)
    return out, stream.tasks


class TestDeviceRunsRealWorkloads:
    @pytest.mark.parametrize("plan_mode", PLAN_MODES)
    def test_sim_stream_matches_serial(self, plan_mode):
        eng_ref, tasks_ref = sim_setup()
        run_serial(tasks_ref)
        ref = eng_ref.state_snapshot()

        eng_dev, tasks_dev = sim_setup()
        from repro.sim import register_device_kernels

        registry = DeviceOpRegistry()
        register_device_kernels(registry)  # strict: the fixed HW opcode set
        runner = DeviceWindowRunner(registry, window_size=32, plan_mode=plan_mode)
        report = runner.run(tasks_dev)

        np.testing.assert_array_equal(eng_dev.state_snapshot(), ref)
        assert report.exec_stats["dispatches"] == 1
        assert report.exec_stats["tasks_run"] == len(tasks_dev)
        assert report.arena_stats["n_classes"] >= 2
        assert report.window_stats["inserted"] == len(tasks_dev)
        # row-view aliasing classes recorded per opcode
        assert "joint_solve" in registry.classes_seen

    @pytest.mark.parametrize("plan_mode", PLAN_MODES)
    def test_dyn_stream_matches_serial(self, plan_mode):
        out_ref, tasks_ref = dyn_setup()
        run_serial(tasks_ref)
        ref = np.asarray(out_ref.value)

        out_dev, tasks_dev = dyn_setup()
        from repro.dyn.blocks import register_device_kernels

        registry = DeviceOpRegistry()
        register_device_kernels(registry)
        report = DeviceWindowRunner(registry, window_size=32,
                                    plan_mode=plan_mode).run(tasks_dev)

        np.testing.assert_array_equal(np.asarray(out_dev.value), ref)
        assert report.exec_stats["dispatches"] == 1
        assert report.arena_stats["n_classes"] >= 2
        assert 0.0 <= report.arena_stats["total_waste_frac"] < 1.0

    def test_make_scheduler_device_contract(self):
        """`make_scheduler("device")` returns a runner conforming to the
        SchedulerReport contract the host schedulers satisfy."""
        eng_ref, tasks_ref = sim_setup(steps=1)
        run_serial(tasks_ref)
        ref = eng_ref.state_snapshot()

        eng_dev, tasks_dev = sim_setup(steps=1)
        run = make_scheduler("device", window_size=32, plan_mode="frontier")
        report = run(tasks_dev)

        np.testing.assert_array_equal(eng_dev.state_snapshot(), ref)
        assert report.exec_stats["dispatches"] == 1
        assert report.window_stats["inserted"] == len(tasks_dev)
        assert 0.0 < report.occupancy_proxy() <= 1.0
        assert report.wall_seconds > 0
        assert report.plan_mode == "frontier"

    def test_make_scheduler_rejects_bad_plan_mode(self):
        with pytest.raises(ValueError, match="plan_mode"):
            make_scheduler("device", plan_mode="bogus")


class TestMultiOutputAndArity:
    def test_multi_output_task(self):
        """The arena path scatters every output of a multi-output task."""
        def split(x, y):
            return x + y, x - y

        def build():
            pool = BufferPool()
            a = pool.alloc((6,), np.float32, value=jnp.arange(6, dtype=jnp.float32))
            b = pool.alloc((6,), np.float32, value=jnp.ones(6))
            s = pool.alloc((6,), np.float32)
            d = pool.alloc((6,), np.float32)
            r, w = default_segments((a, b), (s, d))
            t = Task(opcode="split", fn=split, inputs=(a, b), outputs=(s, d),
                     read_segments=r, write_segments=w)
            return (s, d), [t]

        outs_ref, tasks_ref = build()
        run_serial(tasks_ref)
        outs_dev, tasks_dev = build()
        report = DeviceWindowRunner().run(tasks_dev)
        for dev, ref in zip(outs_dev, outs_ref):
            np.testing.assert_array_equal(np.asarray(dev.value), np.asarray(ref.value))
        assert report.exec_stats["dispatches"] == 1

    def test_signature_equal_view_and_buffer_do_not_group(self):
        """Regression: a full (2,4) buffer and a 2-row view of an (8,4)
        buffer are Task.signature-equal (same value shape) but need
        different gather code — lowering must split them into separate
        steps, not take the first task's addressing for both."""
        def bump(x):
            return x + 1.0

        def build():
            pool = BufferPool()
            small = pool.alloc((2, 4), np.float32,
                               value=jnp.full((2, 4), 10.0))
            big = pool.alloc((8, 4), np.float32,
                             value=jnp.full((8, 4), 100.0))
            outs = [pool.alloc((2, 4), np.float32) for _ in range(2)]
            tasks = []
            for src, dst in ((small, outs[0]), (big.row_view(2, 2), outs[1])):
                r, w = default_segments((src,), (dst,))
                tasks.append(Task(opcode="bump", fn=bump, inputs=(src,),
                                  outputs=(dst,), read_segments=r,
                                  write_segments=w))
            return outs, tasks

        outs_ref, tasks_ref = build()
        run_serial(tasks_ref)
        outs_dev, tasks_dev = build()
        report = DeviceWindowRunner().run(tasks_dev)
        assert report.exec_stats["dispatches"] == 1
        for dev, ref in zip(outs_dev, outs_ref):
            np.testing.assert_array_equal(np.asarray(dev.value),
                                          np.asarray(ref.value))

    def test_variable_arity_beyond_legacy_limit(self):
        """Arity > MAX_ARITY lowers fine through the arena (the sim
        integrate kernel relies on this)."""
        def sum5(a, b, c, d, e):
            return a + b + c + d + e

        def build():
            pool = BufferPool()
            ins = tuple(pool.alloc((4,), np.float32,
                                   value=jnp.full(4, float(i + 1)))
                        for i in range(5))
            out = pool.alloc((4,), np.float32)
            r, w = default_segments(ins, (out,))
            return out, [Task(opcode="sum5", fn=sum5, inputs=ins, outputs=(out,),
                              read_segments=r, write_segments=w)]

        out_ref, t_ref = build()
        run_serial(t_ref)
        out_dev, t_dev = build()
        DeviceWindowRunner().run(t_dev)
        np.testing.assert_array_equal(np.asarray(out_dev.value),
                                      np.asarray(out_ref.value))


# ---------------------------------------------------------------------------
# Row lifecycle: free / recycle / compact (DESIGN §2 A3 gap (2))
# ---------------------------------------------------------------------------

class TestRowLifecycle:
    def _mk(self, pool, n, shape=(6,), dtype=np.float32, base=0.0):
        return [pool.alloc(shape, dtype,
                           value=jnp.full(shape, base + i, dtype=dtype))
                for i in range(n)]

    def test_free_then_add_recycles_the_row(self):
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        a, b = self._mk(pool, 2)
        addr_a = arena.add(a)
        arena.add(b)
        assert arena.free(a) and a not in arena
        c = pool.alloc((6,), np.float32, value=jnp.zeros(6))
        assert arena.add(c) == addr_a  # reuse, not growth
        assert arena.recycled_rows == 1 and arena.freed_rows == 1
        assert len(arena.rows(0)) == 2  # slab never grew

    def test_free_unknown_buffer_is_noop(self):
        pool = BufferPool()
        arena = SlabArena()
        assert arena.free(pool.alloc((4,), np.float32, value=jnp.zeros(4))) is False
        assert arena.freed_rows == 0

    def test_recycled_packed_row_refreshed_on_pack_incremental(self):
        """A recycled row below the watermark holds the dead occupant's
        device bits; the next incremental pack must rewrite it from the new
        buffer's host value."""
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        a, b = self._mk(pool, 2)
        arena.add(a), arena.add(b)
        slabs = arena.pack()
        arena.free(a)
        c = pool.alloc((6,), np.float32, value=jnp.full(6, 42.0))
        cid, row = arena.add(c)
        slabs = arena.pack_incremental(slabs)
        assert slabs[cid].shape[0] == row_capacity(2)
        assert len(arena.rows(cid)) == 2
        np.testing.assert_array_equal(np.asarray(slabs[cid][row][:6]),
                                      np.full(6, 42.0, np.float32))

    def test_full_pack_zeroes_dead_rows_and_unpack_skips_them(self):
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        bufs = self._mk(pool, 3)
        for b in bufs:
            arena.add(b)
        arena.free(bufs[1])
        slabs = arena.pack()
        np.testing.assert_array_equal(np.asarray(slabs[0][1]), np.zeros(8))
        arena.unpack(slabs)  # must not touch the dead row's old buffer
        np.testing.assert_array_equal(np.asarray(bufs[1].value),
                                      np.full(6, 1.0, np.float32))

    def test_unpack_only_is_addressed_not_scanned(self):
        """unpack(only=...) resolves through the address map: exactly
        |only| rows written, released buffers silently skipped."""
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8)
        bufs = self._mk(pool, 4)
        for b in bufs:
            arena.add(b)
        slabs = arena.pack()
        arena.free(bufs[3])
        arena.unpack(slabs, only=[bufs[2], bufs[3]])
        assert arena.unpack_rows_written == 1  # bufs[3] released -> skipped

    def test_needs_compaction_threshold(self):
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8, compact_waste=0.5,
                          compact_min_rows=4)
        bufs = self._mk(pool, 4)
        for b in bufs:
            arena.add(b)
        arena.free(bufs[0])
        assert arena.needs_compaction() == []  # 1/4 < 0.5
        arena.free(bufs[1])
        assert arena.needs_compaction() == [0]  # 2/4 >= 0.5
        small = SlabArena(compact_min_rows=8)
        b = pool.alloc((6,), np.float32, value=jnp.zeros(6))
        small.add(b)
        small.free(b)
        assert small.needs_compaction() == []  # under min_rows floor

    def test_compact_gathers_device_values_and_remaps(self):
        """Compaction drops dead rows from the materialized slab WITHOUT a
        host round-trip, remaps surviving addresses densely in old order,
        and bumps the class generation."""
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8, compact_min_rows=2)
        bufs = self._mk(pool, 6)
        for b in bufs:
            arena.add(b)
        slabs = arena.pack()
        # poison host values: post-compaction unpack must read DEVICE rows
        for b in bufs:
            b.value = jnp.full(6, -99.0)
        for i in (0, 2, 4):
            arena.free(bufs[i])
        assert arena.needs_compaction() == [0]
        slabs, moved = arena.compact(slabs)
        assert moved == {0: {1: 0, 3: 1, 5: 2}}
        assert arena.generation == 1 and arena.class_generation(0) == 1
        assert arena.compactions == 1
        assert slabs[0].shape[0] == row_capacity(3) and len(arena.rows(0)) == 3
        assert arena.free_rows() == 0
        for b in (bufs[1], bufs[3], bufs[5]):
            cid, row = arena.add(b)  # idempotent lookup of the new address
            np.testing.assert_array_equal(
                np.asarray(slabs[cid][row][:6]),
                np.full(6, float(bufs.index(b)), np.float32))

    def test_compact_keeps_unpacked_tail_on_host(self):
        """Rows beyond the watermark were never materialized: compaction
        must not invent device values for them — the next incremental pack
        appends them from host as usual."""
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8, compact_min_rows=2)
        a, b = self._mk(pool, 2)
        arena.add(a), arena.add(b)
        slabs = arena.pack()  # watermark = 2
        arena.free(a)
        tail = pool.alloc((6,), np.float32, value=jnp.full(6, 7.0))
        # recycles a's row -> no unpacked tail yet; free b to force waste
        arena.add(tail)
        arena.free(b)
        c = self._mk(pool, 1, base=30.0)[0]
        arena.add(c)
        d = self._mk(pool, 1, base=40.0)[0]
        arena.add(d)  # grows: row 2, beyond current watermark
        slabs = arena.pack_incremental(slabs)  # watermark = 3
        arena.free(c)
        arena.free(tail)
        slabs, moved = arena.compact(slabs)
        slabs = arena.pack_incremental(slabs)
        arena.unpack(slabs)
        np.testing.assert_array_equal(np.asarray(d.value), np.full(6, 40.0))

    @given(st.lists(st.integers(0, 2), min_size=1, max_size=60),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_lifecycle_never_aliases_live_rows(self, ops, seed):
        """Property: under any add/free/compact interleaving, live buffers
        occupy distinct rows, free-list rows are exactly the dead ones, and
        packed slabs always reproduce every live host value."""
        rng = np.random.RandomState(seed)
        pool = BufferPool()
        arena = SlabArena(pad_multiple=8, compact_min_rows=2)
        live = []
        expected = {}
        slabs = None
        counter = [0]
        for op in ops:
            if op == 0 or not live:  # add
                counter[0] += 1
                b = pool.alloc((5,), np.float32,
                               value=jnp.full(5, float(counter[0])))
                arena.add(b)
                live.append(b)
                expected[id(b)] = float(counter[0])
            elif op == 1:  # free a random live buffer
                b = live.pop(rng.randint(len(live)))
                assert arena.free(b)
            else:  # compact (threshold-driven)
                slabs, _ = arena.compact(slabs)
            if rng.rand() < 0.4:
                slabs = arena.pack_incremental(slabs)
        # no aliasing: every live buffer has a unique address
        addrs = [arena.add(b) for b in live]
        assert len(set(addrs)) == len(addrs)
        # free-list accounting
        assert arena.live_rows() == len(live)
        assert arena.live_rows() + arena.free_rows() == \
            sum(len(arena.rows(c)) for c in range(arena.n_classes()))
        # every live value survives the round trip
        slabs = arena.pack_incremental(slabs)
        arena.unpack(slabs)
        for b in live:
            np.testing.assert_array_equal(
                np.asarray(b.value), np.full(5, expected[id(b)], np.float32))


class TestCrossDevicePinnedSlabs:
    """A mesh shard pins its session's slabs to its own device, but the
    buffers fed to it may hold arrays committed to ANOTHER device — a
    shared buffer last written by a different shard's dispatch. Every
    in-place slab update must re-commit the incoming rows to the slab's
    device first, or jax raises its incompatible-devices error (this
    crashed mesh serving of mixed-priority hazard streams under
    ``--xla_force_host_platform_device_count=8``)."""

    pytestmark = pytest.mark.skipif(
        jax.device_count() < 2, reason="needs >= 2 devices")

    def _pinned(self, pool, arena):
        a = pool.alloc((6,), np.float32, value=jnp.zeros(6))
        arena.add(a)
        return a, [jax.device_put(s, jax.devices()[1]) for s in arena.pack()]

    def _committed(self, fill):
        return jax.device_put(jnp.full(6, fill, jnp.float32),
                              jax.devices()[0])

    def test_pack_incremental_appends_foreign_rows(self):
        pool, arena = BufferPool(), SlabArena(pad_multiple=8)
        _, slabs = self._pinned(pool, arena)
        b = pool.alloc((6,), np.float32, value=self._committed(7.0))
        cid, row = arena.add(b)
        slabs = arena.pack_incremental(slabs)
        np.testing.assert_array_equal(np.asarray(slabs[cid][row][:6]),
                                      np.full(6, 7.0, np.float32))

    def test_pack_incremental_refreshes_recycled_foreign_row(self):
        pool, arena = BufferPool(), SlabArena(pad_multiple=8)
        a, slabs = self._pinned(pool, arena)
        arena.free(a)
        c = pool.alloc((6,), np.float32, value=self._committed(9.0))
        cid, row = arena.add(c)  # recycled below the watermark
        slabs = arena.pack_incremental(slabs)
        np.testing.assert_array_equal(np.asarray(slabs[cid][row][:6]),
                                      np.full(6, 9.0, np.float32))

    def test_update_rows_with_foreign_value(self):
        pool, arena = BufferPool(), SlabArena(pad_multiple=8)
        a, slabs = self._pinned(pool, arena)
        a.value = self._committed(3.0)
        addr = arena.address(a)
        slabs = arena.update_rows(slabs, [a])
        np.testing.assert_array_equal(
            np.asarray(slabs[addr.class_id][addr.row][:6]),
            np.full(6, 3.0, np.float32))
