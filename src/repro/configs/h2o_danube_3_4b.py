"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA window 4096.
[arXiv:2401.16818; unverified] — all-local => long_500k applicable."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    pattern_unit=("attn_local",),
    window=4096,
    tied_embeddings=True,
    source="arXiv:2401.16818; unverified",
)
