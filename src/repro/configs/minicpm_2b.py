"""minicpm-2b [dense]: llama-like with WSD schedule.
40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
[arXiv:2404.06395; hf] — the WSD LR schedule lives in optim/schedules."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab=122753,
    pattern_unit=("attn_global",),
    embed_scale=True,
    tied_embeddings=True,
    source="arXiv:2404.06395; hf",
)
