"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2:1 pattern.
26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000.
[arXiv:2402.19427; hf] — pattern unit (rglru, rglru, attn_local), local
window 2048; 26 = 2 prefix recurrent layers + 8 x unit."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern_unit=("rglru", "rglru", "attn_local"),
    window=2048,
    rglru_width=2560,
    embed_scale=True,
    tied_embeddings=True,
    source="arXiv:2402.19427; hf",
)
