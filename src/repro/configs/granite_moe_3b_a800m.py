"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8)
d_expert=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — experts padded 40 -> 48
so E % TP(16) == 0 (padded experts receive no tokens; DESIGN.md §6)."""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    pattern_unit=("attn_global",),
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0),
    tied_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
