"""Assigned architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` with the exact published configuration
(sources inline). ``SHAPES`` defines the assigned input-shape grid and
``cells(cfg)`` the applicable (shape -> step kind) set, with long_500k
restricted to sub-quadratic archs (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from ..models.config import ArchConfig

from . import (
    deepseek_v2_236b,
    falcon_mamba_7b,
    gemma2_27b,
    granite_moe_3b_a800m,
    h2o_danube_3_4b,
    minicpm_2b,
    mistral_large_123b,
    musicgen_large,
    paligemma_3b,
    recurrentgemma_2b,
)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        musicgen_large, recurrentgemma_2b, falcon_mamba_7b, deepseek_v2_236b,
        granite_moe_3b_a800m, minicpm_2b, mistral_large_123b, h2o_danube_3_4b,
        gemma2_27b, paligemma_3b,
    )
}

# (shape name, seq_len, global_batch, step kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(cfg: ArchConfig) -> List[str]:
    """Applicable shapes for this arch (skips noted in DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


__all__ = ["ARCHS", "SHAPES", "get_config", "cells"]
