"""gemma2-27b [dense]: alternating local/global attention + logit softcaps.
46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, window 4096,
attn softcap 50, final softcap 30. [arXiv:2408.00118; hf]"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    pattern_unit=("attn_local", "attn_global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    tied_embeddings=True,
    source="arXiv:2408.00118; hf",
)
