"""paligemma-3b [vlm]: SigLIP vision prefix + gemma decoder backbone.
18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
[arXiv:2407.07726; hf] — vision frontend is a stub: ``input_specs``
supplies precomputed patch embeddings; the 256-token image prefix is
attended bidirectionally (prefix-LM)."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    pattern_unit=("attn_global",),
    frontend="vision_stub",
    prefix_len=256,
    embed_scale=True,
    tied_embeddings=True,
    source="arXiv:2407.07726; hf",
)
