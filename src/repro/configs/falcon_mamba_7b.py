"""falcon-mamba-7b [ssm]: attention-free Mamba-1 stack.
64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, d_inner=8192.
[arXiv:2410.05355; unverified]"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # attention-free; kept for config uniformity
    n_kv_heads=1,
    d_ff=0,               # mamba blocks have no separate FFN
    vocab=65024,
    pattern_unit=("mamba",),
    ssm_state=16,
    expand=2,             # d_inner = 8192
    d_conv=4,
    tied_embeddings=True,
    source="arXiv:2410.05355; unverified",
)
