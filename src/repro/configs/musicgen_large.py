"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.
48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf] — backbone only; the EnCodec frontend is a stub
(``input_specs`` supplies precomputed frame embeddings)."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,          # 32 x 64 = 2048
    d_ff=8192,
    vocab=2048,           # EnCodec codebook size
    pattern_unit=("attn_global",),
    tied_embeddings=False,
    frontend="audio_stub",
    source="arXiv:2306.05284; hf",
)
