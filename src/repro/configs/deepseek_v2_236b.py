"""deepseek-v2-236b [moe]: MLA attention + fine-grained MoE.
60L d_model=5120 128H d_ff(dense layer 1)=12288 vocab=102400.
MLA: kv_lora=512 (+64 decoupled rope), q_lora=1536, 128/128 nope/v dims.
MoE: 2 shared + 160 routed experts, top-6, d_expert=1536; layer 1 dense.
[arXiv:2405.04434; hf]"""

from ..models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA reconstructs per-head KV from the latent
    head_dim=128,
    d_ff=12288,           # dense-FFN dim (first layer + sizing reference)
    vocab=102400,
    pattern_unit=("mla",),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1),
    tied_embeddings=False,
    source="arXiv:2405.04434; hf",
)
