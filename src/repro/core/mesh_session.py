"""Mesh-sharded device window: per-device slab shards behind one global
admission plane (DESIGN §12).

Everything below `DeviceSession` runs on ONE device: one slab arena, one
dispatch stream, one plan cache. :class:`MeshDeviceSession` partitions the
live window across a JAX device mesh (``launch.mesh.make_window_mesh``):

* each **shard** is a full `DeviceSession` — its own arena (a shard-local
  address space), plan/program caches, and ready-queue epoch executor
  (``plan_mode="loop"`` unchanged) — pinned to one mesh device via the
  session's ``device=`` commitment, so every shard owns a dispatch
  stream;
* the **admission plane** is the outer scheduling window: producers
  submit in program order exactly as with any session, and each epoch the
  plane drains the window in program order, replays a fresh
  :class:`~.scoreboard.IntervalScoreboard` over the epoch to recover each
  task's exact RAW producers (``probe_writers``) and full RAW/WAR/WAW
  hazard set (``insert``), and **places** the task:

  1. a task with same-epoch RAW producers goes to its latest producer's
     shard (dependent chains never leave their device — the placement
     invariant the property tests pin);
  2. else any same-epoch hazard upstream (WAR/WAW) decides the same way;
  3. else **affinity**: the shard that owns (last wrote) one of the
     task's operand buffers — this keeps a decode chain whose epochs
     arrive one step at a time on its device without any same-epoch
     edge;
  4. else **priority-aware balance**: the shard with the least resident
     equal-or-more-urgent work for the task's priority bucket, total
     load as tie-break (new independent chains spread out; urgent
     chains additionally avoid piling onto a shard already busy with
     urgent work — DESIGN §13). With one priority class this is exactly
     least-loaded.

* within an epoch, tasks stream to their shards in **sub-epochs**: the
  plane walks program order and cuts a barrier only when a task touches a
  *base buffer* another shard wrote (or writes one another shard read) in
  the current sub-epoch — inside a sub-epoch no cross-shard write
  conflicts exist at whole-buffer granularity (stricter than hazards:
  disjoint row-views of one buffer must not split row ownership across
  shards), so shards dispatch independently (concurrent streams on real
  multi-device hardware);
* only true **cross-shard edges** move data, through a
  :class:`ShardLink` at sub-epoch boundaries. The link selects a
  transfer mode per session (``transfer_mode="auto"`` probes the backend
  once): **d2d** peer-copies the owning shard's slab row straight onto
  the consumer's slab (``jax.device_put`` between pinned devices — no
  host hop, the row arrives device-authoritative exactly as if the
  consumer had written it), while **staged** is the host fallback — the
  owner syncs the row back (``sync_buffers``, a counted d2h tagged
  ``mesh-transfer``), the consumer marks it host-authoritative
  (``mark_host_dirty``) and re-uploads on its next dispatch (a counted
  h2d, same tag). Rows the owner holds only host-side fall back to
  staged per-row even in d2d mode. Every copy lands in the
  :class:`~.arena.ShardTransferTable` — source/destination shard, shape
  class, bytes, mode — so the capacity claims in ``bench_serving`` are
  honest net of transfer traffic. A per-buffer copy-set memoizes clean
  replicas (a weight buffer read by many shards ships once per shard,
  not once per epoch), and a write **invalidates** every other copy
  holder's authoritative claim (``invalidate_row``) so a superseded d2d
  replica can never clobber the fresh value at a later sync.
* shard drains **overlap** (``overlap_drains=True``): a sub-epoch
  launches every involved shard's epoch back-to-back with retirement
  deferred (``DeviceSession.launch``), then retires them through a
  non-blocking round-robin ``poll_inflight`` pump — independent shards'
  dispatches are genuinely concurrent on multi-device hardware instead
  of serialized by a host-side drain loop. ``drain_overlap`` records the
  max shards simultaneously in flight; a stall raises only when a full
  round-robin pass (plus one blocking poll) advances nothing.

Placement is the CAPACITY mechanism, not just a traffic optimization: a
single interleaved window keeps re-tracing (spec subsets × shape
signatures churn epoch to epoch), while per-chain shard placement keeps
each shard's working set small and structurally stable — near-zero
steady-state compiles per shard (measured in ``bench_serving``'s
``mesh_scaling`` section) — and on multi-device hardware the per-shard
dispatch streams additionally overlap.

Bit-identity: placement only decides WHERE a task runs; ordering comes
from program order + the same interval-hazard semantics every other
session uses, so the differential matrix holds mesh == run_serial
bit-exactly at any shard count, including shard counts above the device
count (shards then share devices round-robin — the logical-shard mode the
default 1-device test environment exercises).
"""

from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .arena import ShardTransferTable
from .buffers import Buffer
from .device_dispatch import DeviceOpRegistry, DeviceSession
from .executors import ExecStats
from .scheduler import SchedulerReport
from .scoreboard import IntervalScoreboard
from .session import SchedulerSession
from .task import Task, operand_base

__all__ = ["MeshDeviceSession", "ShardLink"]


class ShardLink:
    """Cross-shard row mover: the transfer layer between a mesh session's
    per-device shards (DESIGN §12).

    One link per session. ``mode`` selects the path:

    * ``"d2d"`` — the owner exports its device-resident slab row
      (:meth:`DeviceSession.export_row`, a lazy slice that never blocks)
      and the destination imports it (:meth:`DeviceSession.import_row`,
      a ``jax.device_put`` peer copy committed onto the destination's
      pinned device) — no host round-trip, no ``host_syncs``;
    * ``"staged"`` — the original host hop (owner d2h, destination marks
      host-dirty and re-uploads at its next dispatch), both halves tagged
      ``mesh-transfer`` in the sync audit;
    * ``"auto"`` — probe once at construction: a trial peer copy between
      the first two distinct shard devices selects ``d2d`` if the backend
      lands it on the target device, ``staged`` otherwise (the fallback
      matrix for backends without p2p).

    Even under ``d2d``, a row whose authoritative value lives host-side
    (host-fallback writes, never-dispatched buffers) falls back to the
    staged path per-row — ``d2d_fallbacks`` counts those. Every move is
    recorded in the :class:`~.arena.ShardTransferTable` with its actual
    mode, so the byte audit stays exact on both paths.
    """

    MODES = ("auto", "d2d", "staged")

    def __init__(self, shards: Sequence[DeviceSession],
                 table: ShardTransferTable, mode: str = "auto"):
        if mode not in self.MODES:
            raise ValueError(
                f"transfer_mode must be one of {self.MODES}, got {mode!r}")
        self.shards = list(shards)
        self.table = table
        self.requested_mode = mode
        self.selected_mode = (mode if mode != "auto"
                              else ("d2d" if self._probe_p2p() else "staged"))
        self.d2d_moves = 0
        self.staged_moves = 0
        self.d2d_fallbacks = 0

    def _probe_p2p(self) -> bool:
        """One-shot backend capability probe: can a committed array move
        between two distinct shard devices with ``jax.device_put``? A
        single-device mesh trivially supports the d2d path (the peer copy
        degenerates to a same-device put)."""
        devs: List[Any] = []
        for sh in self.shards:
            d = sh.device
            if d is not None and all(d is not e for e in devs):
                devs.append(d)
        if not devs:
            return False  # no pinned devices: nothing to commit a row onto
        if len(devs) == 1:
            return True
        try:
            import jax
            import jax.numpy as jnp

            probe = jax.device_put(jnp.zeros((8,), jnp.float32), devs[0])
            peer = jax.device_put(probe, devs[1])
            jax.block_until_ready(peer)
            (landed,) = peer.devices()
            return landed == devs[1]
        except Exception:
            return False

    def move(self, base: Buffer, owner: int, dest: int) -> str:
        """Move ``base``'s row from shard ``owner`` to shard ``dest``;
        returns the mode actually used (``"d2d"`` or ``"staged"``)."""
        src, dst = self.shards[owner], self.shards[dest]
        label = src.arena.class_of(base).label
        nbytes = src.arena.row_nbytes(base)
        if self.selected_mode == "d2d":
            row = src.export_row(base)
            if row is not None and dst.import_row(base, row):
                self.d2d_moves += 1
                self.table.record(owner, dest, label, nbytes, mode="d2d")
                return "d2d"
            self.d2d_fallbacks += 1
        src.sync_buffers([base], tags=("mesh-transfer",))
        dst.mark_host_dirty(base, tag="mesh-transfer")
        self.staged_moves += 1
        self.table.record(owner, dest, label, nbytes, mode="staged")
        return "staged"

    def stats(self) -> Dict[str, Any]:
        return {
            "transfer_mode": self.selected_mode,
            "transfer_mode_requested": self.requested_mode,
            "d2d_moves": self.d2d_moves,
            "staged_moves": self.staged_moves,
            "d2d_fallbacks": self.d2d_fallbacks,
        }


class MeshDeviceSession(SchedulerSession):
    """A live-fed session whose window is sharded across a device mesh.

    ``n_shards=None`` opens one shard per visible device (via
    ``launch.mesh.make_window_mesh``); an explicit ``n_shards`` may exceed
    the device count — shards then share devices round-robin, which keeps
    the whole path testable on a single-device host. ``devices=None``
    derives the device list from the window mesh; pass an explicit list to
    pin shards yourself. ``transfer_mode`` selects the cross-shard edge
    path (:class:`ShardLink`): ``"auto"`` probes for d2d peer copies and
    falls back to host staging, ``"d2d"``/``"staged"`` force a path (the
    benchmarks force both sides of the A/B). ``overlap_drains=False``
    reverts sub-epoch drains to the sequential one-shard-at-a-time loop
    (the overlap A/B baseline). The remaining knobs are forwarded to each
    per-shard :class:`DeviceSession`.
    """

    def __init__(
        self,
        window_size: int = 32,
        n_shards: Optional[int] = None,
        registry: Optional[DeviceOpRegistry] = None,
        plan_mode: str = "loop",
        devices: Optional[Sequence[Any]] = None,
        history_limit: Optional[int] = None,
        loop_pallas: Optional[bool] = None,
        plan_cache_limit: Optional[int] = 512,
        pad_payloads: bool = False,
        transfer_mode: str = "auto",
        overlap_drains: bool = True,
    ):
        super().__init__(window_size, history_limit=history_limit)
        if devices is None:
            from ..launch.mesh import make_window_mesh

            devices = list(make_window_mesh().devices.flat)
        if n_shards is None:
            n_shards = len(devices)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.devices = list(devices)
        self.registry = (registry if registry is not None
                         else DeviceOpRegistry(strict=False))
        self.plan_mode = plan_mode
        self._shards: List[DeviceSession] = [
            DeviceSession(
                window_size=window_size,
                registry=self.registry,
                plan_mode=plan_mode,
                history_limit=history_limit,
                loop_pallas=loop_pallas,
                plan_cache_limit=plan_cache_limit,
                pad_payloads=pad_payloads,
                device=self.devices[i % len(self.devices)],
            )
            for i in range(n_shards)
        ]
        # id(buffer) -> shard that last WROTE it (the owner: its slab row
        # is authoritative while device-dirty), and -> the shard set
        # holding a CURRENT copy (owner + shards a staged transfer already
        # reached). A write collapses the copy set to the writer.
        self._owner: Dict[int, int] = {}
        self._copies: Dict[int, Set[int]] = {}
        # id(buffer) -> shard that first READ it: read-only working sets
        # (tenant weights, shared tables) are never written, so write
        # ownership can't see them — the read home is what keeps a
        # tenant's requests landing where its weights already reside.
        self._read_home: Dict[int, int] = {}
        # Running per-shard placement totals (the least-loaded signal),
        # plus per-shard totals broken down by priority bucket: the
        # balance branch prefers the shard with the least equal-or-more-
        # urgent work for the incoming task's bucket — priority beats
        # raw least-loaded on tie (DESIGN §13) — with the plain total as
        # tie-break so the single-class default reduces exactly to the
        # old least-loaded rule.
        self._placed: List[int] = [0] * n_shards
        self._placed_by_bucket: List[Dict[int, int]] = [
            {} for _ in range(n_shards)]
        self.transfer_table = ShardTransferTable()
        self.link = ShardLink(self._shards, self.transfer_table,
                              mode=transfer_mode)
        self.overlap_drains = overlap_drains
        # Max shards simultaneously in flight inside one sub-epoch drain —
        # the structural proof the overlapped pump actually overlaps.
        self.drain_overlap = 0
        self.cross_shard_edges = 0
        self.sub_epoch_barriers = 0
        self.epochs = 0
        self.placements: Dict[str, int] = {
            "raw_upstream": 0, "hazard_upstream": 0,
            "affinity": 0, "read_affinity": 0, "balance": 0,
        }

    # -- placement plane ---------------------------------------------------
    def _place_epoch(self, order: List[Task]) -> Dict[int, int]:
        """Decide every task's shard for one epoch (program order in).

        Replays a fresh scoreboard over just this epoch: ``probe_writers``
        (before the task's own insert) yields its exact same-epoch RAW
        producers, ``insert`` the full hazard set. Returns
        ``shard_of_tid``."""
        sb = IntervalScoreboard()
        pos: Dict[int, int] = {}
        shard_of: Dict[int, int] = {}
        for i, t in enumerate(order):
            raw = sb.probe_writers(t.read_segments)
            haz = sb.insert(t.tid, t.read_segments, t.write_segments)
            pos[t.tid] = i
            if raw:
                latest = max(raw, key=lambda tid: pos[tid])
                shard, reason = shard_of[latest], "raw_upstream"
            elif haz:
                latest = max(haz, key=lambda tid: pos[tid])
                shard, reason = shard_of[latest], "hazard_upstream"
            else:
                bids = [id(operand_base(op)) for op in
                        tuple(t.inputs) + tuple(t.outputs)]
                owners = [self._owner[b] for b in bids if b in self._owner]
                homes = [self._read_home[b] for b in bids
                         if b in self._read_home]
                if owners:
                    # the most-represented owning shard (ties: first seen)
                    shard = max(set(owners), key=owners.count)
                    reason = "affinity"
                elif homes:
                    # read-only working-set locality (e.g. a new request
                    # whose only live-in is its tenant's weights)
                    shard = max(set(homes), key=homes.count)
                    reason = "read_affinity"
                else:
                    # Priority-aware balance: least resident urgency for
                    # this task's bucket first (so a high-priority chain
                    # lands away from other urgent work even when raw
                    # totals tie), total load second, shard index last.
                    # Single-class default: both components equal the old
                    # least-loaded count — placement unchanged.
                    bucket = t.priority
                    shard = min(
                        range(self.n_shards),
                        key=lambda s: (
                            sum(c for b, c in
                                self._placed_by_bucket[s].items()
                                if b <= bucket),
                            self._placed[s], s))
                    reason = "balance"
            shard_of[t.tid] = shard
            for op in t.inputs:
                self._read_home.setdefault(id(operand_base(op)), shard)
            self._placed[shard] += 1
            by_bucket = self._placed_by_bucket[shard]
            by_bucket[t.priority] = by_bucket.get(t.priority, 0) + 1
            self.placements[reason] += 1
        return shard_of

    # -- cross-shard staging ----------------------------------------------
    def _stage_transfers(self, task: Task, shard: int) -> None:
        """Materialize the cross-shard edges of one task before its shard
        dispatches: every operand owned by another shard moves through the
        :class:`ShardLink` — a device-to-device row copy when the link
        selected d2d, the host-staged hop otherwise. Memoized per
        (buffer, shard) through the copy set until the next write; a write
        collapses the copy set to the writer and drops every superseded
        copy's authoritative claim (write-owner invalidation — a stale d2d
        replica must never win a later sync race against the fresh row)."""
        for op in tuple(task.inputs) + tuple(task.outputs):
            base = operand_base(op)
            bid = id(base)
            owner = self._owner.get(bid)
            if owner is not None and owner != shard:
                self.cross_shard_edges += 1
                if shard not in self._copies.get(bid, ()):
                    self.link.move(base, owner, shard)
                    self._copies.setdefault(bid, {owner}).add(shard)
        for op in task.outputs:
            base = operand_base(op)
            bid = id(base)
            for s in self._copies.get(bid, ()):
                if s != shard:
                    self._shards[s].invalidate_row(base)
            self._owner[bid] = shard
            self._copies[bid] = {shard}

    # -- the epoch ---------------------------------------------------------
    def _dispatch_sub_epoch(self, sub: List[Tuple[Task, int]]) -> None:
        """One barrier-free slice: stage its cross-shard inputs, feed each
        shard its tasks (program order preserved per shard), drain every
        involved shard, then retire through the outer plane.

        When an outer observer watches the slice (listener, per-task
        callback, or ticket), outer retirement rides each INNER session's
        per-task retirement instead of firing wholesale after the drain: a
        decode chain's callbacks must observe each intermediate slot value
        exactly as they would under `DeviceSession` — and the inner
        watchers this registers are what make the inner device path sync
        values back before the callback reads them. Unwatched slices keep
        the fast path: no per-task observation, no forced syncs, one
        wholesale retirement sweep in program order."""
        watched = bool(self._listeners) or any(
            t.tid in self._watchers or t.tid in self._tickets
            for t, _ in sub)
        involved: List[int] = []
        for task, shard in sub:
            self._stage_transfers(task, shard)
            if shard not in involved:
                involved.append(shard)
            if watched:
                self._shards[shard].submit(task, on_retire=self._note_retired)
            else:
                self._shards[shard].submit(task)
        self.waves.append([t.tid for t, _ in sub])
        if self.overlap_drains:
            self._drain_overlapped(involved)
        else:
            self._drain_sequential(involved)
        if not watched:
            for task, _ in sub:
                self._note_retired(task)

    def _drain_sequential(self, involved: List[int]) -> None:
        """The pre-overlap baseline: block each involved shard to empty in
        turn (kept as the A/B control for the overlapped pump)."""
        for shard in involved:
            sh = self._shards[shard]
            while sh.outstanding:
                before = sh.outstanding
                sh.poll()
                if sh.outstanding == before:
                    raise RuntimeError(
                        f"mesh shard {shard} stalled with "
                        f"{sh.outstanding} tasks outstanding")

    def _drain_overlapped(self, involved: List[int]) -> None:
        """Launch-all-then-poll-round-robin: every involved shard's epoch
        is dispatched back-to-back with retirement deferred
        (:meth:`DeviceSession.launch`), so independent shards' dispatches
        are in flight concurrently; a non-blocking ``poll_inflight``
        round-robin then retires segments as they land. A shard idle in
        one round is NOT a stall while others advance: only when a full
        pass progresses nothing does the pump block on the oldest pending
        shard, and only a fruitless blocking poll raises — with every
        pending shard's outstanding count in the error."""
        for shard in involved:
            self._shards[shard].launch()
        pending = [s for s in involved if self._shards[s].outstanding]
        self.drain_overlap = max(self.drain_overlap, len(pending))
        while pending:
            progressed = False
            for s in list(pending):
                sh = self._shards[s]
                if sh.poll_inflight(block=False) > 0:
                    progressed = True
                if sh.outstanding and not sh.inflight_segments:
                    # Backlog past the shard window: dispatch the next
                    # epoch (still deferred) instead of spinning on it.
                    progressed = sh.launch() or progressed
                if not sh.outstanding:
                    pending.remove(s)
                    progressed = True
            if pending and not progressed:
                sh = self._shards[pending[0]]
                if sh.poll_inflight(block=True) == 0:
                    counts = {s: self._shards[s].outstanding
                              for s in pending}
                    raise RuntimeError(
                        "mesh drain stalled: a full round-robin pass "
                        "advanced no shard; outstanding per shard: "
                        f"{counts}")
                if not sh.outstanding:
                    pending.pop(0)

    def _pump(self) -> bool:
        if self.window.idle():
            return False
        order = self.window.drain_program_order()
        shard_of = self._place_epoch(order)
        # Sub-epoch walk: cut only at cross-shard conflicts within the
        # current slice; same-shard hazards ride the shard's own window.
        # The conflict test is at BASE-BUFFER granularity, not hazard
        # (segment) granularity: two tasks writing disjoint row-views of
        # the same buffer have no hazard, but on different shards they
        # would split row ownership of one slab allocation — each shard's
        # copy partially fresh and the host image never whole. A barrier
        # sequences them so the staging protocol migrates whole rows.
        # Read-read sharing across shards stays barrier-free.
        sub: List[Tuple[Task, int]] = []
        readers: Dict[int, Set[int]] = {}  # id(base) -> shards reading
        writers: Dict[int, Set[int]] = {}  # id(base) -> shards writing
        for t in order:
            shard = shard_of[t.tid]
            rb = {id(operand_base(op)) for op in t.inputs}
            wb = {id(operand_base(op)) for op in t.outputs}
            conflict = any(s != shard
                           for b in rb | wb
                           for s in writers.get(b, ())) or \
                       any(s != shard
                           for b in wb
                           for s in readers.get(b, ()))
            if conflict:
                self._dispatch_sub_epoch(sub)
                self.sub_epoch_barriers += 1
                sub, readers, writers = [], {}, {}
            for b in rb:
                readers.setdefault(b, set()).add(shard)
            for b in wb:
                writers.setdefault(b, set()).add(shard)
            sub.append((t, shard))
        if sub:
            self._dispatch_sub_epoch(sub)
        self.epochs += 1
        return True

    # -- retirement observation --------------------------------------------
    def _pre_observe_retired(self, task: Task) -> None:
        # A late observer of an already-retired task reads the task's
        # operand values host-side: sync exactly those buffers on the
        # shards that OWN them (the owner's claim is the authoritative
        # value; non-owner copies hold the same bits), not a wholesale
        # O(shards) full-session sweep per observer.
        per_shard: Dict[int, List[Buffer]] = {}
        for op in tuple(task.inputs) + tuple(task.outputs):
            base = operand_base(op)
            owner = self._owner.get(id(base))
            if owner is not None:
                per_shard.setdefault(owner, []).append(base)
        for shard, bufs in per_shard.items():
            self._shards[shard].sync_buffers(
                bufs, tags=DeviceSession._tags_of([task]))

    def shard_of(self, buf: Buffer) -> Optional[int]:
        """The shard currently owning (last to write) ``buf``, or None if
        no shard has written it. Serving uses this for per-device slot
        accounting: a request slot's owner is the device its chain ran on."""
        with self._lock:
            return self._owner.get(id(buf))

    # -- row lifecycle -----------------------------------------------------
    def release_buffer(self, buf: Buffer) -> bool:
        """Forward a producer's release to every shard (each holds its own
        row when the buffer crossed shards) and drop the ownership entry.
        True if any shard recycled a row."""
        with self._lock:
            freed = False
            for sh in self._shards:
                freed = sh.release_buffer(buf) or freed
            self._owner.pop(id(buf), None)
            self._copies.pop(id(buf), None)
            self._read_home.pop(id(buf), None)
            return freed

    # -- lifecycle ---------------------------------------------------------
    def sync(self) -> None:
        """Force every shard's device-resident values back to host."""
        with self._lock:
            for sh in self._shards:
                sh.sync()

    def flush(self) -> None:
        super().flush()
        for sh in self._shards:
            sh.flush()

    def session_stats(self) -> Dict[str, Any]:
        """Mesh counters + every shard's full ``session_stats()``. The
        aggregate keys mirror `DeviceSession`'s so benchmarks can treat
        any device-backed session uniformly; ``per_shard`` keeps the
        honest breakdown (host_syncs per shard = the transfer audit)."""
        with self._lock:
            per_shard = [sh.session_stats() for sh in self._shards]

            def total(key: str) -> int:
                return sum(s[key] for s in per_shard)

            return {
                "plan_mode": "mesh",
                "n_shards": self.n_shards,
                "n_devices": len({id(d) for d in self.devices}),
                "epochs": self.epochs,
                "sub_epoch_barriers": self.sub_epoch_barriers,
                "cross_shard_edges": self.cross_shard_edges,
                "placements": dict(self.placements),
                "transfers": self.transfer_table.as_dict(),
                **self.link.stats(),
                "overlap_drains": self.overlap_drains,
                "drain_overlap": self.drain_overlap,
                "d2d_row_exports": total("d2d_row_exports"),
                "d2d_row_imports": total("d2d_row_imports"),
                "row_invalidations": total("row_invalidations"),
                "device_dispatches": total("device_dispatches"),
                "loop_dispatches": total("loop_dispatches"),
                "host_task_dispatches": total("host_task_dispatches"),
                "plan_cache_hits": total("plan_cache_hits"),
                "plan_cache_misses": total("plan_cache_misses"),
                "compiled_programs": total("compiled_programs"),
                "host_syncs": total("host_syncs"),
                "host_syncs_d2h": total("host_syncs_d2h"),
                "host_syncs_h2d": total("host_syncs_h2d"),
                "slab_bytes": total("slab_bytes"),
                "arena_live_rows": total("arena_live_rows"),
                "arena_free_rows": total("arena_free_rows"),
                "arena_recycled_rows": total("arena_recycled_rows"),
                "arena_compactions": total("arena_compactions"),
                "dep_checks": self.window.stats.dep_checks,
                "scoreboard_probes": self.window.stats.scoreboard_probes,
                "per_shard": per_shard,
            }

    def _finalize(self) -> SchedulerReport:
        wall = time.perf_counter() - self._t0
        for sh in self._shards:
            if not sh.closed:
                sh.close()
        # Aggregate exec stats across shards for the report surface.
        stats = ExecStats()
        for sh in self._shards:
            stats.dispatches += sh.stats.dispatches
            stats.tasks_run += sh.stats.tasks_run
            stats.compiles += sh.stats.compiles
            stats.wave_widths.extend(sh.stats.wave_widths)
        stats.exec_seconds = wall
        report = SchedulerReport(self.window, stats, wall, self.waves)
        report.plan_mode = "mesh"  # type: ignore[attr-defined]
        report.session_stats = self.session_stats()  # type: ignore[attr-defined]
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": sum(sh.arena.n_classes() for sh in self._shards),
            "per_shard": [sh.arena.padding_waste() for sh in self._shards],
        }
        return report
