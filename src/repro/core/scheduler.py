"""ACS-SW schedulers: window -> waves -> executor.

:class:`WaveScheduler` is the TPU-adapted ACS-SW runtime (wave-synchronous:
all READY kernels launch as one fused wave, retire together, refill). It is
deterministic, which the equivalence tests rely on.

:class:`ThreadedStreamScheduler` is the *mechanically faithful* ACS-SW of
paper §IV-B: a window module plus K scheduler threads, each emulating one
CUDA stream (Algorithm 2's poll/launch/StreamSync/retire loop). It exists
to reproduce the paper's software architecture and its overhead profile
(per-kernel dispatch + sync from host threads); the wave scheduler is the
performance path on TPU.

Every scheduler here is a thin closed-batch facade over a live
:class:`~.session.SchedulerSession` (DESIGN.md §10): ``run(tasks)`` opens a
session, submits the whole list, and closes — while ``session()`` (or
:func:`make_session`) hands out the open-loop form that producers feed
continuously, the paper's §III-D input FIFO. Both produce identical final
buffer contents as the serial baseline (property-tested): ACS only
reorders provably independent kernels.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence

from .executors import ExecStats, FusedWaveExecutor, SerialExecutor
from .task import Task
from .window import SchedulingWindow

__all__ = [
    "GroupTrace",
    "SchedulerReport",
    "WaveScheduler",
    "ThreadedStreamScheduler",
    "run_serial",
    "SCHEDULER_NAMES",
    "SESSION_NAMES",
    "PLAN_MODES",
    "make_scheduler",
    "make_session",
]


class GroupTrace:
    """Lifetime of one dispatched group: frontier schedules overlap, so a
    flat wave list cannot express the timeline — launch/retire stamps can."""

    __slots__ = ("tids", "t_launch", "t_retire", "blocking")

    def __init__(self, tids: List[int], t_launch: float, t_retire: float, blocking: bool = False):
        self.tids = tids
        self.t_launch = t_launch
        self.t_retire = t_retire
        self.blocking = blocking  # retired via blocking sync, not poll

    def as_dict(self) -> Dict[str, Any]:
        return {
            "tids": list(self.tids),
            "t_launch": self.t_launch,
            "t_retire": self.t_retire,
            "blocking": self.blocking,
        }


class SchedulerReport:
    def __init__(
        self,
        window: SchedulingWindow,
        exec_stats: ExecStats,
        wall_seconds: float,
        waves: List[List[int]],
        groups: Optional[List[GroupTrace]] = None,
    ):
        self.window_stats = window.stats.as_dict()
        self.exec_stats = exec_stats.as_dict()
        self.wall_seconds = wall_seconds
        self.waves = waves  # list of lists of tids (schedule trace)
        # Overlapping-lifetime trace (frontier schedulers): one entry per
        # dispatched group, launch/retire timestamped relative to run start.
        self.groups = groups if groups is not None else []

    @property
    def mean_wave_width(self) -> float:
        return self.exec_stats["mean_wave_width"]

    def occupancy_proxy(self, max_parallel: Optional[int] = None) -> float:
        """Wave-width occupancy proxy (DESIGN.md §2): mean fraction of the
        achievable parallel width actually filled per launch."""
        widths = [len(w) for w in self.waves] or [1]
        cap = max_parallel or max(widths)
        return sum(min(w, cap) for w in widths) / (len(widths) * cap)

    def max_inflight_groups(self) -> int:
        """Peak number of groups simultaneously in flight (trace-derived):
        >1 means the scheduler actually overlapped execution windows."""
        events = []
        for g in self.groups:
            events.append((g.t_launch, 1))
            events.append((g.t_retire, -1))
        depth = peak = 0
        for _, delta in sorted(events):
            depth += delta
            peak = max(peak, depth)
        return peak

    def retire_order(self) -> List[int]:
        """Tids in retirement order (groups sorted by retire stamp)."""
        order: List[int] = []
        for g in sorted(self.groups, key=lambda g: g.t_retire):
            order.extend(g.tids)
        return order

    def as_dict(self) -> Dict[str, Any]:
        out = {
            "wall_seconds": self.wall_seconds,
            "waves": len(self.waves),
            **{f"window_{k}": v for k, v in self.window_stats.items()},
            **{f"exec_{k}": v for k, v in self.exec_stats.items()},
        }
        if self.groups:
            out["groups"] = len(self.groups)
            out["max_inflight_groups"] = self.max_inflight_groups()
        return out


class WaveScheduler:
    """Windowed out-of-order scheduler, wave-synchronous execution."""

    def __init__(self, window_size: int = 32, executor: Optional[Any] = None, max_wave: Optional[int] = None):
        self.window_size = window_size
        self.executor = executor if executor is not None else FusedWaveExecutor()
        self.max_wave = max_wave  # cap = number of "streams"; None = unbounded

    def session(self):
        """Open a live :class:`~.session.WaveSession` sharing this
        scheduler's executor (compile caches persist across sessions)."""
        from .session import WaveSession

        return WaveSession(window_size=self.window_size, executor=self.executor,
                           max_wave=self.max_wave)

    def run(self, stream: Iterable[Task]) -> SchedulerReport:
        """Closed-batch wrapper: open a session, submit everything, close."""
        session = self.session()
        session.submit(list(stream))
        return session.close()


class ThreadedStreamScheduler:
    """Paper-faithful ACS-SW: K scheduler threads == K CUDA streams."""

    def __init__(self, window_size: int = 32, num_streams: int = 4):
        self.window_size = window_size
        self.num_streams = num_streams
        # Per-signature compiled kernels live across run() calls, like
        # SerialExecutor._jit_cache — a long-running runtime recompiles per
        # new kernel shape, not per stream.
        self._jit_cache: Dict = {}

    def session(self):
        """Open a live :class:`~.session.ThreadedSession`: K worker threads
        park on a condition variable until the FIFO feeds them."""
        from .session import ThreadedSession

        return ThreadedSession(window_size=self.window_size,
                               num_streams=self.num_streams,
                               jit_cache=self._jit_cache)

    def run(self, stream: Iterable[Task]) -> SchedulerReport:
        """Closed-batch wrapper: open a session, submit everything, close."""
        session = self.session()
        session.submit(list(stream))
        return session.close()


def run_serial(stream: Iterable[Task]) -> SchedulerReport:
    """The single-stream baseline: program order, one dispatch per kernel."""
    sched = WaveScheduler(window_size=1, executor=SerialExecutor())
    return sched.run(stream)


SCHEDULER_NAMES = ("serial", "wave", "threaded", "frontier", "device")
# Policies that can run as live-fed sessions. "device" is the persistent
# device-resident window (DeviceSession): submissions accumulate in the
# live window and drain in one-dispatch epochs over a session-lifetime
# slab arena with a structure-keyed plan cache.
SESSION_NAMES = ("serial", "wave", "threaded", "frontier", "device", "mesh")
# Device plan lowerings. "wave"/"frontier" lower an epoch to a fixed
# DeviceStep table (order decided on host at plan time); "loop" lowers it
# to a device-resident ready-queue program (lax.while_loop / Pallas fast
# path) where retirement decrements dependents' counters ON DEVICE — the
# whole dependency frontier advances in one dispatch (DESIGN §2 A3).
PLAN_MODES = ("wave", "frontier", "loop")


def make_scheduler(name: str, window_size: int = 32, num_streams: int = 4,
                   max_inflight: int = 8, plan_mode: str = "wave"):
    """Factory over the five ACS execution policies; the single source
    benchmarks and examples share. Returns a *persistent* scheduler's bound
    ``run`` (``tasks -> SchedulerReport``): compile caches — including the
    serial baseline's per-signature jit cache and the device runner's
    lowered-program cache — carry across streams, as a long-running
    runtime's would.

    ``plan_mode`` selects the ACS-HW analogue's plan lowering (``"wave"``,
    ``"frontier"`` or the device-resident ready-queue ``"loop"``, DESIGN
    §2 A3) and only affects ``name="device"``.
    """
    if plan_mode not in PLAN_MODES:
        raise ValueError(f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
    if name == "serial":
        return WaveScheduler(window_size=1, executor=SerialExecutor()).run
    if name == "wave":
        return WaveScheduler(window_size=window_size).run
    if name == "threaded":
        return ThreadedStreamScheduler(window_size=window_size,
                                       num_streams=num_streams).run
    if name == "frontier":
        from .frontier import AsyncFrontierScheduler

        return AsyncFrontierScheduler(window_size=window_size,
                                      max_inflight=max_inflight).run
    if name == "device":
        from .device_dispatch import DeviceWindowRunner

        return DeviceWindowRunner(window_size=window_size,
                                  plan_mode=plan_mode).run
    raise ValueError(f"unknown scheduler {name!r}; choose from {SCHEDULER_NAMES}")


def make_session(name: str, window_size: int = 32, num_streams: int = 4,
                 max_inflight: int = 8, max_group: Optional[int] = None,
                 plan_mode: str = "wave",
                 history_limit: Optional[int] = None):
    """Factory over the live scheduler sessions (DESIGN.md §10): returns an
    open :class:`~.session.SchedulerSession` that producers feed with
    ``submit()`` while it dependency-checks, launches, and retires
    concurrently in flight; ``close()`` returns the usual report.

    ``"serial"`` is a window-1 session (program order, one dispatch per
    kernel) — useful as the live-fed equivalence baseline. ``"device"`` is
    the persistent device-resident window (DESIGN §2 A3): submissions
    accumulate and drain in one-dispatch epochs over a session-lifetime
    slab arena; ``plan_mode`` selects its plan lowering and only affects
    this session kind.
    """
    from .session import ThreadedSession, WaveSession

    if plan_mode not in PLAN_MODES:
        raise ValueError(f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
    if name == "serial":
        return WaveSession(window_size=1, executor=SerialExecutor(),
                           history_limit=history_limit)
    if name == "wave":
        return WaveSession(window_size=window_size,
                           history_limit=history_limit)
    if name == "threaded":
        return ThreadedSession(window_size=window_size,
                               num_streams=num_streams,
                               history_limit=history_limit)
    if name == "frontier":
        from .frontier import FrontierSession

        return FrontierSession(window_size=window_size,
                               max_inflight=max_inflight, max_group=max_group,
                               history_limit=history_limit)
    if name == "device":
        from .device_dispatch import DeviceSession

        return DeviceSession(window_size=window_size, plan_mode=plan_mode,
                             max_group=max_group, history_limit=history_limit)
    if name == "mesh":
        from .mesh_session import MeshDeviceSession

        # The mesh session shards the window across visible devices
        # (one shard per device by default; construct MeshDeviceSession
        # directly for explicit n_shards / device lists). Its per-shard
        # executors always use the ready-queue "loop" lowering, so the
        # factory-level plan_mode — validated above — is not forwarded.
        return MeshDeviceSession(window_size=window_size,
                                 history_limit=history_limit)
    raise ValueError(f"unknown session {name!r}; choose from {SESSION_NAMES}")
