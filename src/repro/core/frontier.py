"""Async frontier scheduling — retiring dependencies, not waves.

Both seed ACS-SW schedulers are barrier-bound. :class:`~.scheduler.WaveScheduler`
retires an entire wave before refilling the window, so the slowest kernel
in a wave gates every successor — even ones whose true upstreams finished
long ago. :class:`~.scheduler.ThreadedStreamScheduler` retires at kernel
granularity but pays a global lock plus a ``block_until_ready`` per kernel,
exactly the per-kernel sync overhead §II-D budgets against. The remaining
speedup (Jangda et al.'s fine-grained kernel synchronization, Atos's
asynchronous frontiers) lives between those two points: retire and dispatch
at the granularity of individual dependency edges, without a host sync per
kernel.

:class:`AsyncFrontierScheduler` implements that point on TPU/JAX
(DESIGN.md §9):

* the READY set is partitioned into homogeneous groups (equal
  ``Task.signature``) and each *group* is dispatched asynchronously via
  :class:`~.executors.GroupExecutor` — JAX async dispatch returns future
  arrays which are written straight into the output buffers, so downstream
  groups chain on-device and the host never blocks per kernel;
* groups retire individually, as their results land (non-blocking
  ``poll``), immediately waking only their true downstreams — no wave
  barrier;
* dependency checking (window insertion) and wave-program compilation
  (``GroupExecutor.warm``) are overlapped against in-flight execution via
  a double-buffered dispatch queue: while launched groups execute, the
  next groups are staged (dep-checked + compiled); the buffers flip and
  the staged groups launch while their successors stage.

A blocking sync happens only when the pipeline truly stalls (window full
of in-flight work and nothing polls complete); ``ExecStats.blocking_syncs``
counts these, and the benchmark acceptance bar is syncs << dispatches.

The frontier is expressed as a live :class:`FrontierSession` (DESIGN.md
§10): producers ``submit()`` while groups are in flight — the executor's
in-flight ledger survives across submissions, so a task submitted now can
coalesce with, launch behind, or retire ahead of work dispatched before it
existed. :class:`AsyncFrontierScheduler.run` is the closed-batch wrapper
(open, submit everything, close) that all batch callers keep using.
"""

from __future__ import annotations

import collections
import time
from typing import Deque, Iterable, List, Optional, Sequence, Set

from .executors import GroupExecutor, GroupHandle
from .scheduler import GroupTrace, SchedulerReport
from .session import SchedulerSession
from .task import Task

__all__ = ["AsyncFrontierScheduler", "DispatchQueue", "FrontierSession"]


class DispatchQueue:
    """Double-buffered, coalescing group staging.

    ``stage()`` sorts freshly-READY kernels into per-signature buckets in
    the *back* buffer while previously-launched groups are still executing.
    Buckets coalesce: a kernel that wakes two retires after its batchable
    sibling still joins the same bucket, so group width recovers even
    though the frontier never waits for a full wave (the pipeline delay
    before the next ``flip`` IS the batching window). ``flip()`` promotes
    the back buffer to launchable — warming compiled callables on the way,
    one iteration ahead of launch — once the front has drained. The point
    is pipelining: dependency analysis, batching, and compilation happen
    behind device time, and the launch loop only ever touches ready-made
    groups.
    """

    def __init__(self, max_group: Optional[int] = None):
        self.max_group = max_group
        # back buffer: signature -> coalescing bucket (insertion-ordered)
        self._staged: "collections.OrderedDict[tuple, List[Task]]" = (
            collections.OrderedDict()
        )
        self._launchable: Deque[List[Task]] = collections.deque()  # front
        self._queued_tids: Set[int] = set()

    def stage(self, ready: Sequence[Task]) -> int:
        """Bucket not-yet-queued READY tasks by signature; returns the
        number of new buckets opened."""
        opened = 0
        for t in ready:
            if t.tid in self._queued_tids:
                continue
            bucket = self._staged.get(t.signature)
            if bucket is None:
                bucket = self._staged[t.signature] = []
                opened += 1
            bucket.append(t)
            self._queued_tids.add(t.tid)
        return opened

    def flip(self, executor: GroupExecutor) -> bool:
        """Promote the back buffer once the front is drained; compile-warm
        every promoted group (ahead of its launch next iteration)."""
        if self._launchable or not self._staged:
            return False
        for bucket in self._staged.values():
            while bucket:
                cut = bucket[: self.max_group] if self.max_group else bucket
                bucket = bucket[len(cut):]
                executor.warm(cut)
                self._launchable.append(cut)
        self._staged = collections.OrderedDict()
        return True

    def pop(self) -> Optional[List[Task]]:
        if not self._launchable:
            return None
        group = self._launchable.popleft()
        for t in group:
            self._queued_tids.discard(t.tid)
        return group

    @property
    def has_launchable(self) -> bool:
        return bool(self._launchable)

    def empty(self) -> bool:
        return not self._staged and not self._launchable


class FrontierSession(SchedulerSession):
    """Live-fed rolling frontier: the session form of the async frontier.

    Every ``poll`` runs one scheduling step — retire groups whose results
    landed (waking only true downstreams), launch staged groups up to the
    in-flight cap, stage the fresh READY set, flip the double buffer.
    In-flight groups live on the *executor's* ledger, so they survive
    across ``submit`` calls: the producer can keep feeding the FIFO while
    earlier groups execute, which is the paper's §III-D picture. ``drive``
    adds the blocking fallback (sync the oldest in-flight group) used when
    the pipeline genuinely stalls.
    """

    def __init__(
        self,
        window_size: int = 32,
        executor: Optional[GroupExecutor] = None,
        max_inflight: int = 8,
        max_group: Optional[int] = None,
        history_limit: Optional[int] = None,
    ):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        super().__init__(window_size, history_limit=history_limit)
        ex = executor if executor is not None else GroupExecutor()
        if ex.inflight:
            # One live session per executor: poll_landed would hand this
            # session groups whose tasks live in ANOTHER session's window
            # (retire-not-resident corruption). Fail loudly at open instead.
            raise RuntimeError(
                f"executor has {len(ex.inflight)} in-flight group(s) from "
                "another session; close it before opening a new one"
            )
        self.executor = ex
        self.queue = DispatchQueue(max_group)
        self.max_inflight = max_inflight

    def _retire_group(self, handle: GroupHandle, blocking: bool) -> None:
        self.window.retire_many(handle.tasks)
        self.groups.append(
            GroupTrace(
                [t.tid for t in handle.tasks],
                handle.t_launch - self._t0,
                time.perf_counter() - self._t0,
                blocking=blocking,
            )
        )
        for t in handle.tasks:
            self._note_retired(t)

    def _pump(self) -> bool:
        # Per-pump window costs are all incremental: retire_many updates
        # scoreboard claims + downstream sets in O(own segments +
        # out-degree), refill dep-checks via scoreboard probes, and
        # ready_tasks() is a plain ordered read — no per-poll sort, no
        # pairwise rescan — so polling stays cheap at window 256+.
        ex = self.executor
        progressed = False

        # 1. Retire every group whose results have landed (non-blocking).
        for handle in ex.poll_landed():
            self._retire_group(handle, blocking=False)
            progressed = True

        # 2. Launch previously staged groups up to the in-flight cap.
        while len(ex.inflight) < self.max_inflight and self.queue.has_launchable:
            group = self.queue.pop()
            assert group is not None
            for t in group:
                self.window.mark_executing(t)
            ex.launch(group)
            self.waves.append([t.tid for t in group])
            progressed = True

        # 3. Stage the next groups from the current READY set (coalescing
        #    batchable siblings), 4. flip the double buffer when drained.
        #    ready_tasks() yields urgent priority buckets first (DESIGN
        #    §13), so staging order — hence group open order and launch
        #    order — serves high-priority kernels ahead of independent
        #    lower-priority peers with no frontier-side logic.
        self.queue.stage(self.window.ready_tasks())
        if self.queue.flip(ex):
            progressed = True
        return progressed

    def poll(self) -> List[Task]:
        # Pump to quiescence, not one step: a retire that wakes a staged
        # downstream should launch it within the same poll — otherwise
        # every dependency edge costs an extra host round-trip.
        with self._lock:
            while self._pump():
                pass
        return self._drain_fresh()

    def drive(self) -> List[Task]:
        with self._lock:
            progressed = False
            while self._pump():
                progressed = True
            if not progressed:
                self._sync_one()
        return self._drain_fresh()

    def _on_stall(self) -> None:
        with self._lock:
            self._sync_one()

    def _sync_one(self) -> None:
        """Blocking fallback (lock held): sync the oldest in-flight group —
        the one whose downstreams have waited longest."""
        handle = self.executor.sync_oldest()
        if handle is not None:
            self._retire_group(handle, blocking=True)
        elif not self.window.idle():
            # No in-flight work, no READY kernels, window non-empty:
            # impossible by the window's no-deadlock invariant.
            raise RuntimeError("frontier stall: no READY kernels but window non-empty")

    def _finalize(self) -> SchedulerReport:
        ex = self.executor
        ex.finalize()
        wall = time.perf_counter() - self._t0
        # Accumulate like every other executor: the executor (and its
        # ExecStats) persists across sessions, so overwriting would pair
        # last-run seconds with all-runs dispatch counters in deltas.
        ex.stats.exec_seconds += wall
        return SchedulerReport(self.window, ex.stats, wall, self.waves,
                               groups=self.groups)


class AsyncFrontierScheduler:
    """Windowed out-of-order scheduler with rolling, barrier-free retire.

    Parameters
    ----------
    window_size:
        ACS scheduling window size (paper default 32).
    max_inflight:
        Cap on simultaneously in-flight groups — the analogue of the
        paper's stream count. More in-flight groups = more overlap, but
        retire latency for any one group grows.
    max_group:
        Cap on tasks fused per group launch (None = unbounded), mirroring
        ``WaveScheduler.max_wave``.
    """

    def __init__(
        self,
        window_size: int = 32,
        executor: Optional[GroupExecutor] = None,
        max_inflight: int = 8,
        max_group: Optional[int] = None,
    ):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.window_size = window_size
        self.executor = executor if executor is not None else GroupExecutor()
        self.max_inflight = max_inflight
        self.max_group = max_group

    def session(self) -> FrontierSession:
        """Open a live session sharing this scheduler's executor (compile
        caches and stats persist, as a long-running runtime's would)."""
        return FrontierSession(
            window_size=self.window_size,
            executor=self.executor,
            max_inflight=self.max_inflight,
            max_group=self.max_group,
        )

    def run(self, stream: Iterable[Task]) -> SchedulerReport:
        """Closed-batch wrapper: open a session, submit everything, close."""
        session = self.session()
        session.submit(list(stream))
        return session.close()
