"""Virtual device address space + buffer pool.

The paper's dependency checks operate on *virtual addresses* resolved just
before kernel launch (§IV-A). JAX arrays do not expose stable device
addresses, so the runtime maintains its own virtual address space: every
logical buffer is assigned a contiguous address range at allocation time,
and kernel wrappers resolve (buffer, offset, size) references into absolute
``Segment``s — exactly the role of ``get_addresses`` in Fig 17.

This indirection is *faithful*, not cosmetic: sub-buffer views (e.g. one
request's KV-cache rows, one body's state slice in the physics engine)
map to sub-intervals of the parent buffer's range, so partial-overlap
dependencies behave like real address-range checks, including aliasing.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .segments import Segment

__all__ = ["Buffer", "BufferView", "BufferPool"]

_ALIGN = 256  # bytes; mirrors typical device allocator alignment.


@dataclasses.dataclass
class Buffer:
    """A logical device allocation with a virtual address range."""

    name: str
    base: int
    nbytes: int
    shape: Tuple[int, ...]
    dtype: Any
    # Host-side value (a jax array / numpy array). The ACS executors
    # functionally update this as tasks retire.
    value: Any = None

    @property
    def segment(self) -> Segment:
        return Segment(self.base, self.nbytes)

    def view(self, offset_bytes: int, nbytes: int) -> "BufferView":
        if offset_bytes < 0 or offset_bytes + nbytes > self.nbytes:
            raise ValueError(
                f"view [{offset_bytes}, {offset_bytes + nbytes}) out of bounds "
                f"for buffer {self.name!r} of {self.nbytes} bytes"
            )
        return BufferView(self, offset_bytes, nbytes)

    def row_view(self, row_start: int, row_count: int) -> "BufferView":
        """View of contiguous leading-axis rows — the common case
        (a request's KV rows, a token group's slice, a body's state)."""
        if not self.shape:
            raise ValueError("row_view requires a shaped buffer")
        row_bytes = self.nbytes // self.shape[0]
        v = self.view(row_start * row_bytes, row_count * row_bytes)
        return BufferView(self, v.offset, v.nbytes, row_start, row_count)

    # Value plumbing (executors read/write through these) -----------------
    def get_value(self):
        return self.value

    def set_value(self, new) -> None:
        self.value = new


@dataclasses.dataclass(frozen=True)
class BufferView:
    """A (buffer, offset, size) reference — resolvable to a Segment.

    ``row_start``/``row_count`` are set when the view is a contiguous
    leading-axis row slice; executors use them to slice / scatter values.
    """

    buffer: Buffer
    offset: int
    nbytes: int
    row_start: Optional[int] = None
    row_count: Optional[int] = None

    @property
    def segment(self) -> Segment:
        return Segment(self.buffer.base + self.offset, self.nbytes)

    @property
    def name(self) -> str:
        return f"{self.buffer.name}[{self.offset}:{self.offset + self.nbytes}]"

    def get_value(self):
        if self.row_start is not None:
            return self.buffer.value[self.row_start : self.row_start + self.row_count]
        raise ValueError("only row views carry values; use the parent buffer")

    def set_value(self, new) -> None:
        if self.row_start is None:
            raise ValueError("only row views support value writeback")
        val = self.buffer.value
        if hasattr(val, "at"):  # jax array
            self.buffer.value = val.at[self.row_start : self.row_start + self.row_count].set(new)
        else:  # numpy
            val[self.row_start : self.row_start + self.row_count] = new


class BufferPool:
    """Bump allocator over the virtual address space (thread-safe).

    Addresses are never recycled during a stream's lifetime: the paper's
    window only ever holds a handful of live kernels, and monotonically
    increasing addresses make WAR/WAW detection exact without a free-list.
    """

    def __init__(self) -> None:
        self._next = _ALIGN  # keep 0 unused; eases debugging.
        self._buffers: Dict[str, Buffer] = {}
        self._lock = threading.Lock()
        self._anon = 0
        self._free_hooks: List[Callable[[Buffer], None]] = []

    def add_free_hook(self, cb: Callable[[Buffer], None]) -> None:
        """Subscribe to buffer release: ``cb(buf)`` fires after ``free``
        drops the pool's reference. This is how downstream residency
        tracking (the device arena's row free-list) learns a buffer's
        lifetime ended without the pool knowing the consumer exists."""
        with self._lock:
            self._free_hooks.append(cb)

    def alloc(
        self,
        shape: Tuple[int, ...],
        dtype: Any = np.float32,
        name: Optional[str] = None,
        value: Any = None,
    ) -> Buffer:
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize if shape else np.dtype(dtype).itemsize
        nbytes = max(nbytes, 1)
        with self._lock:
            if name is None:
                name = f"buf{self._anon}"
                self._anon += 1
            if name in self._buffers:
                raise KeyError(f"buffer {name!r} already allocated")
            base = self._next
            padded = (nbytes + _ALIGN - 1) // _ALIGN * _ALIGN
            self._next = base + padded
            buf = Buffer(name=name, base=base, nbytes=nbytes, shape=tuple(shape), dtype=np.dtype(dtype), value=value)
            self._buffers[name] = buf
            return buf

    def free(self, name: str) -> None:
        """Release a named buffer: the pool drops its reference (so the
        host/device value can be collected) and the name becomes reusable.
        Virtual addresses are NOT recycled — the bump pointer stays
        monotone, so a freed buffer's range remains retired and past
        segment checks stay exact. Long-running runtimes (the serving
        driver's per-request prompt buffers) must free or they leak.
        Registered free hooks fire after the reference drops (outside the
        pool lock — hooks may take their own locks)."""
        with self._lock:
            if name not in self._buffers:
                raise KeyError(f"buffer {name!r} not allocated")
            buf = self._buffers.pop(name)
            hooks = tuple(self._free_hooks)
        for cb in hooks:
            cb(buf)

    def from_array(self, arr: Any, name: Optional[str] = None) -> Buffer:
        arr_np_dtype = np.dtype(str(arr.dtype)) if hasattr(arr, "dtype") else np.dtype(np.float32)
        return self.alloc(tuple(arr.shape), arr_np_dtype, name=name, value=arr)

    def buffers(self) -> Tuple[Buffer, ...]:
        """All live allocations, in allocation order (the slab arena and
        the device runner enumerate a pool's buffers through this)."""
        with self._lock:
            return tuple(self._buffers.values())

    def __getitem__(self, name: str) -> Buffer:
        return self._buffers[name]

    def __contains__(self, name: str) -> bool:
        return name in self._buffers

    def __len__(self) -> int:
        return len(self._buffers)
