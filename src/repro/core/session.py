"""Persistent scheduler sessions — the live-fed ACS window (DESIGN.md §10).

The paper's runtime is *open-loop*: applications launch kernels into the
input FIFO **while** the window dependency-checks, dispatches, and retires
concurrently in flight (§III-C/D, Fig 14/15 — the FIFO refills the window
as vacancies appear, it is never a closed batch). The seed schedulers only
exposed ``run(tasks)``, which drains a closed list to empty; a serving
runtime built on that must rebuild its stream and block the host every
iteration, so decode *i* can never overlap prefill *i+1*.

:class:`SchedulerSession` is the open-loop runtime. Lifecycle:

* ``submit(tasks)`` — producers push tasks (or whole ``TaskStream``s) at
  any time; returns the current backlog depth (FIFO + resident), the
  backpressure signal. A ``TaskStream`` constructed with ``sink=session``
  feeds every ``AcsKernel.launch`` straight into the window.
* ``poll()`` — non-blocking progress: dispatch what is READY, retire what
  has landed; returns tasks retired since the last drain.
* ``drive()`` — like ``poll`` but may block for one retirement when the
  pipeline is otherwise stalled (the frontier's oldest-group sync).
* ``flush()`` — block until everything submitted so far has retired.
* ``close()`` — end the input stream (``window.close_input()``), flush,
  finalize, and return the familiar :class:`~.scheduler.SchedulerReport`.

Callers observe retirement without draining the world: per-task completion
callbacks (``submit(..., on_retire=...)`` / ``on_task_retired``) fire as
each task retires, and ``ticket()`` hands out a future-like
:class:`TaskTicket`. The closed-batch ``run(tasks)`` entry points on every
scheduler are now thin open-submit-close wrappers over these sessions, so
all batch callers and the serial-equivalence property are unchanged.

Dependency checking inside every session kind is the window's interval
scoreboard (``core/scoreboard.py``): a live ``submit()`` costs
O(segments x log intervals) regardless of window size, so sessions can
run windows of 128-512 without the insertion scan eating the concurrency
it exposes; ``window_stats()`` surfaces the probe-vs-pairwise counters
live.

Thread-safety: all bookkeeping runs under one re-entrant lock, so
retirement callbacks may submit follow-on work into the same session (the
serving runtime's decode chain does exactly this). ``ThreadedSession``
executes on worker threads and fires callbacks from them; the
single-threaded sessions make progress only inside ``poll``/``drive``/
``flush`` calls.

Bookkeeping that feeds the final report (the wave/group schedule traces,
the retired-tid set backing ``on_task_retired``'s fire-immediately
semantics) defaults to session-lifetime state. A server fed unbounded
streams passes ``history_limit=N`` instead: schedule traces become rolling
windows (``deque(maxlen=N)``), per-tag counts keep the N most recent tags,
and the retired-tid set evicts its oldest members into a merged
interval list — ``_is_retired`` stays exact for every tid ever retired at
O(N + log intervals) memory, so fire-immediately callback semantics
survive the rotation. The host-memory boundedness this buys a long-lived
server is asserted by ``benchmarks/bench_soak.py``.
"""

from __future__ import annotations

import bisect
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Set, Union

import jax

from .executors import ExecStats, FusedWaveExecutor
from .scheduler import SchedulerReport
from .task import Task
from .window import SchedulingWindow

__all__ = ["SchedulerSession", "TaskTicket", "WaveSession", "ThreadedSession"]

RetireCallback = Callable[[Task], None]


class TaskTicket:
    """Future-like handle to one task's retirement (thread-safe)."""

    __slots__ = ("task", "_event")

    def __init__(self, task: Task):
        self.task = task
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until retirement. Only meaningful while something else
        drives the session (a worker thread, or another caller polling)."""
        return self._event.wait(timeout)


class SchedulerSession:
    """Base class: open window + retirement bookkeeping. Subclasses supply
    the dispatch policy via ``_pump`` (one non-blocking scheduling step)
    and may override ``drive``/``flush``."""

    def __init__(self, window_size: int = 32,
                 history_limit: Optional[int] = None):
        if history_limit is not None and history_limit < 1:
            raise ValueError(f"history_limit must be >= 1, got {history_limit}")
        self.window = SchedulingWindow(window_size)
        self.window.open_input()
        self._lock = threading.RLock()
        self._t0 = time.perf_counter()
        self.history_limit = history_limit
        if history_limit is None:
            self.waves: Any = []
            self.groups: Any = []  # GroupTrace entries (frontier)
        else:
            self.waves = deque(maxlen=history_limit)
            self.groups = deque(maxlen=history_limit)
        self._submitted = 0
        self._retired = 0
        self._retired_tids: Set[int] = set()
        # Bounded mode: retirement order of _retired_tids members, and the
        # evicted tids merged into sorted disjoint [lo, hi] intervals so
        # _is_retired stays exact after rotation.
        self._retired_order: Optional[deque] = (
            deque() if history_limit is not None else None)
        self._retired_evicted: List[List[int]] = []
        self._fresh: List[Task] = []  # retired since last drain
        self._watchers: Dict[int, List[RetireCallback]] = {}
        self._tickets: Dict[int, TaskTicket] = {}
        self._listeners: List[RetireCallback] = []
        self.retired_by_tag: Dict[str, int] = {}
        self._closed = False

    # -- producer side -----------------------------------------------------
    def submit(
        self,
        tasks: Union[Task, Iterable[Task]],
        on_retire: Optional[RetireCallback] = None,
    ) -> int:
        """Enqueue task(s) into the live window; callable at any time while
        the session is open, including from retirement callbacks. Returns
        the post-submit backlog depth (input FIFO + window residents) —
        the producer's backpressure signal."""
        batch = [tasks] if isinstance(tasks, Task) else list(tasks)
        with self._lock:
            if self._closed or not self.window.input_open:
                raise RuntimeError("cannot submit to a closed session")
            for t in batch:
                if on_retire is not None:
                    self._watchers.setdefault(t.tid, []).append(on_retire)
                self._submitted += 1
                self.window.submit(t)
            depth = self.window.backlog()
            self._wake()
        return depth

    def backlog(self) -> int:
        """Tasks submitted but not yet retired (FIFO + resident)."""
        with self._lock:
            return self.window.backlog()

    def window_stats(self) -> Dict[str, int]:
        """Live snapshot of the window's counters (dep_checks =
        pairwise-equivalent Algorithm 1 cost, scoreboard_probes = interval
        cells actually inspected, inserted/retired/max_resident) — the
        monitoring surface servers poll without draining the session."""
        with self._lock:
            return self.window.stats.as_dict()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def outstanding(self) -> int:
        with self._lock:
            return self._submitted - self._retired

    # -- retirement observation --------------------------------------------
    def add_retire_listener(self, cb: RetireCallback) -> None:
        """Session-wide retirement subscriber (fires for every task)."""
        with self._lock:
            self._listeners.append(cb)

    def _is_retired(self, tid: int) -> bool:
        """Exact has-this-tid-ever-retired test (lock held): the live set,
        plus the merged intervals of tids evicted under ``history_limit``."""
        if tid in self._retired_tids:
            return True
        iv = self._retired_evicted
        if not iv:
            return False
        # last interval whose lo <= tid ([tid, inf] sorts after any of them)
        i = bisect.bisect_right(iv, [tid, float("inf")]) - 1
        return i >= 0 and iv[i][0] <= tid <= iv[i][1]

    def _evict_retired_tid(self, tid: int) -> None:
        """Move one tid from the live retired set into the interval list
        (lock held), merging with adjacent intervals."""
        iv = self._retired_evicted
        i = bisect.bisect_left(iv, [tid, tid])
        left = i > 0 and iv[i - 1][1] + 1 >= tid
        right = i < len(iv) and iv[i][0] <= tid + 1
        if left and tid <= iv[i - 1][1]:
            return  # already covered
        if left and right and iv[i][0] == tid + 1:
            iv[i - 1][1] = iv[i][1]
            del iv[i]
        elif left:
            iv[i - 1][1] = tid
        elif right and iv[i][0] == tid + 1:
            iv[i][0] = tid
        elif right and iv[i][0] <= tid:
            pass  # already covered
        else:
            iv.insert(i, [tid, tid])

    def _pre_observe_retired(self, task: Task) -> None:
        """Hook (lock held) before an observer attaches to an ALREADY
        retired task and reads its outputs: the base sessions retire
        host-side so values are always fresh, but device-backed sessions
        override this to sync slab values back first — a late
        callback/ticket holder must read host values as fresh as an early
        one's."""

    def on_task_retired(self, task: Task, cb: RetireCallback) -> None:
        """Per-task completion callback; fires immediately if the task has
        already retired."""
        with self._lock:
            if self._is_retired(task.tid):
                self._pre_observe_retired(task)
                fire_now = True
            else:
                self._watchers.setdefault(task.tid, []).append(cb)
                fire_now = False
        if fire_now:
            cb(task)

    def ticket(self, task: Task) -> TaskTicket:
        """Future-like handle for one task's retirement."""
        with self._lock:
            tk = self._tickets.get(task.tid)
            if tk is None:
                tk = TaskTicket(task)
                if self._is_retired(task.tid):
                    self._pre_observe_retired(task)
                    tk._event.set()
                else:
                    self._tickets[task.tid] = tk
            return tk

    # -- scheduler side ----------------------------------------------------
    def poll(self) -> List[Task]:
        """Non-blocking progress; returns tasks retired since last drain."""
        with self._lock:
            self._pump()
        return self._drain_fresh()

    def drive(self) -> List[Task]:
        """Progress, blocking for at most one retirement if stalled."""
        return self.poll()

    def flush(self) -> None:
        """Block until every task submitted so far has retired."""
        while True:
            with self._lock:
                if self._retired >= self._submitted:
                    return
                progressed = self._pump()
            if not progressed:
                self._on_stall()

    def close(self) -> SchedulerReport:
        """End the input stream, drain everything in flight, and report."""
        with self._lock:
            if self._closed:
                raise RuntimeError("session already closed")
            self.window.close_input()
        self.flush()
        report = self._finalize()
        self._closed = True
        return report

    # -- internals ---------------------------------------------------------
    def _pump(self) -> bool:
        """One non-blocking scheduling step; True if progress was made.
        Called with the lock held."""
        raise NotImplementedError

    def _on_stall(self) -> None:
        """Nothing progressed during flush but work remains outstanding."""
        raise RuntimeError("scheduler stall: no READY kernels but window non-empty")

    def _finalize(self) -> SchedulerReport:
        raise NotImplementedError

    def _wake(self) -> None:
        """Submission hook (threaded sessions notify their workers)."""

    def _drain_fresh(self) -> List[Task]:
        with self._lock:
            out, self._fresh = self._fresh, []
        return out

    def _note_retired(self, task: Task) -> None:
        """Central retirement bookkeeping (lock held): counters, per-tag
        accounting, tickets, then callbacks. Callbacks run under the
        re-entrant lock so they may submit into this session."""
        self._retired += 1
        self._retired_tids.add(task.tid)
        if self._retired_order is not None:
            self._retired_order.append(task.tid)
            while len(self._retired_tids) > self.history_limit:
                old = self._retired_order.popleft()
                if old in self._retired_tids:
                    self._retired_tids.discard(old)
                    self._evict_retired_tid(old)
        self._fresh.append(task)
        tag = task.stream_tag
        if tag is not None:
            self.retired_by_tag[tag] = self.retired_by_tag.get(tag, 0) + 1
            if self.history_limit is not None and \
                    len(self.retired_by_tag) > self.history_limit:
                self.retired_by_tag.pop(next(iter(self.retired_by_tag)))
        ticket = self._tickets.pop(task.tid, None)
        if ticket is not None:
            ticket._event.set()
        for cb in self._watchers.pop(task.tid, ()):  # noqa: B020
            cb(task)
        for cb in self._listeners:
            cb(task)


class WaveSession(SchedulerSession):
    """Wave-synchronous session: each ``poll`` launches the current READY
    set as one fused wave and retires it. With ``window_size=1`` this
    degenerates to the serial baseline even under live feeding (tested
    property); ``WaveScheduler.run`` is the closed-batch wrapper."""

    def __init__(self, window_size: int = 32, executor: Optional[Any] = None,
                 max_wave: Optional[int] = None,
                 history_limit: Optional[int] = None):
        super().__init__(window_size, history_limit=history_limit)
        self.executor = executor if executor is not None else FusedWaveExecutor()
        self.max_wave = max_wave

    def _pump(self) -> bool:
        ready = self.window.ready_tasks()
        if not ready:
            return False
        if self.max_wave is not None:
            # ready_tasks() is priority-bucketed (DESIGN §13): a capped
            # wave takes the most urgent READY kernels first.
            ready = ready[: self.max_wave]
        for t in ready:
            self.window.mark_executing(t)
        self.executor.execute_wave(ready)
        self.waves.append([t.tid for t in ready])
        for t in ready:
            self.window.retire(t)
            self._note_retired(t)
        return True

    def _finalize(self) -> SchedulerReport:
        self.executor.finalize()
        wall = time.perf_counter() - self._t0
        return SchedulerReport(self.window, self.executor.stats, wall, self.waves)


class ThreadedSession(SchedulerSession):
    """Paper-faithful ACS-SW as a live session: K worker threads == K CUDA
    streams, executing concurrently with producer submissions.

    Idle workers park on a :class:`threading.Condition` and are signalled
    on submit, retire, and close — the session wake-up primitive that
    replaced the seed's ``time.sleep(0)`` spin-poll, so an idle stream
    burns no CPU while it waits for the FIFO to refill."""

    def __init__(self, window_size: int = 32, num_streams: int = 4,
                 jit_cache: Optional[Dict] = None,
                 history_limit: Optional[int] = None):
        super().__init__(window_size, history_limit=history_limit)
        self.num_streams = num_streams
        self.stats = ExecStats()
        self._jit_cache = jit_cache if jit_cache is not None else {}
        self._cv = threading.Condition(self._lock)
        self._worker_error: Optional[BaseException] = None
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"acs-stream-{i}")
            for i in range(num_streams)
        ]
        for th in self._threads:
            th.start()

    def _wake(self) -> None:
        self._cv.notify_all()

    def _worker(self) -> None:
        # Algorithm 2, session form: wait (not spin) for a READY kernel,
        # launch, StreamSync, retire, signal.
        try:
            while True:
                with self._cv:
                    task = None
                    while task is None:
                        if self.window.drained():
                            return  # input closed AND complete
                        ready = self.window.ready_tasks()
                        if ready:
                            task = ready[0]
                            self.window.mark_executing(task)
                            fn = self._jit_cache.get(task.signature)
                            if fn is None:
                                fn = jax.jit(task.fn)
                                self._jit_cache[task.signature] = fn
                                self.stats.compiles += 1
                            vals = task.input_values()
                        else:
                            self._cv.wait()  # woken on submit/retire/close
                out = fn(*vals)
                jax.block_until_ready(out)  # StreamSync
                with self._cv:
                    task.write_outputs(out)
                    self.window.retire(task)
                    self.stats.dispatches += 1
                    self.stats.tasks_run += 1
                    self.stats.wave_widths.append(1)
                    self.waves.append([task.tid])
                    self._note_retired(task)
                    self._cv.notify_all()
        except BaseException as exc:  # surface worker crashes to flush/close
            with self._cv:
                self._worker_error = exc
                self._cv.notify_all()

    def _check_error(self) -> None:
        if self._worker_error is not None:
            raise RuntimeError("threaded session worker failed") from self._worker_error

    def poll(self) -> List[Task]:
        with self._cv:
            self._check_error()
        return self._drain_fresh()

    def drive(self) -> List[Task]:
        with self._cv:
            self._check_error()
            if self._retired < self._submitted:
                self._cv.wait(timeout=0.1)
                self._check_error()
        return self._drain_fresh()

    def flush(self) -> None:
        with self._cv:
            while self._retired < self._submitted:
                self._check_error()
                self._cv.wait(timeout=0.1)
            self._check_error()

    def _finalize(self) -> SchedulerReport:
        with self._cv:
            self._cv.notify_all()  # input is closed: let idle workers exit
        for th in self._threads:
            th.join()
        self._check_error()
        if not self.window.drained():
            raise RuntimeError("threaded scheduler exited before draining the window")
        wall = time.perf_counter() - self._t0
        self.stats.exec_seconds = wall
        return SchedulerReport(self.window, self.stats, wall, self.waves)
