"""Wave executors — how a set of READY kernels actually runs on the device.

On a GPU, ACS launches ready kernels into parallel streams. A TPU core runs
one program at a time, so "concurrent execution" is realized by *fusing the
ready set into one launch* (DESIGN.md §2, assumption A1):

* :class:`SerialExecutor` — one device dispatch per task, in program order.
  This is the paper's single-stream baseline.
* :class:`FusedWaveExecutor` — the ACS-SW analogue. A wave (the ready set)
  is partitioned into homogeneous groups (equal ``Task.signature``); each
  group becomes ONE vmapped call (N small kernels -> 1 batched kernel) and
  the groups are emitted into a single jitted wave program that XLA
  schedules as one launch. Compiled wave programs are cached by the wave's
  signature multiset — the "CUDA-Graph-without-reconstruction" property:
  different inputs produce different graphs, but recurring wave *shapes*
  reuse compiled artifacts (A2).

Dispatch counts are recorded: they are the TPU-side analogue of the kernel
launch + synchronization overheads of §II-D.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import numpy as np

from .task import Task

__all__ = ["ExecStats", "SerialExecutor", "FusedWaveExecutor"]


class ExecStats:
    def __init__(self) -> None:
        self.dispatches = 0
        self.compiles = 0
        self.tasks_run = 0
        self.wave_widths: List[int] = []
        self.exec_seconds = 0.0

    def as_dict(self) -> Dict[str, Any]:
        w = np.asarray(self.wave_widths or [0])
        return {
            "dispatches": self.dispatches,
            "compiles": self.compiles,
            "tasks_run": self.tasks_run,
            "waves": len(self.wave_widths),
            "mean_wave_width": float(w.mean()),
            "max_wave_width": int(w.max()),
            "exec_seconds": self.exec_seconds,
        }


class SerialExecutor:
    """Single-stream baseline: every kernel is its own dispatch."""

    def __init__(self) -> None:
        self.stats = ExecStats()
        self._jit_cache: Dict[Tuple, Callable] = {}

    def execute_wave(self, tasks: Sequence[Task]) -> None:
        t0 = time.perf_counter()
        for task in tasks:
            fn = self._jit_cache.get(task.signature)
            if fn is None:
                fn = jax.jit(task.fn)
                self._jit_cache[task.signature] = fn
                self.stats.compiles += 1
            out = fn(*task.input_values())
            task.write_outputs(out)
            self.stats.dispatches += 1
            self.stats.tasks_run += 1
            self.stats.wave_widths.append(1)
        self.stats.exec_seconds += time.perf_counter() - t0

    def finalize(self) -> None:
        jax.block_until_ready(jax.numpy.zeros(()))


def _group_by_signature(tasks: Sequence[Task]) -> List[List[Task]]:
    groups: Dict[Tuple, List[Task]] = {}
    order: List[Tuple] = []
    for t in tasks:
        key = t.signature
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(t)
    return [groups[k] for k in order]


class FusedWaveExecutor:
    """ACS-SW on TPU: the ready set becomes one fused, batched launch."""

    def __init__(self) -> None:
        self.stats = ExecStats()
        self._wave_cache: Dict[Tuple, Callable] = {}

    # wave signature = ordered multiset of task signatures
    @staticmethod
    def _wave_key(groups: List[List[Task]]) -> Tuple:
        return tuple((g[0].signature, len(g)) for g in groups)

    @staticmethod
    def _build_wave_fn(groups: List[List[Task]]) -> Callable:
        metas = []
        for g in groups:
            metas.append((g[0].fn, len(g) > 1))

        def wave_fn(group_inputs):
            outs = []
            for (fn, batched), ins in zip(metas, group_inputs):
                if batched:
                    outs.append(jax.vmap(fn)(*ins))
                else:
                    outs.append(fn(*ins))
            return outs

        return jax.jit(wave_fn)

    def execute_wave(self, tasks: Sequence[Task]) -> None:
        if not tasks:
            return
        t0 = time.perf_counter()
        groups = _group_by_signature(tasks)
        key = self._wave_key(groups)
        wave_fn = self._wave_cache.get(key)
        if wave_fn is None:
            wave_fn = self._build_wave_fn(groups)
            self._wave_cache[key] = wave_fn
            self.stats.compiles += 1

        group_inputs = []
        for g in groups:
            if len(g) > 1:
                n_in = len(g[0].inputs)
                stacked = tuple(
                    jax.numpy.stack([t.input_values()[i] for t in g]) for i in range(n_in)
                )
                group_inputs.append(stacked)
            else:
                group_inputs.append(g[0].input_values())

        group_outputs = wave_fn(group_inputs)
        self.stats.dispatches += 1
        self.stats.tasks_run += len(tasks)
        self.stats.wave_widths.append(len(tasks))

        for g, outs in zip(groups, group_outputs):
            if len(g) > 1:
                # outs: stacked along axis 0 (single-output) or tuple thereof
                if isinstance(outs, (tuple, list)):
                    for i, t in enumerate(g):
                        t.write_outputs(tuple(o[i] for o in outs))
                else:
                    for i, t in enumerate(g):
                        t.write_outputs(outs[i])
            else:
                g[0].write_outputs(outs)
        self.stats.exec_seconds += time.perf_counter() - t0

    def finalize(self) -> None:
        jax.block_until_ready(jax.numpy.zeros(()))
