"""Wave executors — how a set of READY kernels actually runs on the device.

On a GPU, ACS launches ready kernels into parallel streams. A TPU core runs
one program at a time, so "concurrent execution" is realized by *fusing the
ready set into one launch* (DESIGN.md §2, assumption A1):

* :class:`SerialExecutor` — one device dispatch per task, in program order.
  This is the paper's single-stream baseline.
* :class:`FusedWaveExecutor` — the ACS-SW analogue. A wave (the ready set)
  is partitioned into homogeneous groups (equal ``Task.signature``); each
  group becomes ONE vmapped call (N small kernels -> 1 batched kernel) and
  the groups are emitted into a single jitted wave program that XLA
  schedules as one launch. Compiled wave programs are cached by the wave's
  signature multiset — the "CUDA-Graph-without-reconstruction" property:
  different inputs produce different graphs, but recurring wave *shapes*
  reuse compiled artifacts (A2).
* :class:`GroupExecutor` — the frontier half-executor (DESIGN.md §9). One
  homogeneous group per launch, split into non-blocking ``launch()`` /
  ``poll()`` halves: ``launch`` rides JAX async dispatch and writes the
  *future* arrays straight into the output buffers (downstream kernels
  chain on them without host sync), ``poll`` asks the runtime whether the
  group's results have landed, and ``sync`` is the explicit blocking
  fallback — counted separately, because blocking syncs are exactly the
  §II-D overhead the frontier scheduler exists to avoid.

Dispatch counts are recorded: they are the TPU-side analogue of the kernel
launch + synchronization overheads of §II-D.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .task import Task, operand_dtype, operand_shape

__all__ = [
    "ExecStats",
    "SerialExecutor",
    "FusedWaveExecutor",
    "GroupExecutor",
    "GroupHandle",
    "group_by_signature",
]


class ExecStats:
    def __init__(self) -> None:
        self.dispatches = 0
        self.compiles = 0
        self.tasks_run = 0
        self.wave_widths: List[int] = []
        self.exec_seconds = 0.0
        # Host-blocking device syncs (block_until_ready). Wave/serial
        # executors sync implicitly via value consumption; the frontier
        # path counts every explicit block so "syncs << dispatches" is a
        # checkable property.
        self.blocking_syncs = 0

    def as_dict(self) -> Dict[str, Any]:
        w = np.asarray(self.wave_widths or [0])
        return {
            "dispatches": self.dispatches,
            "compiles": self.compiles,
            "tasks_run": self.tasks_run,
            "waves": len(self.wave_widths),
            "mean_wave_width": float(w.mean()),
            "max_wave_width": int(w.max()),
            "exec_seconds": self.exec_seconds,
            "blocking_syncs": self.blocking_syncs,
        }


class SerialExecutor:
    """Single-stream baseline: every kernel is its own dispatch."""

    def __init__(self) -> None:
        self.stats = ExecStats()
        self._jit_cache: Dict[Tuple, Callable] = {}

    def execute_wave(self, tasks: Sequence[Task]) -> None:
        t0 = time.perf_counter()
        for task in tasks:
            fn = self._jit_cache.get(task.signature)
            if fn is None:
                fn = jax.jit(task.fn)
                self._jit_cache[task.signature] = fn
                self.stats.compiles += 1
            out = fn(*task.input_values())
            task.write_outputs(out)
            self.stats.dispatches += 1
            self.stats.tasks_run += 1
            self.stats.wave_widths.append(1)
        self.stats.exec_seconds += time.perf_counter() - t0

    def finalize(self) -> None:
        jax.block_until_ready(jax.numpy.zeros(()))


def group_by_signature(tasks: Sequence[Task]) -> List[List[Task]]:
    """Partition tasks into homogeneous (batchable) groups, oldest-first."""
    groups: Dict[Tuple, List[Task]] = {}
    order: List[Tuple] = []
    for t in tasks:
        key = t.signature
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(t)
    return [groups[k] for k in order]


_group_by_signature = group_by_signature  # backwards-compat alias


class FusedWaveExecutor:
    """ACS-SW on TPU: the ready set becomes one fused, batched launch."""

    def __init__(self) -> None:
        self.stats = ExecStats()
        self._wave_cache: Dict[Tuple, Callable] = {}

    # wave signature = ordered multiset of task signatures
    @staticmethod
    def _wave_key(groups: List[List[Task]]) -> Tuple:
        return tuple((g[0].signature, len(g)) for g in groups)

    @staticmethod
    def _build_wave_fn(groups: List[List[Task]]) -> Callable:
        metas = []
        for g in groups:
            metas.append((g[0].fn, len(g) > 1))

        def wave_fn(group_inputs):
            outs = []
            for (fn, batched), ins in zip(metas, group_inputs):
                if batched:
                    outs.append(jax.vmap(fn)(*ins))
                else:
                    outs.append(fn(*ins))
            return outs

        return jax.jit(wave_fn)

    def execute_wave(self, tasks: Sequence[Task]) -> None:
        if not tasks:
            return
        t0 = time.perf_counter()
        groups = _group_by_signature(tasks)
        key = self._wave_key(groups)
        wave_fn = self._wave_cache.get(key)
        if wave_fn is None:
            wave_fn = self._build_wave_fn(groups)
            self._wave_cache[key] = wave_fn
            self.stats.compiles += 1

        group_inputs = []
        for g in groups:
            if len(g) > 1:
                n_in = len(g[0].inputs)
                stacked = tuple(
                    jax.numpy.stack([t.input_values()[i] for t in g]) for i in range(n_in)
                )
                group_inputs.append(stacked)
            else:
                group_inputs.append(g[0].input_values())

        group_outputs = wave_fn(group_inputs)
        self.stats.dispatches += 1
        self.stats.tasks_run += len(tasks)
        self.stats.wave_widths.append(len(tasks))

        for g, outs in zip(groups, group_outputs):
            if len(g) > 1:
                # outs: stacked along axis 0 (single-output) or tuple thereof
                if isinstance(outs, (tuple, list)):
                    for i, t in enumerate(g):
                        t.write_outputs(tuple(o[i] for o in outs))
                else:
                    for i, t in enumerate(g):
                        t.write_outputs(outs[i])
            else:
                g[0].write_outputs(outs)
        self.stats.exec_seconds += time.perf_counter() - t0

    def finalize(self) -> None:
        jax.block_until_ready(jax.numpy.zeros(()))


class GroupHandle:
    """An in-flight homogeneous group: the launch's raw result arrays plus
    the tasks whose window slots it still occupies."""

    __slots__ = ("tasks", "raw_outputs", "t_launch")

    def __init__(self, tasks: Sequence[Task], raw_outputs: List[Any], t_launch: float):
        self.tasks = list(tasks)
        self.raw_outputs = raw_outputs  # flat list of jax arrays (futures)
        self.t_launch = t_launch


def _is_ready(arr: Any) -> bool:
    is_ready = getattr(arr, "is_ready", None)
    if is_ready is None:
        return True  # no async introspection: treat dispatch as landed
    return bool(is_ready())


class GroupExecutor:
    """Non-blocking group launches for the frontier scheduler.

    ``launch`` dispatches one homogeneous group (vmapped when width > 1)
    and immediately writes the un-materialized result arrays into the
    output buffers: JAX async dispatch makes them futures, and any
    downstream kernel consuming those buffers chains on-device without a
    host round-trip. ``poll`` is the non-blocking completion probe;
    ``sync`` is the blocking fallback (counted in ``stats.blocking_syncs``).

    ``warm`` is the compile-ahead half: building a group's jitted callable
    while *other* groups execute hides compilation behind device time
    (DESIGN.md §9 double-buffering).

    The executor owns the **in-flight ledger**: ``launch`` appends to
    ``inflight`` (oldest first) and ``poll_landed``/``sync_oldest`` consume
    it. Keeping the ledger here — not in a scheduler run loop — is what
    lets groups stay in flight *across session submissions* (DESIGN.md
    §10): a live session launches, returns to its producer, and retires
    the group on a later ``poll`` with nothing lost in between. One live
    session per executor.
    """

    def __init__(self) -> None:
        self.stats = ExecStats()
        self._fn_cache: Dict[Tuple, Callable] = {}
        self.inflight: Deque[GroupHandle] = collections.deque()

    # -- compile-ahead -----------------------------------------------------
    @staticmethod
    def _abstract_inputs(group: Sequence[Task]) -> List[Any]:
        t = group[0]
        batch = (len(group),) if len(group) > 1 else ()
        return [
            jax.ShapeDtypeStruct(batch + operand_shape(x), operand_dtype(x))
            for x in t.inputs
        ]

    def warm(self, group: Sequence[Task]) -> Callable:
        """Eager compile (jax.jit alone is lazy — tracing+XLA would
        otherwise happen inside ``launch`` and stall the dispatch loop).
        Warming calls the jitted fn once on zero-filled arrays of the
        group's shapes: that populates the wrapper's own dispatch cache, so
        real launches stay on jit's C++ fast path (an AOT
        ``lower().compile()`` executable would dispatch through the slower
        Python path on every launch). The dummy work is tiny and async."""
        key = (group[0].signature, len(group) > 1)
        fn = self._fn_cache.get(key)
        if fn is None:
            base = group[0].fn
            fn = jax.jit(jax.vmap(base)) if len(group) > 1 else jax.jit(base)
            try:
                fn(*(jax.numpy.zeros(s.shape, s.dtype)
                     for s in self._abstract_inputs(group)))
            except Exception:
                pass  # fall back to compile-at-first-launch
            self._fn_cache[key] = fn
            self.stats.compiles += 1
        return fn

    # -- non-blocking halves -----------------------------------------------
    def launch(self, group: Sequence[Task]) -> GroupHandle:
        fn = self.warm(group)
        if len(group) > 1:
            n_in = len(group[0].inputs)
            vals = [t.input_values() for t in group]
            stacked = tuple(
                jax.numpy.stack([v[i] for v in vals]) for i in range(n_in)
            )
            outs = fn(*stacked)
            raw: List[Any] = []
            if isinstance(outs, (tuple, list)):
                for i, t in enumerate(group):
                    vals = tuple(o[i] for o in outs)
                    t.write_outputs(vals)
                    raw.extend(jax.tree_util.tree_leaves(vals))
            else:
                for i, t in enumerate(group):
                    t.write_outputs(outs[i])
                raw.append(outs)
        else:
            outs = fn(*group[0].input_values())
            group[0].write_outputs(outs)
            # leaves, not top-level elements: pytree-valued outputs (e.g.
            # serving cache tuples) must expose their arrays to poll()
            raw = jax.tree_util.tree_leaves(outs)
        self.stats.dispatches += 1
        self.stats.tasks_run += len(group)
        self.stats.wave_widths.append(len(group))
        handle = GroupHandle(group, raw, time.perf_counter())
        self.inflight.append(handle)
        return handle

    def poll(self, handle: GroupHandle) -> bool:
        """True iff every result of the group has landed on device."""
        return all(_is_ready(a) for a in handle.raw_outputs)

    def poll_landed(self) -> List[GroupHandle]:
        """Remove and return every in-flight group whose results have
        landed (non-blocking) — the session's rolling-retire probe."""
        landed: List[GroupHandle] = []
        still: Deque[GroupHandle] = collections.deque()
        for handle in self.inflight:
            if self.poll(handle):
                landed.append(handle)
            else:
                still.append(handle)
        self.inflight = still
        return landed

    def sync(self, handle: GroupHandle) -> None:
        """Blocking fallback: wait for the group (the §II-D overhead)."""
        jax.block_until_ready(handle.raw_outputs)
        self.stats.blocking_syncs += 1
        try:
            self.inflight.remove(handle)
        except ValueError:
            pass  # already consumed via poll_landed/sync_oldest

    def sync_oldest(self) -> Optional[GroupHandle]:
        """Blocking-sync the oldest in-flight group (its downstreams have
        waited longest); None when nothing is in flight."""
        if not self.inflight:
            return None
        handle = self.inflight.popleft()
        self.sync(handle)
        return handle

    def finalize(self) -> None:
        jax.block_until_ready(jax.numpy.zeros(()))
