"""The ACS scheduling window (paper §III-C/D, Fig 14/15).

Faithful mechanics:

* kernels arrive through an **input FIFO** in program order;
* a fixed-size **window** (default N=32, the paper's chosen size) holds the
  kernels currently being tracked;
* on insertion, the incoming kernel is dependency-checked against every
  kernel already resident (Algorithm 1 over read/write segments) and the
  overlapping residents form its **upstream list**;
* a kernel whose upstream list is empty is **READY**; launched kernels are
  EXECUTING; on completion the kernel is retired, removed from every
  upstream list, and vacancies are refilled from the FIFO.

The input side has explicit **open/drain semantics** for live-fed sessions
(§III-D: the FIFO is refilled *while* kernels execute). A window is born
with its input closed (closed-batch compatibility: submit everything, then
drain). ``open_input()`` marks it live: ``drained()`` then reports False
even when the window is momentarily empty — the producer may still submit
— until ``close_input()`` declares the stream complete. ``idle()`` is the
weaker "empty right now" predicate either way.

Note on Algorithm 1 as printed: it tests the incoming kernel's *writes*
against residents' reads+writes (WAR + WAW) only. Correctness also needs
RAW (incoming *reads* vs residents' writes) — §III-C's prose ("overlaps
between read segments and write segments") implies it; we implement the
full RAW/WAR/WAW check (`segments.depends_on`).

Because insertion order == program order, dependencies only ever point
from newer to older kernels; the window can never deadlock, and a window
of size 1 degenerates to the serial baseline (tested property).

Ready-set maintenance is **incremental** (DESIGN.md §9): each slot keeps
its upstream tid set AND the window keeps the reverse adjacency
(tid -> dependent tids), so a retire touches only the retiree's true
downstreams — O(out-degree) — instead of rescanning every resident slot.
The READY set is an index keyed by insertion sequence number; since a
woken dependent can carry an older seq than a task inserted READY after
it, `ready_tasks()` sorts the (small) index — O(R log R) — to report
oldest-first program order.
"""

from __future__ import annotations

import collections
import enum
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Set

from .segments import depends_on, window_upstreams
from .task import Task

__all__ = ["TaskState", "SchedulingWindow", "WindowStats"]


class TaskState(enum.Enum):
    PENDING = "pending"
    READY = "ready"
    EXECUTING = "executing"


class _Slot:
    __slots__ = ("task", "upstream", "state", "seq")

    def __init__(self, task: Task, upstream: set, state: TaskState, seq: int):
        self.task = task
        self.upstream = upstream  # set of tids this task waits on
        self.state = state
        self.seq = seq  # monotone insertion index (== program order)


class WindowStats:
    """Counters for the benchmarks (dep checks mirror Table II)."""

    def __init__(self) -> None:
        self.dep_checks = 0
        self.inserted = 0
        self.retired = 0
        self.max_resident = 0

    def as_dict(self) -> Dict[str, int]:
        return {
            "dep_checks": self.dep_checks,
            "inserted": self.inserted,
            "retired": self.retired,
            "max_resident": self.max_resident,
        }


class SchedulingWindow:
    def __init__(self, size: int = 32):
        if size < 1:
            raise ValueError("window size must be >= 1")
        self.size = size
        self.fifo: Deque[Task] = collections.deque()
        self.slots: "collections.OrderedDict[int, _Slot]" = collections.OrderedDict()
        self.stats = WindowStats()
        self._seq = 0
        # Live-session input state: False = closed batch (default; the
        # producer has submitted everything it ever will), True = a
        # session may still submit, so an empty window is idle, not done.
        self._input_open = False
        # Reverse dependency edges: producer tid -> tids of resident
        # dependents. Maintained at insertion; consumed at retire so the
        # upstream update is O(out-degree), not O(window).
        self._downstream: Dict[int, Set[int]] = {}
        # READY slots keyed by insertion seq -> tid. NOT oldest-first by
        # dict order: a retire can wake a PENDING dependent whose seq is
        # older than a task inserted READY after it, so ready_tasks()
        # sorts by seq to report program order.
        self._ready: Dict[int, int] = {}

    # -- producer side ----------------------------------------------------
    def submit(self, task: Task) -> None:
        self.fifo.append(task)
        self._fill()

    def submit_all(self, tasks: Iterable[Task]) -> None:
        self.fifo.extend(tasks)
        self._fill()

    def open_input(self) -> None:
        """Mark the input FIFO live: more submissions may arrive, so an
        empty window is ``idle()`` but not ``drained()``."""
        self._input_open = True

    def close_input(self) -> None:
        """Declare the input stream complete: once the window empties it is
        ``drained()`` for good. Idempotent."""
        self._input_open = False

    @property
    def input_open(self) -> bool:
        return self._input_open

    def fifo_depth(self) -> int:
        """Kernels waiting in the input FIFO (not yet window-resident) —
        the session backpressure signal."""
        return len(self.fifo)

    def backlog(self) -> int:
        """Kernels submitted but not yet retired (FIFO + resident): the
        depth a session reports to producers as its backpressure signal."""
        return len(self.fifo) + len(self.slots)

    # -- scheduler side ---------------------------------------------------
    def ready_tasks(self) -> List[Task]:
        """All READY kernels, oldest-first (they may launch concurrently)."""
        if len(self._ready) > 1:
            seqs = sorted(self._ready)
        else:
            seqs = list(self._ready)
        return [self.slots[self._ready[s]].task for s in seqs]

    def mark_executing(self, task: Task) -> None:
        slot = self.slots[task.tid]
        if slot.state is not TaskState.READY:
            raise RuntimeError(f"task {task.tid} launched while {slot.state}")
        slot.state = TaskState.EXECUTING
        del self._ready[slot.seq]

    def retire(self, task: Task) -> None:
        """Kernel completed: drop it, update upstream lists, refill window."""
        self._retire_no_fill(task)
        self._fill()

    def retire_many(self, tasks: Sequence[Task]) -> None:
        """Batch retire (one refill pass): the frontier scheduler retires a
        whole homogeneous group at once when its results land."""
        for task in tasks:
            self._retire_no_fill(task)
        self._fill()

    def drained(self) -> bool:
        """Closed AND complete: input stream ended and nothing is resident.
        A live (``input_open``) window is never drained — see ``idle()``."""
        return not self._input_open and not self.fifo and not self.slots

    def idle(self) -> bool:
        """Empty *right now* — but if the input is open, more may arrive."""
        return not self.fifo and not self.slots

    def resident(self) -> int:
        return len(self.slots)

    # -- internals ----------------------------------------------------------
    def _retire_no_fill(self, task: Task) -> None:
        slot = self.slots.get(task.tid)
        if slot is None:
            raise RuntimeError(f"task {task.tid} retired but not resident")
        if slot.state is not TaskState.EXECUTING:
            raise RuntimeError(f"task {task.tid} retired while {slot.state}")
        del self.slots[task.tid]
        for dep_tid in self._downstream.pop(task.tid, ()):
            dep = self.slots[dep_tid]
            dep.upstream.discard(task.tid)
            if not dep.upstream and dep.state is TaskState.PENDING:
                dep.state = TaskState.READY
                self._ready[dep.seq] = dep_tid
        self.stats.retired += 1

    def _fill(self) -> None:
        while self.fifo and len(self.slots) < self.size:
            task = self.fifo.popleft()
            tids = list(self.slots.keys())
            self.stats.dep_checks += len(tids)
            # one vectorized interval pass over the whole window (Table II)
            mask = window_upstreams(
                task.read_segments,
                task.write_segments,
                [self.slots[t].task.read_segments for t in tids],
                [self.slots[t].task.write_segments for t in tids],
            )
            upstream = {tid for tid, hit in zip(tids, mask) if hit}
            for up_tid in upstream:
                self._downstream.setdefault(up_tid, set()).add(task.tid)
            state = TaskState.PENDING if upstream else TaskState.READY
            slot = _Slot(task, upstream, state, self._seq)
            self._seq += 1
            self.slots[task.tid] = slot
            if state is TaskState.READY:
                self._ready[slot.seq] = task.tid
            self.stats.inserted += 1
            self.stats.max_resident = max(self.stats.max_resident, len(self.slots))
