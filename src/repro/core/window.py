"""The ACS scheduling window (paper §III-C/D, Fig 14/15).

Faithful mechanics:

* kernels arrive through an **input FIFO** in program order;
* a fixed-size **window** (default N=32, the paper's chosen size) holds the
  kernels currently being tracked;
* on insertion, the incoming kernel is dependency-checked against the
  residents (RAW/WAR/WAW over read/write segments, Algorithm 1's hazard
  semantics) and the conflicting residents form its **upstream list**;
* a kernel whose upstream list is empty is **READY**; launched kernels are
  EXECUTING; on completion the kernel is retired, removed from every
  upstream list, and vacancies are refilled from the FIFO.

The input side has explicit **open/drain semantics** for live-fed sessions
(§III-D: the FIFO is refilled *while* kernels execute). A window is born
with its input closed (closed-batch compatibility: submit everything, then
drain). ``open_input()`` marks it live: ``drained()`` then reports False
even when the window is momentarily empty — the producer may still submit
— until ``close_input()`` declares the stream complete. ``idle()`` is the
weaker "empty right now" predicate either way.

Note on Algorithm 1 as printed: it tests the incoming kernel's *writes*
against residents' reads+writes (WAR + WAW) only. Correctness also needs
RAW (incoming *reads* vs residents' writes) — §III-C's prose ("overlaps
between read segments and write segments") implies it; we implement the
full RAW/WAR/WAW check.

**Dependency authority** (DESIGN.md §9): the sole source of upstream sets
is the incremental :class:`~.scoreboard.IntervalScoreboard` — per
address-interval writer/reader tid sets in a sorted boundary structure,
probed only at the incoming kernel's own (coalesced) segments. An
insertion costs O(segments x log intervals) instead of the seed's
O(window x segments^2) pairwise scan (``segments.window_upstreams``, now
demoted to the property-test oracle), which is what makes windows of
128-512 affordable. ``WindowStats`` counts both the scoreboard cells
actually probed and the pairwise-equivalent check count the seed path
would have performed, so Table II comparisons stay honest.

Because insertion order == program order, dependencies only ever point
from newer to older kernels; the window can never deadlock, and a window
of size 1 degenerates to the serial baseline (tested property).

Ready-set maintenance is **incremental** (DESIGN.md §9): each slot keeps
its upstream tid set AND the window keeps the reverse adjacency
(tid -> dependent tids), so a retire touches only the retiree's true
downstreams — O(out-degree) — instead of rescanning every resident slot.
The READY index is a sorted list of (priority bucket, insertion seq,
tid) — DESIGN §13. Within a bucket the ordering is exactly the old
(seq, tid) program order, so schedulers that consume ``ready_tasks()``
in order launch urgent work first WITHOUT perturbing relative order
inside a class: with a single priority class (the default) the index is
bit-identical to the pre-QoS one. Fresh inserts bisect in (a
high-priority insert may jump ahead of lower buckets; within its own
bucket its seq is the global max so it lands last), and a woken
dependent — whose seq may be older than a task inserted READY after it
— bisects into its bucket. ``ready_tasks()`` stays a plain O(R) read
with no per-poll sort. Priority only reorders *provably independent*
kernels (everything in READY is dependency-free by construction), so it
can never violate a hazard; consumers that need strict program order
(the §2-A3 loop lowering, mesh placement) use ``drain_program_order()``
which re-sorts by seq and is priority-oblivious.
"""

from __future__ import annotations

import bisect
import collections
import enum
from typing import Deque, Dict, Iterable, List, Sequence, Set, Tuple

from .scoreboard import IntervalScoreboard
from .task import Task

__all__ = ["TaskState", "SchedulingWindow", "WindowStats"]


class TaskState(enum.Enum):
    PENDING = "pending"
    READY = "ready"
    EXECUTING = "executing"


class _Slot:
    __slots__ = ("task", "upstream", "state", "seq", "priority")

    def __init__(self, task: Task, upstream: set, state: TaskState, seq: int):
        self.task = task
        self.upstream = upstream  # set of tids this task waits on
        self.state = state
        self.seq = seq  # monotone insertion index (== program order)
        # READY-index bucket, captured at insertion so the key used to
        # bisect into _ready is identical to the one used to delete from
        # it even if task.priority is mutated while resident.
        self.priority = task.priority


class WindowStats:
    """Counters for the benchmarks (dep checks mirror Table II).

    ``dep_checks`` is the *pairwise-equivalent* count: how many
    incoming-vs-resident checks Algorithm 1's scan would have performed
    (residents at each insertion) — kept so Table II comparisons against
    the paper stay honest. ``scoreboard_probes`` is what the incremental
    path actually did: interval cells inspected across all insertions.
    The ratio probes/checks is the concurrency-discovery saving."""

    def __init__(self) -> None:
        self.dep_checks = 0
        self.scoreboard_probes = 0
        self.inserted = 0
        self.retired = 0
        self.max_resident = 0

    def as_dict(self) -> Dict[str, int]:
        return {
            "dep_checks": self.dep_checks,
            "scoreboard_probes": self.scoreboard_probes,
            "inserted": self.inserted,
            "retired": self.retired,
            "max_resident": self.max_resident,
        }


class SchedulingWindow:
    def __init__(self, size: int = 32):
        if size < 1:
            raise ValueError("window size must be >= 1")
        self.size = size
        self.fifo: Deque[Task] = collections.deque()
        self.slots: "collections.OrderedDict[int, _Slot]" = collections.OrderedDict()
        self.stats = WindowStats()
        # The dependency authority: interval claims of every resident.
        # Residency here and on the scoreboard are updated in lockstep
        # (insert at _fill, remove at retire).
        self.scoreboard = IntervalScoreboard()
        self._seq = 0
        # Live-session input state: False = closed batch (default; the
        # producer has submitted everything it ever will), True = a
        # session may still submit, so an empty window is idle, not done.
        self._input_open = False
        # Reverse dependency edges: producer tid -> tids of resident
        # dependents. Maintained at insertion; consumed at retire so the
        # upstream update is O(out-degree), not O(window).
        self._downstream: Dict[int, Set[int]] = {}
        # READY slots as a sorted list of (priority, seq, tid): kept
        # ordered incrementally (inserts and wakes bisect into place), so
        # ready_tasks() is a plain O(R) read — urgent buckets first,
        # program order within a bucket — with no per-poll sort.
        self._ready: List[Tuple[int, int, int]] = []

    # -- producer side ----------------------------------------------------
    def submit(self, task: Task) -> None:
        self.fifo.append(task)
        self._fill()

    def submit_all(self, tasks: Iterable[Task]) -> None:
        self.fifo.extend(tasks)
        self._fill()

    def open_input(self) -> None:
        """Mark the input FIFO live: more submissions may arrive, so an
        empty window is ``idle()`` but not ``drained()``."""
        self._input_open = True

    def close_input(self) -> None:
        """Declare the input stream complete: once the window empties it is
        ``drained()`` for good. Idempotent."""
        self._input_open = False

    @property
    def input_open(self) -> bool:
        return self._input_open

    def fifo_depth(self) -> int:
        """Kernels waiting in the input FIFO (not yet window-resident) —
        the session backpressure signal."""
        return len(self.fifo)

    def backlog(self) -> int:
        """Kernels submitted but not yet retired (FIFO + resident): the
        depth a session reports to producers as its backpressure signal."""
        return len(self.fifo) + len(self.slots)

    # -- scheduler side ---------------------------------------------------
    def ready_tasks(self) -> List[Task]:
        """All READY kernels (they may launch concurrently): most urgent
        priority bucket first, oldest-first within a bucket."""
        return [self.slots[tid].task for _, _, tid in self._ready]

    def mark_executing(self, task: Task) -> None:
        slot = self.slots[task.tid]
        if slot.state is not TaskState.READY:
            raise RuntimeError(f"task {task.tid} launched while {slot.state}")
        slot.state = TaskState.EXECUTING
        idx = bisect.bisect_left(self._ready, (slot.priority, slot.seq, task.tid))
        del self._ready[idx]

    def retire(self, task: Task) -> None:
        """Kernel completed: drop it, update upstream lists, refill window."""
        self._retire_no_fill(task)
        self._fill()

    def retire_many(self, tasks: Sequence[Task]) -> None:
        """Batch retire (one refill pass): the frontier scheduler retires a
        whole homogeneous group at once when its results land."""
        for task in tasks:
            self._retire_no_fill(task)
        self._fill()

    def drained(self) -> bool:
        """Closed AND complete: input stream ended and nothing is resident.
        A live (``input_open``) window is never drained — see ``idle()``."""
        return not self._input_open and not self.fifo and not self.slots

    def idle(self) -> bool:
        """Empty *right now* — but if the input is open, more may arrive."""
        return not self.fifo and not self.slots

    def resident(self) -> int:
        return len(self.slots)

    def seq_of(self, tid: int) -> int:
        """Insertion sequence number (== program order) of a resident task.
        Consumers that retire-and-refill in waves but must reconstruct
        program order afterwards (the device ready-queue lowering) capture
        this BEFORE retiring — the slot is destroyed at retire."""
        return self.slots[tid].seq

    def drain_program_order(self) -> List[Task]:
        """Drain everything admitted so far (retire-and-refill waves) and
        return the tasks in PROGRAM order. The ready-queue epoch lowering
        and the mesh placement plane both need a topological order, and
        program order guarantees every dependency edge points forward;
        each task's insertion seq is captured before its slot is destroyed
        at retire. Raises on a stalled window (READY empty but residents
        remain) — impossible under program-order admission."""
        drained: List[Tuple[int, Task]] = []
        while not self.idle():
            ready = self.ready_tasks()
            if not ready:
                raise RuntimeError(
                    "window stall: no READY kernels but window non-empty")
            for t in ready:
                self.mark_executing(t)
                drained.append((self.seq_of(t.tid), t))
            self.retire_many(ready)
        drained.sort(key=lambda p: p[0])
        return [t for _, t in drained]

    # -- internals ----------------------------------------------------------
    def _retire_no_fill(self, task: Task) -> None:
        slot = self.slots.get(task.tid)
        if slot is None:
            raise RuntimeError(f"task {task.tid} retired but not resident")
        if slot.state is not TaskState.EXECUTING:
            raise RuntimeError(f"task {task.tid} retired while {slot.state}")
        del self.slots[task.tid]
        self.scoreboard.retire(task.tid)
        for dep_tid in self._downstream.pop(task.tid, ()):
            dep = self.slots[dep_tid]
            dep.upstream.discard(task.tid)
            if not dep.upstream and dep.state is TaskState.PENDING:
                dep.state = TaskState.READY
                bisect.insort(self._ready, (dep.priority, dep.seq, dep_tid))
        self.stats.retired += 1

    def _fill(self) -> None:
        while self.fifo and len(self.slots) < self.size:
            task = self.fifo.popleft()
            # Pairwise-equivalent accounting: Algorithm 1 would have
            # checked the incoming kernel against every resident.
            self.stats.dep_checks += len(self.slots)
            # The actual check: probe only the intervals this kernel's
            # own segments touch (exact RAW/WAR/WAW upstream set).
            upstream = self.scoreboard.insert(
                task.tid, task.read_segments, task.write_segments
            )
            self.stats.scoreboard_probes = self.scoreboard.probe_cells
            for up_tid in upstream:
                self._downstream.setdefault(up_tid, set()).add(task.tid)
            state = TaskState.PENDING if upstream else TaskState.READY
            slot = _Slot(task, upstream, state, self._seq)
            self._seq += 1
            self.slots[task.tid] = slot
            if state is TaskState.READY:
                # Fresh insert: within its own bucket seq is the global
                # max, but a more-urgent bucket must jump ahead of every
                # lower one — append when it sorts last (the common
                # single-class case), bisect otherwise.
                entry = (slot.priority, slot.seq, task.tid)
                if not self._ready or entry > self._ready[-1]:
                    self._ready.append(entry)
                else:
                    bisect.insort(self._ready, entry)
            self.stats.inserted += 1
            self.stats.max_resident = max(self.stats.max_resident, len(self.slots))
