"""The ACS scheduling window (paper §III-C/D, Fig 14/15).

Faithful mechanics:

* kernels arrive through an **input FIFO** in program order;
* a fixed-size **window** (default N=32, the paper's chosen size) holds the
  kernels currently being tracked;
* on insertion, the incoming kernel is dependency-checked against every
  kernel already resident (Algorithm 1 over read/write segments) and the
  overlapping residents form its **upstream list**;
* a kernel whose upstream list is empty is **READY**; launched kernels are
  EXECUTING; on completion the kernel is retired, removed from every
  upstream list, and vacancies are refilled from the FIFO.

Note on Algorithm 1 as printed: it tests the incoming kernel's *writes*
against residents' reads+writes (WAR + WAW) only. Correctness also needs
RAW (incoming *reads* vs residents' writes) — §III-C's prose ("overlaps
between read segments and write segments") implies it; we implement the
full RAW/WAR/WAW check (`segments.depends_on`).

Because insertion order == program order, dependencies only ever point
from newer to older kernels; the window can never deadlock, and a window
of size 1 degenerates to the serial baseline (tested property).
"""

from __future__ import annotations

import collections
import enum
from typing import Deque, Dict, Iterable, List, Optional

from .segments import depends_on, window_upstreams
from .task import Task

__all__ = ["TaskState", "SchedulingWindow", "WindowStats"]


class TaskState(enum.Enum):
    PENDING = "pending"
    READY = "ready"
    EXECUTING = "executing"


class _Slot:
    __slots__ = ("task", "upstream", "state")

    def __init__(self, task: Task, upstream: set, state: TaskState):
        self.task = task
        self.upstream = upstream  # set of tids this task waits on
        self.state = state


class WindowStats:
    """Counters for the benchmarks (dep checks mirror Table II)."""

    def __init__(self) -> None:
        self.dep_checks = 0
        self.inserted = 0
        self.retired = 0
        self.max_resident = 0

    def as_dict(self) -> Dict[str, int]:
        return {
            "dep_checks": self.dep_checks,
            "inserted": self.inserted,
            "retired": self.retired,
            "max_resident": self.max_resident,
        }


class SchedulingWindow:
    def __init__(self, size: int = 32):
        if size < 1:
            raise ValueError("window size must be >= 1")
        self.size = size
        self.fifo: Deque[Task] = collections.deque()
        self.slots: "collections.OrderedDict[int, _Slot]" = collections.OrderedDict()
        self.stats = WindowStats()

    # -- producer side ----------------------------------------------------
    def submit(self, task: Task) -> None:
        self.fifo.append(task)
        self._fill()

    def submit_all(self, tasks: Iterable[Task]) -> None:
        self.fifo.extend(tasks)
        self._fill()

    # -- scheduler side ---------------------------------------------------
    def ready_tasks(self) -> List[Task]:
        """All READY kernels, oldest-first (they may launch concurrently)."""
        return [s.task for s in self.slots.values() if s.state is TaskState.READY]

    def mark_executing(self, task: Task) -> None:
        slot = self.slots[task.tid]
        if slot.state is not TaskState.READY:
            raise RuntimeError(f"task {task.tid} launched while {slot.state}")
        slot.state = TaskState.EXECUTING

    def retire(self, task: Task) -> None:
        """Kernel completed: drop it, update upstream lists, refill window."""
        slot = self.slots.pop(task.tid)
        if slot.state is not TaskState.EXECUTING:
            raise RuntimeError(f"task {task.tid} retired while {slot.state}")
        for other in self.slots.values():
            other.upstream.discard(task.tid)
            if not other.upstream and other.state is TaskState.PENDING:
                other.state = TaskState.READY
        self.stats.retired += 1
        self._fill()

    def drained(self) -> bool:
        return not self.fifo and not self.slots

    def resident(self) -> int:
        return len(self.slots)

    # -- internals ----------------------------------------------------------
    def _fill(self) -> None:
        while self.fifo and len(self.slots) < self.size:
            task = self.fifo.popleft()
            tids = list(self.slots.keys())
            self.stats.dep_checks += len(tids)
            # one vectorized interval pass over the whole window (Table II)
            mask = window_upstreams(
                task.read_segments,
                task.write_segments,
                [self.slots[t].task.read_segments for t in tids],
                [self.slots[t].task.write_segments for t in tids],
            )
            upstream = {tid for tid, hit in zip(tids, mask) if hit}
            state = TaskState.PENDING if upstream else TaskState.READY
            self.slots[task.tid] = _Slot(task, upstream, state)
            self.stats.inserted += 1
            self.stats.max_resident = max(self.stats.max_resident, len(self.slots))
