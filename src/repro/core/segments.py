"""Memory segment algebra — the dependency primitive of ACS.

The paper (Fig 13, Algorithm 1) detects inter-kernel dependencies by
checking overlap between the *write segments* of a newly arriving kernel
and the *read+write segments* of every kernel already in the scheduling
window (and vice versa: its reads against their writes — RAW, WAR and WAW
hazards all serialize).

A segment is a half-open interval ``[start, start+size)`` in a virtual
device address space (see ``buffers.py``). Overlap check is the classic
``start_1 < end_2 and end_1 > start_2`` from Algorithm 1.

Two implementations are provided:

* ``segments_overlap`` / ``any_overlap`` — scalar reference, used by the
  property tests as the oracle.
* ``SegmentSet`` — a small-array numpy representation enabling vectorized
  window-wide checks (the paper budgets ~0.4–1.6 us per check, Table II).

The vectorized whole-window scan (``window_upstreams`` / ``StackedWindow``)
was the production window's per-insertion check through PR 4; it is O(window
x segments^2) per insertion, which caps usable window sizes around the
paper's N=32. The live dependency authority is now the incremental
``core.scoreboard.IntervalScoreboard`` (O(segments x log intervals) per
insertion); the pairwise scan survives here as the *property-test oracle*
the scoreboard is asserted bit-identical against (``tests/test_scoreboard.py``)
and as the baseline leg of ``benchmarks/bench_depcheck.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Segment",
    "SegmentSet",
    "segments_overlap",
    "any_overlap",
    "depends_on",
    "window_upstreams",
    "StackedWindow",
    "pairwise_window_replay",
]


@dataclasses.dataclass(frozen=True)
class Segment:
    """Half-open address interval ``[start, start + size)``."""

    start: int
    size: int

    def __post_init__(self) -> None:
        if self.size < 0:
            raise ValueError(f"segment size must be >= 0, got {self.size}")

    @property
    def end(self) -> int:
        return self.start + self.size

    def overlaps(self, other: "Segment") -> bool:
        return segments_overlap(self, other)


def segments_overlap(a: Segment, b: Segment) -> bool:
    """Algorithm 1 inner test: half-open interval intersection.

    Empty segments (size 0) contain no addresses and never overlap — the
    strict inequalities only guarantee this when both intervals are
    non-empty, so guard explicitly.
    """
    if a.size == 0 or b.size == 0:
        return False
    return a.start < b.end and a.end > b.start


def any_overlap(xs: Iterable[Segment], ys: Sequence[Segment]) -> bool:
    """True iff any segment in ``xs`` overlaps any segment in ``ys``.

    O(|xs|*|ys|) scalar loop — the oracle the vectorized path is tested
    against (and a direct transcription of Algorithm 1's double loop).
    """
    for a in xs:
        for b in ys:
            if segments_overlap(a, b):
                return True
    return False


class SegmentSet:
    """Vectorized set of segments as parallel (start, end) numpy arrays.

    The window module holds one ``SegmentSet`` per kernel for its reads and
    one for its writes; a dependency check between a window-resident kernel
    and an incoming kernel is then 3 vectorized interval intersections
    (W_new x RW_old, R_new x W_old covered by RW_new x W_old + W_new x R_old).
    """

    __slots__ = ("starts", "ends", "_coalesced")

    def __init__(self, segments: Sequence[Segment] | None = None):
        if segments:
            self.starts = np.asarray([s.start for s in segments], dtype=np.int64)
            self.ends = np.asarray([s.end for s in segments], dtype=np.int64)
        else:
            self.starts = np.empty((0,), dtype=np.int64)
            self.ends = np.empty((0,), dtype=np.int64)
        self._coalesced: "SegmentSet | None" = None

    @classmethod
    def from_arrays(cls, starts: np.ndarray, ends: np.ndarray) -> "SegmentSet":
        out = cls()
        out.starts = np.asarray(starts, dtype=np.int64)
        out.ends = np.asarray(ends, dtype=np.int64)
        return out

    def __len__(self) -> int:
        return int(self.starts.shape[0])

    def __iter__(self):
        for s, e in zip(self.starts, self.ends):
            yield Segment(int(s), int(e - s))

    def union(self, other: "SegmentSet") -> "SegmentSet":
        return SegmentSet.from_arrays(
            np.concatenate([self.starts, other.starts]),
            np.concatenate([self.ends, other.ends]),
        )

    def coalesced(self) -> "SegmentSet":
        """Canonical form: sorted, empty segments dropped, adjacent or
        overlapping intervals merged. The covered address set — and hence
        every hazard verdict — is unchanged, but a task touching many
        contiguous row views of one buffer registers ONE scoreboard claim
        instead of one per row, cutting probe counts and boundary churn.
        Cached (segment sets are de facto immutable once built); returns
        ``self`` when already canonical."""
        if self._coalesced is not None:
            return self._coalesced
        n = len(self)
        if n == 0:
            self._coalesced = self
            return self
        starts, ends = self.starts, self.ends
        if bool(np.all(starts < ends)) and (
            n == 1 or bool(np.all(starts[1:] > ends[:-1]))
        ):
            self._coalesced = self  # already sorted, non-empty, disjoint
            return self
        keep = starts < ends
        ss, ee = starts[keep], ends[keep]
        order = np.argsort(ss, kind="stable")
        ss, ee = ss[order], ee[order]
        out_s: list = []
        out_e: list = []
        for s, e in zip(ss, ee):
            if out_e and s <= out_e[-1]:
                if e > out_e[-1]:
                    out_e[-1] = e
            else:
                out_s.append(s)
                out_e.append(e)
        merged = SegmentSet.from_arrays(
            np.asarray(out_s, dtype=np.int64), np.asarray(out_e, dtype=np.int64)
        )
        merged._coalesced = merged
        self._coalesced = merged
        return merged

    def intersects(self, other: "SegmentSet") -> bool:
        """Vectorized all-pairs interval overlap (broadcasted Algorithm 1)."""
        if len(self) == 0 or len(other) == 0:
            return False
        # (n, 1) vs (1, m) broadcast; tiny n*m for window-scale sets.
        # Empty segments (start == end) must not report overlap.
        return bool(
            np.any(
                (self.starts[:, None] < other.ends[None, :])
                & (self.ends[:, None] > other.starts[None, :])
                & (self.ends[:, None] > self.starts[:, None])
                & (other.ends[None, :] > other.starts[None, :])
            )
        )


class StackedWindow:
    """Pre-stacked (starts, ends, owner) arrays for a window's resident
    read and write segments: one broadcasted interval pass checks an
    incoming kernel against the whole window (Table II fast path).

    Demoted from the production dependency path to the *pairwise oracle*:
    the live window now maintains an incremental interval scoreboard
    (``core.scoreboard``), and this all-pairs form is what the scoreboard's
    upstream sets are property-tested against — plus the baseline leg of
    ``benchmarks/bench_depcheck.py`` showing where the O(window) scan
    stopped scaling."""

    __slots__ = ("n", "rs", "re", "own_r", "ws", "we", "own_w")

    def __init__(self, resident_reads: Sequence[SegmentSet],
                 resident_writes: Sequence[SegmentSet]):
        self.n = len(resident_reads)
        if self.n == 0:
            z = np.empty(0, np.int64)
            self.rs = self.re = self.ws = self.we = z
            self.own_r = self.own_w = z
            return
        self.rs = np.concatenate([r.starts for r in resident_reads])
        self.re = np.concatenate([r.ends for r in resident_reads])
        self.ws = np.concatenate([w.starts for w in resident_writes])
        self.we = np.concatenate([w.ends for w in resident_writes])
        self.own_r = np.concatenate(
            [np.full(len(r), i) for i, r in enumerate(resident_reads)]
        )
        self.own_w = np.concatenate(
            [np.full(len(w), i) for i, w in enumerate(resident_writes)]
        )

    def check(self, reads_new: SegmentSet, writes_new: SegmentSet) -> np.ndarray:
        """Boolean upstream mask over residents (RAW | WAR | WAW)."""
        n = self.n
        if n == 0:
            return np.zeros(0, dtype=bool)

        def hits(starts_a, ends_a, starts_b, ends_b, owners):
            if len(starts_a) == 0 or len(starts_b) == 0:
                return np.zeros(n, dtype=bool)
            m = (
                (starts_a[:, None] < ends_b[None])
                & (ends_a[:, None] > starts_b[None])
                & (ends_a[:, None] > starts_a[:, None])
                & (ends_b[None] > starts_b[None])
            ).any(axis=0)
            out = np.zeros(n, dtype=bool)
            np.logical_or.at(out, owners[m], True)
            return out

        dep = hits(reads_new.starts, reads_new.ends, self.ws, self.we, self.own_w)
        dep |= hits(writes_new.starts, writes_new.ends, self.rs, self.re, self.own_r)
        dep |= hits(writes_new.starts, writes_new.ends, self.ws, self.we, self.own_w)
        return dep


def window_upstreams(
    reads_new: SegmentSet,
    writes_new: SegmentSet,
    resident_reads: Sequence[SegmentSet],
    resident_writes: Sequence[SegmentSet],
) -> np.ndarray:
    """Vectorized whole-window check (stack + one broadcasted pass).

    The seed window called this per insertion; it is now the oracle the
    scoreboard path is asserted bit-identical against."""
    return StackedWindow(resident_reads, resident_writes).check(
        reads_new, writes_new
    )


def pairwise_window_replay(tasks, window_size: int):
    """Oracle replay of the seed scheduling window: fill each vacancy by
    dep-checking the incoming task against ALL residents via the
    whole-window scan, then drain in waves of dependency-free residents.

    Returns the wave schedule as lists of tids. This is the single shared
    copy of the demoted pairwise dependency logic: the scoreboard property
    tests assert the production window's schedule equals this replay
    bit-for-bit, and ``benchmarks/bench_window_size.py`` times it to show
    where the O(window x segments^2) path stopped scaling. ``tasks`` need
    only ``tid``/``read_segments``/``write_segments``.
    """
    import collections

    fifo = collections.deque(tasks)
    resident: "collections.OrderedDict[int, tuple]" = collections.OrderedDict()

    def fill():
        while fifo and len(resident) < window_size:
            t = fifo.popleft()
            tids = list(resident)
            mask = window_upstreams(
                t.read_segments, t.write_segments,
                [resident[x][0].read_segments for x in tids],
                [resident[x][0].write_segments for x in tids],
            )
            resident[t.tid] = (t, {x for x, hit in zip(tids, mask) if hit})

    fill()
    waves = []
    while resident:
        ready = [x for x, (_, up) in resident.items() if not up]
        if not ready:
            raise RuntimeError("pairwise replay stalled")
        waves.append(ready)
        for x in ready:
            del resident[x]
        for _, up in resident.values():
            up.difference_update(ready)
        fill()
    return waves


def depends_on(
    reads_new: SegmentSet,
    writes_new: SegmentSet,
    reads_old: SegmentSet,
    writes_old: SegmentSet,
) -> bool:
    """True iff the *new* kernel must wait for the *old* kernel.

    Hazards (paper §III-C: "checking for overlaps between read segments and
    write segments"):
      RAW: new reads  ∩ old writes
      WAR: new writes ∩ old reads
      WAW: new writes ∩ old writes
    """
    return (
        reads_new.intersects(writes_old)
        or writes_new.intersects(reads_old)
        or writes_new.intersects(writes_old)
    )
