"""Analytic device model for scheduling-policy evaluation (the role
Accel-Sim plays in the paper's §V: ACS-HW cannot run on real TPU/GPU
hardware from this container, so speedups and occupancy for the four
policies are derived from an explicit, calibratable cost model).

Model
-----
A device has ``units`` parallel execution slots (SM analogue). Kernel k
needs ``u_k = min(ctas_k, units)`` slots for ``t_k`` seconds where::

    t_k = max(flops_k / flops_rate, bytes_k / bytes_rate, min_kernel_us)

Policies (paper §VI configurations):

* ``serial``    — single stream: kernels run alone, back-to-back; each
                  pays ``launch_us``. Occupancy = small-kernel widths.
* ``acs_sw``    — windowed waves (this repo's WaveScheduler plan); kernels
                  in a wave run concurrently (shelf-packed onto ``units``);
                  each kernel pays ``launch_us + sync_us`` on its slot
                  (Algorithm 2's per-stream launch + StreamSync).
* ``acs_hw``    — same wave plan; per-kernel overhead is the hardware
                  window's dispatch latency (``hw_dispatch_us``, §IV-D:
                  N cycles ≈ 0.05-0.1 us) and no CPU sync.
* ``cudagraph`` — full-DAG level schedule, zero per-kernel overhead, plus
                  the measured host-side DAG construction time (per input
                  for dynamic graphs — the Fig 9 cost; amortized for
                  static graphs).

The model intentionally ignores second-order effects (L2 contention,
wave quantization) — it is for *policy comparison*, and its constants are
calibrated from the paper's own measurements (5-20 us launch+sync, §II-D).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .task import Task, operand_shape

__all__ = ["DeviceModel", "RTX3060_LIKE", "RTX3070_LIKE", "TPU_V5E_CORE",
           "kernel_time_us", "kernel_ctas", "shelf_makespan", "simulate"]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    units: int              # parallel kernel slots (SMs / cores)
    launch_us: float        # host kernel-launch overhead
    sync_us: float          # CPU<->device completion sync overhead
    hw_dispatch_us: float   # ACS-HW window dispatch latency
    flops_per_us: float
    bytes_per_us: float
    min_kernel_us: float = 1.0
    threads_per_cta: int = 256
    # achieved fraction of peak for small kernels (no deep pipelining,
    # cold caches, short grids) — calibrates absolute kernel times
    small_kernel_efficiency: float = 0.12
    num_streams: int = 4    # ACS-SW scheduler threads (paper §IV-B)


# paper §V hardware: RTX3060 (real runs), RTX3070 (Accel-Sim). The 3us
# kernel floor reflects measured small-kernel wall times on this device
# class (pipeline drain/fill + scheduling tails dominate tiny grids).
RTX3060_LIKE = DeviceModel("rtx3060", units=28, launch_us=5.0, sync_us=8.0,
                           hw_dispatch_us=0.08, flops_per_us=12.7e6,
                           bytes_per_us=360e3, min_kernel_us=3.0)
RTX3070_LIKE = DeviceModel("rtx3070", units=46, launch_us=5.0, sync_us=8.0,
                           hw_dispatch_us=0.08, flops_per_us=20.3e6,
                           bytes_per_us=448e3, min_kernel_us=3.0)
# TPU v5e single core, for the TPU-adapted wave analysis (roofline constants
# from the assignment: 197 TF/s bf16, 819 GB/s HBM). "units" models the 8
# independent lanes a wave-fused program can fill via batching.
TPU_V5E_CORE = DeviceModel("tpu-v5e", units=8, launch_us=10.0, sync_us=15.0,
                           hw_dispatch_us=0.1, flops_per_us=197e6,
                           bytes_per_us=819e3)


def kernel_time_us(task: Task, m: DeviceModel) -> float:
    eff = m.small_kernel_efficiency
    return max(task.cost_flops / (eff * m.flops_per_us),
               task.cost_bytes / (eff * m.bytes_per_us),
               m.min_kernel_us)


def kernel_ctas(task: Task, m: DeviceModel) -> int:
    elems = sum(int(np.prod(operand_shape(o))) for o in task.outputs)
    return max(1, -(-elems // m.threads_per_cta))


def shelf_makespan(
    items: Sequence[Tuple[int, float]], units: int
) -> Tuple[float, float]:
    """Greedy shelf packing of (width, time) items onto ``units`` slots.
    Returns (makespan_us, busy_slot_us)."""
    makespan = 0.0
    busy = 0.0
    cap = 0
    shelf_t = 0.0
    for u, t in sorted(items, key=lambda x: -x[1]):
        busy += u * t
        if cap + u > units and cap > 0:
            makespan += shelf_t
            cap, shelf_t = 0, 0.0
        cap += u
        shelf_t = max(shelf_t, t)
    makespan += shelf_t
    return makespan, busy


def simulate(
    waves: Sequence[Sequence[Task]],
    model: DeviceModel,
    policy: str,
    construct_us: float = 0.0,
) -> Dict[str, float]:
    """Model total device time + achieved occupancy for a wave plan.

    ``waves`` is the schedule trace: for ``serial`` pass one task per wave
    (program order); for acs/cudagraph pass the window/level plan.
    """
    total = construct_us
    busy_total = 0.0
    for wave in waves:
        if policy == "serial":
            for task in wave:
                t = kernel_time_us(task, model)
                u = min(kernel_ctas(task, model), model.units)
                total += t + model.launch_us
                busy_total += u * t
        else:
            if policy == "acs_sw":
                # per-kernel launch+sync runs on the K scheduler threads,
                # overlapping with device execution of other kernels: the
                # wave is bounded by max(device makespan, CPU issue rate).
                ovh = (model.launch_us + model.sync_us) / model.num_streams
            elif policy == "acs_hw":
                ovh = model.hw_dispatch_us
            elif policy == "cudagraph":
                ovh = 0.0
            else:
                raise ValueError(policy)
            items = []
            for task in wave:
                t = kernel_time_us(task, model)
                u = min(kernel_ctas(task, model), model.units)
                items.append((u, t))
                busy_total += u * t
            span, _ = shelf_makespan(items, model.units)
            total += max(span, ovh * len(wave))
    occupancy = busy_total / (model.units * total) if total > 0 else 0.0
    return {
        "time_us": total,
        "occupancy": min(occupancy, 1.0),
        "kernels": float(sum(len(w) for w in waves)),
        "policy_overhead_us": construct_us,
    }
