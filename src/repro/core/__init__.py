"""repro.core — ACS: windowed out-of-order kernel scheduling (the paper's
contribution), adapted to TPU/JAX. See DESIGN.md §2 for the mapping."""

from .arena import (ArenaAddress, ShapeClass, ShardTransferTable, SlabArena,
                    pad_shape, row_capacity)
from .buffers import Buffer, BufferPool, BufferView
from .dag_baseline import DagRunner, build_full_dag, level_schedule
from .device_dispatch import (
    DeviceOpRegistry,
    DeviceSession,
    DeviceWindowRunner,
    lower_plan,
    plan_active_fraction,
    plan_frontier,
    plan_waves,
)
from .executors import FusedWaveExecutor, GroupExecutor, SerialExecutor
from .frontier import AsyncFrontierScheduler, DispatchQueue, FrontierSession
from .mesh_session import MeshDeviceSession
from .perfmodel import (
    DeviceModel,
    RTX3060_LIKE,
    RTX3070_LIKE,
    TPU_V5E_CORE,
    simulate,
)
from .scheduler import (
    GroupTrace,
    PLAN_MODES,
    SCHEDULER_NAMES,
    SESSION_NAMES,
    SchedulerReport,
    ThreadedStreamScheduler,
    WaveScheduler,
    make_scheduler,
    make_session,
    run_serial,
)
from .session import SchedulerSession, TaskTicket, ThreadedSession, WaveSession
from .scoreboard import IntervalScoreboard
from .segments import Segment, SegmentSet, any_overlap, depends_on, segments_overlap
from .task import Task, operand_base, operand_dtype, operand_shape
from .window import SchedulingWindow, TaskState
from .wrapper import KERNEL_REGISTRY, AcsKernel, TaskStream, acs_kernel

__all__ = [
    "Buffer",
    "BufferPool",
    "BufferView",
    "DagRunner",
    "build_full_dag",
    "level_schedule",
    "ArenaAddress",
    "ShapeClass",
    "SlabArena",
    "pad_shape",
    "ShardTransferTable",
    "row_capacity",
    "DeviceOpRegistry",
    "DeviceSession",
    "DeviceWindowRunner",
    "MeshDeviceSession",
    "lower_plan",
    "plan_active_fraction",
    "plan_frontier",
    "plan_waves",
    "FusedWaveExecutor",
    "GroupExecutor",
    "SerialExecutor",
    "AsyncFrontierScheduler",
    "DispatchQueue",
    "FrontierSession",
    "SchedulerSession",
    "TaskTicket",
    "ThreadedSession",
    "WaveSession",
    "DeviceModel",
    "RTX3060_LIKE",
    "RTX3070_LIKE",
    "TPU_V5E_CORE",
    "simulate",
    "GroupTrace",
    "PLAN_MODES",
    "SCHEDULER_NAMES",
    "SESSION_NAMES",
    "SchedulerReport",
    "ThreadedStreamScheduler",
    "WaveScheduler",
    "make_scheduler",
    "make_session",
    "run_serial",
    "IntervalScoreboard",
    "Segment",
    "SegmentSet",
    "any_overlap",
    "depends_on",
    "segments_overlap",
    "Task",
    "operand_base",
    "operand_dtype",
    "operand_shape",
    "SchedulingWindow",
    "TaskState",
    "KERNEL_REGISTRY",
    "AcsKernel",
    "TaskStream",
    "acs_kernel",
]
