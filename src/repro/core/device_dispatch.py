"""ACS-HW analogue: the scheduling window lives on the device (DESIGN §2 A3).

The paper's ACS-HW moves the window into GPU hardware so that kernel
completion -> upstream update -> ready dispatch never round-trips to the
CPU. A TPU has no command processor we can extend, so the TPU-idiomatic
equivalent is a *device-resident window interpreter*:

1. The host runs the (cheap, windowed) dependency analysis ONCE per stream
   and emits a plan (wave-synchronous or frontier-grouped — `plan_waves` /
   `plan_frontier`), then lowers it over a **shape-class slab arena**
   (`core/arena.py`): every step is one homogeneous task group with a
   static ``(opcode, arity, input/output shape classes)`` spec plus dense
   int32 row tables — the moral equivalent of the upstream-id SRAM tables
   of Fig 20, generalized from one uniform ``(D,)`` shape to the real
   sim/dyn workloads (mixed shapes and dtypes, variable arity, row-view
   aliasing, multi-output tasks).
2. A single compiled program walks the steps (runs of identical step specs
   are compressed into ``lax.scan``s), gathering operand rows from the
   per-class slabs (cross-class gathers — inputs and outputs of one step
   may live in different slabs), applying the step's kernel (vmapped over
   the group), and scattering results back.

Host involvement: ONE dispatch for the whole stream — vs one per kernel
(serial) or one per wave (ACS-SW). This is exactly the communication
reduction ACS-HW claims, realized with jax control flow instead of SRAM
next to a command processor.

:class:`DeviceWindowRunner` is the *closed-batch* form: each ``run`` plans,
lowers, packs a fresh arena, and dispatches once. :class:`DeviceSession`
is the *persistent* form (DESIGN §2 A3): a live
:class:`~.session.SchedulerSession` whose window accepts ``submit``-ed
tasks at any time and drains them in **epochs** — each epoch lowers only
the newly admitted window slice against a session-lifetime
:class:`~.arena.SlabArena` (slabs stay device-resident across epochs;
host values re-sync only at retire boundaries) with a structure-keyed plan
cache at session scope, so recurring stream shapes skip re-lowering
entirely. That is the rolling-window half of ACS-HW the per-stream runner
cannot express: the dependency state and the operands live beside the
device for the whole program, and a new submission costs one epoch
dispatch, not a re-plan/repack of the world.

The seed's uniform-shape interpreter survives as the *legacy path*
(`compile_wave_plan` + `DeviceWindowRunner.execute_uniform`): operands
must share one padded shape ``(D,)``, opcodes must be arity-<=3 registry
branches. It now refuses over-arity tasks loudly instead of silently
truncating operand lists.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .arena import SlabArena
from .buffers import Buffer, BufferView
from .executors import ExecStats, SerialExecutor, group_by_signature
from .scheduler import PLAN_MODES, SchedulerReport
from .scoreboard import dependency_arrays
from .session import SchedulerSession
from .task import Task, operand_base, operand_shape
from .window import SchedulingWindow

__all__ = [
    "DeviceOpRegistry",
    "compile_wave_plan",
    "plan_waves",
    "plan_frontier",
    "plan_active_fraction",
    "lower_plan",
    "lower_epoch_program",
    "EpochProgram",
    "DeviceStep",
    "DeviceWindowRunner",
    "DeviceSession",
]

MAX_ARITY = 3  # legacy uniform-slab path only; the arena path has no limit


class DeviceOpRegistry:
    """The device interpreter's fixed opcode table (the paper's HW window
    supports a finite kernel set burned in next to the command processor).

    ``register`` assigns each kernel name a stable opcode. ``strict``
    registries refuse to lower tasks whose opcode was never registered —
    the faithful HW behaviour; non-strict registries auto-register on
    first sight (the software-managed table `make_scheduler("device")`
    uses, so any workload runs out of the box). During lowering the
    registry also records which shape classes each opcode was dispatched
    over (``classes_seen``) — the per-class registration benchmarks print.
    """

    def __init__(self, strict: bool = True) -> None:
        self._ops: List[Tuple[str, Optional[Callable]]] = []
        self._index: Dict[str, int] = {}
        self.strict = strict
        # opcode name -> set of (input class labels, output class labels)
        self.classes_seen: Dict[str, set] = {}
        # The ready-queue fast path's fixed kernel table: opcode name ->
        # elementwise shape-preserving branch fn the on-device lax.switch
        # may call. Eligibility requires a task's fn to BE the registered
        # branch (object identity), so the switch can never silently
        # diverge from what the host path would have executed.
        self._branch_fns: Dict[str, Callable] = {}

    def register(self, name: str, fn: Optional[Callable] = None) -> int:
        """Register ``name`` (idempotent). ``fn`` is the legacy uniform-path
        branch ``fn(x, y, z) -> out``; the arena path executes each task
        group's own wrapper-resolved callable and ignores it.

        Re-registering a known name upgrades an fn-less entry with the
        supplied branch fn; supplying a *different* fn for a name that
        already has one is a conflict and raises."""
        idx = self._index.get(name)
        if idx is not None:
            stored = self._ops[idx][1]
            if fn is not None:
                if stored is None:
                    self._ops[idx] = (name, fn)
                elif stored is not fn:
                    raise ValueError(
                        f"opcode {name!r} already registered with a different "
                        "branch fn; device opcodes are fixed per registry"
                    )
            return idx
        idx = len(self._ops)
        self._ops.append((name, fn))
        self._index[name] = idx
        return idx

    def opcode(self, name: str) -> int:
        idx = self._index.get(name)
        if idx is None:
            if not self.strict:
                return self.register(name)
            raise KeyError(
                f"opcode {name!r} is not in the device registry "
                f"(registered: {sorted(self._index) or 'none'}); register it "
                "or build the runner with an auto-registering registry"
            )
        return idx

    def note_classes(self, name: str, in_labels: Tuple[str, ...],
                     out_labels: Tuple[str, ...]) -> None:
        self.classes_seen.setdefault(name, set()).add((in_labels, out_labels))

    def register_switch_branch(self, name: str, fn: Callable) -> int:
        """Admit ``fn`` to the ready-queue fast path's fixed kernel table
        (and register the opcode name). Branches must be elementwise and
        row-shape-preserving — the Pallas loop stores each result over the
        task's output row. Re-registering the same fn is idempotent; a
        different fn for a known name is a conflict (the HW table is
        burned in)."""
        stored = self._branch_fns.get(name)
        if stored is not None and stored is not fn:
            raise ValueError(
                f"switch branch {name!r} already registered with a different "
                "fn; the device switch table is fixed per registry")
        self._branch_fns[name] = fn
        return self.register(name)

    def switch_branch(self, name: str) -> Optional[Callable]:
        """The registered fast-path branch fn for ``name`` (None if the
        opcode is interpreter-only)."""
        return self._branch_fns.get(name)

    @property
    def branches(self) -> List[Callable]:
        """Legacy uniform-path branch table (registration order). Opcode
        ints index this list inside ``lax.switch``, so every registered
        name must carry a branch fn to use the uniform interpreter."""
        missing = [n for n, fn in self._ops if fn is None]
        if missing:
            raise ValueError(
                "legacy uniform path needs an fn(x, y, z) branch for every "
                f"registered opcode; missing: {missing} (real kernels are "
                "registered fn-less — run them through the arena path)"
            )
        return [fn for _, fn in self._ops]

    def __contains__(self, name: str) -> bool:
        return name in self._index

    def __len__(self) -> int:
        return len(self._ops)


# ---------------------------------------------------------------------------
# Planning: run the windowed scheduler symbolically (no execution)
# ---------------------------------------------------------------------------

def plan_waves(tasks: Sequence[Task], window_size: int = 32,
               return_window: bool = False):
    """Run the windowed scheduler symbolically to obtain the wave plan.

    Planning cost rides the window's interval scoreboard: each insertion
    probes only its own segments' intervals, so planning at window
    128-512 costs barely more per task than at 32 (the seed's pairwise
    scan made large planning windows quadratic-feeling — see
    ``benchmarks/bench_window_size.py``).

    With ``return_window=True`` also returns the planning
    :class:`SchedulingWindow`, whose stats (dep checks, scoreboard
    probes, occupancy) are the real numbers behind the plan — the runner
    reports them instead of a fresh all-zero window.
    """
    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    waves: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning waves")
        for t in ready:
            window.mark_executing(t)
        waves.append(ready)
        window.retire_many(ready)
    return (waves, window) if return_window else waves


def plan_frontier(
    tasks: Sequence[Task], window_size: int = 32, max_group: Optional[int] = None,
    return_window: bool = False,
):
    """Frontier-plan mode: one homogeneous group per device step.

    Wave planning retires an entire front per step, so every step is
    padded to the *widest wave* and a slow-to-unblock kernel stretches the
    whole table. The frontier plan instead retires one homogeneous group at
    a time, re-collecting the READY set between groups — newly unblocked
    kernels join the very next step rather than waiting out the front.
    Steps are narrower but denser (higher active-slot fraction).
    """
    from .executors import group_by_signature

    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    groups: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning frontier groups")
        group = group_by_signature(ready)[0]
        if max_group is not None:
            group = group[:max_group]
        for t in group:
            window.mark_executing(t)
        window.retire_many(group)
        groups.append(group)
    return (groups, window) if return_window else groups


def plan_active_fraction(plan: Sequence[Sequence[Task]]) -> float:
    """Fraction of (step, slot) table cells holding a real kernel — the
    padding-waste metric the frontier plan improves."""
    if not plan:
        return 1.0
    max_w = max(len(step) for step in plan)
    return sum(len(step) for step in plan) / (len(plan) * max_w)


# ---------------------------------------------------------------------------
# Legacy lowering: one uniform (D,) shape class, arity <= 3
# ---------------------------------------------------------------------------

def compile_wave_plan(
    waves: Sequence[Sequence[Task]],
    registry: DeviceOpRegistry,
    buffer_index: Dict[str, int],
    n_rows: int,
) -> Dict[str, np.ndarray]:
    """Lower a wave schedule to dense dispatch tables (the 'SRAM' image).

    Legacy single-class path: every operand indexes one uniform slab and
    arity is capped at ``MAX_ARITY``. Over-arity tasks are an error here —
    the arena path (`lower_plan`) is the one without the limit.
    """
    n_waves = len(waves)
    max_w = max((len(w) for w in waves), default=1)
    dummy = n_rows  # slab has one extra scratch row
    opc = np.zeros((n_waves, max_w), dtype=np.int32)
    ins = np.full((n_waves, max_w, MAX_ARITY), dummy, dtype=np.int32)
    outs = np.full((n_waves, max_w), dummy, dtype=np.int32)
    active = np.zeros((n_waves, max_w), dtype=bool)
    for wi, wave in enumerate(waves):
        for si, task in enumerate(wave):
            if len(task.inputs) > MAX_ARITY:
                raise ValueError(
                    f"task {task.opcode}#{task.tid} has {len(task.inputs)} "
                    f"operands but the legacy uniform-slab path supports at "
                    f"most {MAX_ARITY}; use the arena path "
                    "(DeviceWindowRunner.execute) for variable arity"
                )
            if len(task.outputs) != 1:
                raise ValueError(
                    f"task {task.opcode}#{task.tid} has {len(task.outputs)} "
                    "outputs but the legacy uniform-slab path supports "
                    "exactly one; use the arena path "
                    "(DeviceWindowRunner.execute) for multi-output tasks"
                )
            opc[wi, si] = registry.opcode(task.opcode)
            for ai, op in enumerate(task.inputs):
                ins[wi, si, ai] = buffer_index[op.buffer.name if hasattr(op, "buffer") else op.name]
            outs[wi, si] = buffer_index[
                task.outputs[0].buffer.name if hasattr(task.outputs[0], "buffer") else task.outputs[0].name
            ]
            active[wi, si] = True
    return {"opcode": opc, "ins": ins, "outs": outs, "active": active}


# ---------------------------------------------------------------------------
# Arena lowering: per-class tables, variable arity, multi-output, views
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _OperandSpec:
    """Static half of one operand column (shared by the whole group)."""

    class_id: int
    true_shape: Tuple[int, ...]
    is_view: bool
    view_rows: int  # leading-axis rows covered when is_view


@dataclasses.dataclass(frozen=True)
class _StepSpec:
    """Static half of one device step: what gets compiled."""

    opcode: int
    width: int
    inputs: Tuple[_OperandSpec, ...]
    outputs: Tuple[_OperandSpec, ...]
    signature: Tuple  # group Task.signature — compile-cache identity


@dataclasses.dataclass
class DeviceStep:
    """One lowered step: one homogeneous task group, dense row tables.

    ``in_rows``/``out_rows`` are ``[n_operands, width]`` int32 slab row
    ids; ``*_starts`` carry the leading-axis offset for view operands
    (zero otherwise). The spec (opcode, width, shape classes) is static —
    identical specs across streams reuse one compiled program.
    """

    spec: _StepSpec
    fn: Callable
    in_rows: np.ndarray
    in_starts: np.ndarray
    out_rows: np.ndarray
    out_starts: np.ndarray
    tids: Tuple[int, ...]

    def tables(self) -> Dict[str, np.ndarray]:
        return {
            "in_rows": self.in_rows, "in_starts": self.in_starts,
            "out_rows": self.out_rows, "out_starts": self.out_starts,
        }


def _operand_spec(arena: SlabArena, op) -> Tuple[_OperandSpec, int, int]:
    """Returns (static spec, row, start) for one operand occurrence."""
    addr = arena.address(op)
    return (
        _OperandSpec(
            class_id=addr.class_id,
            true_shape=tuple(operand_shape(op)),
            is_view=addr.is_view,
            view_rows=addr.row_count if addr.is_view else 0,
        ),
        addr.row,
        addr.row_start,
    )


def _lowering_groups(wave: Sequence[Task], arena: SlabArena) -> List[List[Task]]:
    """Partition one plan step into arena-homogeneous groups, oldest-first.

    ``Task.signature`` alone is NOT enough here: it encodes operand value
    shapes, so a full ``(2, 4)`` buffer and a 2-row view of an ``(8, 4)``
    buffer are signature-equal (host executors batch them fine — they are
    value-based) yet need different gather/scatter code. The grouping key
    therefore also carries each operand's static arena addressing
    (class id, view-ness, view extent)."""

    def opkey(op):
        addr = arena.address(op)
        return (addr.class_id, addr.is_view, addr.row_count)

    groups: Dict[Tuple, List[Task]] = {}
    order: List[Tuple] = []
    for t in wave:
        key = (
            t.signature,
            tuple(opkey(o) for o in t.inputs),
            tuple(opkey(o) for o in t.outputs),
        )
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(t)
    return [groups[k] for k in order]


def lower_plan(
    plan: Sequence[Sequence[Task]],
    registry: DeviceOpRegistry,
    arena: SlabArena,
) -> List[DeviceStep]:
    """Lower a wave/frontier plan to arena-addressed device steps.

    Shared by both plan modes: each plan step (a wave, or an already
    homogeneous frontier group) is partitioned into arena-homogeneous
    groups (`_lowering_groups` — signature plus static arena addressing;
    tasks within a plan step are independent by construction, so sub-step
    order is free) and each group becomes one :class:`DeviceStep` with
    static (opcode, arity, shape classes) and dense per-operand row
    tables.
    """
    steps: List[DeviceStep] = []
    for wave in plan:
        for group in _lowering_groups(wave, arena):
            head = group[0]
            opcode = registry.opcode(head.opcode)
            n_in, n_out = len(head.inputs), len(head.outputs)
            width = len(group)
            in_specs: List[_OperandSpec] = []
            out_specs: List[_OperandSpec] = []
            in_rows = np.zeros((n_in, width), np.int32)
            in_starts = np.zeros((n_in, width), np.int32)
            out_rows = np.zeros((n_out, width), np.int32)
            out_starts = np.zeros((n_out, width), np.int32)
            for gi, task in enumerate(group):
                for i, op in enumerate(task.inputs):
                    spec, row, start = _operand_spec(arena, op)
                    in_rows[i, gi], in_starts[i, gi] = row, start
                    if gi == 0:
                        in_specs.append(spec)
                for o, op in enumerate(task.outputs):
                    spec, row, start = _operand_spec(arena, op)
                    out_rows[o, gi], out_starts[o, gi] = row, start
                    if gi == 0:
                        out_specs.append(spec)
            labels = tuple(arena.classes[s.class_id].label for s in in_specs)
            out_labels = tuple(arena.classes[s.class_id].label for s in out_specs)
            registry.note_classes(head.opcode, labels, out_labels)
            steps.append(
                DeviceStep(
                    spec=_StepSpec(opcode, width, tuple(in_specs),
                                   tuple(out_specs), head.signature),
                    fn=head.fn,
                    in_rows=in_rows, in_starts=in_starts,
                    out_rows=out_rows, out_starts=out_starts,
                    tids=tuple(t.tid for t in group),
                )
            )
    return steps


def _gather_operand(slabs, spec: _OperandSpec, rows, starts, width: int):
    """Gather one operand column: ``[width, *true_shape]`` (or unbatched
    when width == 1)."""
    slab = slabs[spec.class_id]
    if spec.is_view:
        rest = tuple(slab.shape[2:])  # padded row shape beyond the view axis
        zeros = (0,) * len(rest)

        def one(row, start):
            return jax.lax.dynamic_slice(
                slab[row], (start,) + zeros, (spec.view_rows,) + rest
            )

        vals = jax.vmap(one)(rows, starts) if width > 1 else one(rows[0], starts[0])
    else:
        vals = slab[rows] if width > 1 else slab[rows[0]]
    trim = tuple(slice(0, s) for s in spec.true_shape)
    if width > 1:
        trim = (slice(None),) + trim
    return vals[trim]


def _pad_value(val, target_shape: Tuple[int, ...]):
    if tuple(val.shape) == tuple(target_shape):
        return val
    pads = [(0, p - s) for s, p in zip(val.shape, target_shape)]
    return jnp.pad(val, pads)


def _scatter_operand(slabs, spec: _OperandSpec, rows, starts, width: int, val):
    """Scatter one output column back into its class slab."""
    slab = slabs[spec.class_id]
    padded_row = tuple(slab.shape[1:])
    if spec.is_view:
        # A view write updates a sub-interval of its parent's row. Within a
        # step two view writes may target the SAME parent row (disjoint
        # intervals — overlap would be a WAW hazard and land in different
        # steps), so the update must be sequential, not a vectorized
        # scatter that would drop all but one update to a duplicated row.
        target = (spec.view_rows,) + padded_row[1:]
        zeros = (0,) * (len(padded_row) - 1)
        for g in range(width):
            v = _pad_value(val[g] if width > 1 else val, target)
            row = rows[g]
            updated = jax.lax.dynamic_update_slice(
                slab[row], v.astype(slab.dtype), (starts[g],) + zeros
            )
            slab = slab.at[row].set(updated)
    else:
        if width > 1:
            v = jax.vmap(lambda x: _pad_value(x, padded_row))(val)
            slab = slab.at[rows].set(v.astype(slab.dtype))
        else:
            slab = slab.at[rows[0]].set(_pad_value(val, padded_row).astype(slab.dtype))
    out = list(slabs)
    out[spec.class_id] = slab
    return out


def _apply_step(slabs, spec: _StepSpec, fn: Callable, tables):
    ins = [
        _gather_operand(slabs, s, tables["in_rows"][i], tables["in_starts"][i],
                        spec.width)
        for i, s in enumerate(spec.inputs)
    ]
    out = jax.vmap(fn)(*ins) if spec.width > 1 else fn(*ins)
    outs = tuple(out) if isinstance(out, (tuple, list)) else (out,)
    if len(outs) != len(spec.outputs):
        raise ValueError(
            f"device step opcode {spec.opcode}: kernel returned {len(outs)} "
            f"values for {len(spec.outputs)} outputs"
        )
    for o, s in enumerate(spec.outputs):
        slabs = _scatter_operand(slabs, s, tables["out_rows"][o],
                                 tables["out_starts"][o], spec.width, outs[o])
    return slabs


def _build_program(
    steps: Sequence[DeviceStep],
) -> Tuple[Callable, List[Tuple[_StepSpec, Callable, int]]]:
    """Returns (jitted program, run segmentation). The program executes
    every lowered step; the segmentation tells `_run_tables` how to stack
    the per-step tables the program expects.

    Runs of consecutive steps with an identical static spec (the recurring
    structure of sim streams) collapse into a single ``lax.scan`` over
    their stacked row tables, bounding trace size by the number of
    *distinct* step specs in a run-length sense rather than total steps.
    """
    runs: List[Tuple[_StepSpec, Callable, int]] = []  # (spec, fn, run length)
    for st in steps:
        if runs and runs[-1][0] == st.spec:
            spec, fn, n = runs[-1]
            runs[-1] = (spec, fn, n + 1)
        else:
            runs.append((st.spec, st.fn, 1))

    def run_program(slabs, run_tables):
        slabs = list(slabs)
        for (spec, fn, length), tables in zip(runs, run_tables):
            if length == 1:
                slabs = _apply_step(slabs, spec, fn, tables)
            else:
                def body(carry, tbl, _spec=spec, _fn=fn):
                    return tuple(_apply_step(list(carry), _spec, _fn, tbl)), None

                carry, _ = jax.lax.scan(body, tuple(slabs), tables)
                slabs = list(carry)
        return tuple(slabs)

    return jax.jit(run_program), runs


def _run_tables(steps: Sequence[DeviceStep],
                runs: Sequence[Tuple[_StepSpec, Callable, int]]) -> List[Dict]:
    """Stack each run's per-step tables: [T, n_operands, width] for scans,
    plain [n_operands, width] for singleton runs."""
    tables: List[Dict] = []
    idx = 0
    for _, _, length in runs:
        chunk = steps[idx: idx + length]
        idx += length
        if length == 1:
            tables.append({k: jnp.asarray(v) for k, v in chunk[0].tables().items()})
        else:
            tables.append({
                k: jnp.asarray(np.stack([s.tables()[k] for s in chunk]))
                for k in chunk[0].tables()
            })
    return tables


# ---------------------------------------------------------------------------
# Ready-queue lowering: the whole dependency frontier in one dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EpochProgram:
    """One epoch lowered as a device-resident ready-queue program.

    Static halves (``specs``/``fns``/``opnames`` — what gets compiled) are
    separated from the device operands: per-spec dense address tables, the
    per-task ``(spec_id, spec_pos)`` dispatch map, the dependency arrays
    from :func:`~.scoreboard.dependency_arrays`, and the initial ring
    state. Order is decided *on device* by the queue; the tables only say
    where each task's operands live and who it wakes.
    """

    specs: Tuple[_StepSpec, ...]
    fns: Tuple[Callable, ...]
    opnames: Tuple[str, ...]
    spec_tables: List[Dict[str, np.ndarray]]  # per spec: [n_operands, count]
    spec_id: np.ndarray    # [n] int32: task position -> spec index
    spec_pos: np.ndarray   # [n] int32: task position -> column in its tables
    indeg: np.ndarray      # [n] int32 initial upstream counters
    dep_tbl: np.ndarray    # [n, m] int32 forward edges, sentinel n
    ring0: np.ndarray      # [n+1] int32 initially-ready positions, pad n
    tail0: int             # count of initially-ready tasks
    tids: Tuple[int, ...]

    @property
    def n_tasks(self) -> int:
        return len(self.tids)

    def payload(self) -> Dict[str, Any]:
        """The device-operand half, as jnp arrays (upload once, reuse
        across epochs via the plan cache)."""
        return {
            "tables": tuple(
                {k: jnp.asarray(v) for k, v in tbl.items()}
                for tbl in self.spec_tables),
            "spec_id": jnp.asarray(self.spec_id),
            "spec_pos": jnp.asarray(self.spec_pos),
            "dep_tbl": jnp.asarray(self.dep_tbl),
            "rem0": jnp.asarray(
                np.concatenate([self.indeg, np.zeros(1, np.int32)])),
            "ring0": jnp.asarray(self.ring0),
            "tail0": jnp.asarray([self.tail0], jnp.int32),
        }


def lower_epoch_program(tasks: Sequence[Task], registry: DeviceOpRegistry,
                        arena: SlabArena) -> EpochProgram:
    """Lower one epoch (tasks in program order) to a ready-queue program.

    Unlike :func:`lower_plan`, no host-side wave/frontier schedule exists:
    tasks group purely by structure (`_lowering_groups` over the whole
    epoch — signature + static arena addressing), each group contributing
    one spec and dense per-task address columns, and the exact dependency
    arrays ride along so the device can discover the execution order
    itself. Program order is topological (the window admits in program
    order), so every edge points forward and the queue never starves.
    """
    tasks = list(tasks)
    n = len(tasks)
    groups = _lowering_groups(tasks, arena)
    # Canonical group order: _lowering_groups returns first-occurrence
    # order, so two epochs over the SAME spec set but different arrival
    # interleavings would produce permuted `specs` tuples — distinct
    # program-cache keys and distinct jit traces for identical programs.
    # Spec order is semantically free here (the queue dispatches per task
    # through spec_id), so sort by structure and collapse the permutations.
    def _group_key(g):
        head = g[0]
        return (head.opcode, repr(head.signature),
                repr([(arena.address(o).class_id, arena.address(o).is_view,
                       arena.address(o).row_count)
                      for o in tuple(head.inputs) + tuple(head.outputs)]))

    groups.sort(key=_group_key)
    specs: List[_StepSpec] = []
    fns: List[Callable] = []
    opnames: List[str] = []
    spec_tables: List[Dict[str, np.ndarray]] = []
    spec_id = np.zeros(n, np.int32)
    spec_pos = np.zeros(n, np.int32)
    pos = {t.tid: i for i, t in enumerate(tasks)}
    for s, group in enumerate(groups):
        head = group[0]
        opcode = registry.opcode(head.opcode)
        n_in, n_out = len(head.inputs), len(head.outputs)
        count = len(group)
        in_specs: List[_OperandSpec] = []
        out_specs: List[_OperandSpec] = []
        tbl = {
            "in_rows": np.zeros((n_in, count), np.int32),
            "in_starts": np.zeros((n_in, count), np.int32),
            "out_rows": np.zeros((n_out, count), np.int32),
            "out_starts": np.zeros((n_out, count), np.int32),
        }
        for gi, task in enumerate(group):
            spec_id[pos[task.tid]] = s
            spec_pos[pos[task.tid]] = gi
            for i, op in enumerate(task.inputs):
                spec, row, start = _operand_spec(arena, op)
                tbl["in_rows"][i, gi], tbl["in_starts"][i, gi] = row, start
                if gi == 0:
                    in_specs.append(spec)
            for o, op in enumerate(task.outputs):
                spec, row, start = _operand_spec(arena, op)
                tbl["out_rows"][o, gi], tbl["out_starts"][o, gi] = row, start
                if gi == 0:
                    out_specs.append(spec)
        registry.note_classes(
            head.opcode,
            tuple(arena.classes[sp.class_id].label for sp in in_specs),
            tuple(arena.classes[sp.class_id].label for sp in out_specs))
        # width=1: the queue executes tasks one at a time, each slicing its
        # own column; the spec's signature keeps compile-cache identity.
        specs.append(_StepSpec(opcode, 1, tuple(in_specs), tuple(out_specs),
                               head.signature))
        fns.append(head.fn)
        opnames.append(head.opcode)
        spec_tables.append(tbl)

    indeg, dep_tbl = dependency_arrays(tasks)
    ready = np.flatnonzero(indeg == 0)
    ring0 = np.full(n + 1, n, np.int32)
    ring0[: len(ready)] = ready
    return EpochProgram(
        specs=tuple(specs), fns=tuple(fns), opnames=tuple(opnames),
        spec_tables=spec_tables, spec_id=spec_id, spec_pos=spec_pos,
        indeg=indeg, dep_tbl=dep_tbl, ring0=ring0, tail0=int(len(ready)),
        tids=tuple(t.tid for t in tasks),
    )


def _bucket(n: int, minimum: int = 8) -> int:
    """Next power-of-two bucket >= n (floored at ``minimum``)."""
    b = minimum
    while b < n:
        b *= 2
    return b


def _padded_loop_payload(program: EpochProgram) -> Dict[str, Any]:
    """Bucket-pad the interpreter payload so jit signatures quantize.

    The loop interpreter's trace signature is the payload's SHAPES: task
    count ``n``, per-spec column counts, and dependency width ``m``. A
    live-fed window sees a near-continuous spread of all three, and every
    new combination silently retraces + XLA-compiles — which dominates
    wall time for small irregular kernels (exactly the regime the paper
    targets). Padding each dimension to a power-of-two bucket collapses
    that spread to O(log) signatures per spec set.

    Pad tasks are unreachable: their counters start at 1 and nothing
    points at them, so the ``head < tail`` loop drains exactly the real
    tasks and exits (this is why the Pallas path — whose ``fori_loop``
    pops exactly ``n`` tasks — keeps the exact payload instead). Dep-table
    sentinels are remapped from ``n`` to the padded count so they keep
    landing in the trash slot of ``remaining``/``ring``.
    """
    n = program.n_tasks
    n_p = _bucket(n)
    m = program.dep_tbl.shape[1]
    m_p = _bucket(max(m, 1), minimum=2)
    spec_id = np.zeros(n_p, np.int32)
    spec_id[:n] = program.spec_id
    spec_pos = np.zeros(n_p, np.int32)
    spec_pos[:n] = program.spec_pos
    dep_block = program.dep_tbl.astype(np.int32, copy=True)
    dep_block[dep_block == n] = n_p
    dep_tbl = np.full((n_p, m_p), n_p, np.int32)
    dep_tbl[:n, :m] = dep_block
    rem0 = np.ones(n_p + 1, np.int32)  # pad tasks never reach zero
    rem0[:n] = program.indeg
    rem0[n_p] = 0  # trash slot
    ring0 = np.full(n_p + 1, n_p, np.int32)
    ring0[: program.tail0] = program.ring0[: program.tail0]
    tables = []
    for tbl in program.spec_tables:
        count = tbl["in_rows"].shape[1] if tbl["in_rows"].size else \
            tbl["out_rows"].shape[1]
        c_p = _bucket(count)
        padded = {}
        for k, v in tbl.items():
            out = np.zeros((v.shape[0], c_p), np.int32)
            out[:, : v.shape[1]] = v
            padded[k] = jnp.asarray(out)
        tables.append(padded)
    return {
        "tables": tuple(tables),
        "spec_id": jnp.asarray(spec_id),
        "spec_pos": jnp.asarray(spec_pos),
        "dep_tbl": jnp.asarray(dep_tbl),
        "rem0": jnp.asarray(rem0),
        "ring0": jnp.asarray(ring0),
        "tail0": jnp.asarray([program.tail0], jnp.int32),
    }


def _build_loop_interpreter(specs: Sequence[_StepSpec],
                            fns: Sequence[Callable]) -> Callable:
    """The general ready-queue executor: a ``lax.while_loop`` over the
    slabs + counter/ring/flag state. Structurally the Pallas kernel
    (`kernels/ready_queue.py`) with none of its eligibility limits —
    views, mixed classes, multi-output and arbitrary arity all work, each
    task dispatching through ``lax.switch`` to its spec's column-sliced
    ``_apply_step``. One dispatch advances the whole frontier."""

    def run(slabs, payload):
        tables = payload["tables"]
        spec_id, spec_pos = payload["spec_id"], payload["spec_pos"]
        dep_tbl = payload["dep_tbl"]
        n = spec_id.shape[0]

        branches = []
        for s, (spec, fn) in enumerate(zip(specs, fns)):
            def br(operand, _spec=spec, _fn=fn, _s=s):
                slabs_, p = operand
                tbl = {k: jax.lax.dynamic_slice_in_dim(v, p, 1, axis=1)
                       for k, v in tables[_s].items()}
                return tuple(_apply_step(list(slabs_), _spec, _fn, tbl))
            branches.append(br)

        def cond(state):
            _, _, _, _, head, tail = state
            return head < tail

        def body(state):
            slabs_, remaining, ring, done, head, tail = state
            t = ring[head]
            slabs_ = jax.lax.switch(spec_id[t], branches,
                                    (slabs_, spec_pos[t]))
            done = done.at[t].set(1)
            deps = dep_tbl[t]  # [m], sentinel n lands in the trash slot
            remaining = remaining.at[deps].add(-1)
            newly = ((deps < n) & (remaining[deps] == 0)).astype(jnp.int32)
            offs = jnp.cumsum(newly) - newly
            slot = jnp.where(newly == 1, tail + offs, n)
            ring = ring.at[slot].set(deps)
            return (slabs_, remaining, ring, done, head + 1,
                    tail + jnp.sum(newly))

        state = (tuple(slabs), payload["rem0"], payload["ring0"],
                 jnp.zeros(n, jnp.int32), jnp.int32(0),
                 payload["tail0"][0])
        out = jax.lax.while_loop(cond, body, state)
        return out[0], out[3]

    return jax.jit(run)


def _loop_pallas_parts(program: EpochProgram, registry: DeviceOpRegistry,
                       arena: SlabArena):
    """Fast-path eligibility: ``(class_id, branches)`` when every spec fits
    the Pallas ready-queue kernel, else None. Requirements: one shape
    class with padding-free 2-D rows, no views, arity <= 3, exactly one
    output, and every fn IS its opcode's registered switch branch."""
    if not program.specs:
        return None
    cids = {sp.class_id for st in program.specs
            for sp in st.inputs + st.outputs}
    if len(cids) != 1:
        return None
    cid = cids.pop()
    padded = arena.classes[cid].padded_shape
    if len(padded) != 1:
        return None
    branches = []
    for spec, fn, name in zip(program.specs, program.fns, program.opnames):
        if len(spec.outputs) != 1 or len(spec.inputs) > 3:
            return None
        for sp in spec.inputs + spec.outputs:
            if sp.is_view or tuple(sp.true_shape) != tuple(padded):
                return None
        if registry.switch_branch(name) is not fn:
            return None
        arity = len(spec.inputs)
        branches.append(lambda x, y, z, _fn=fn, _k=arity:
                        _fn(*((x, y, z)[:_k])))
    return cid, tuple(branches)


def _loop_task_table(program: EpochProgram) -> np.ndarray:
    """Flatten the per-spec tables into the Pallas kernel's ``[n, 5]``
    dispatch rows ``(branch, in0, in1, in2, out_row)``; unused input slots
    alias the task's own output row (always a valid slab index)."""
    n = program.n_tasks
    task_tbl = np.zeros((n, 5), np.int32)
    for i in range(n):
        s = int(program.spec_id[i])
        col = int(program.spec_pos[i])
        tbl = program.spec_tables[s]
        out_row = int(tbl["out_rows"][0, col])
        rows = [int(r) for r in tbl["in_rows"][:, col]]
        rows += [out_row] * (3 - len(rows))
        task_tbl[i] = [s] + rows + [out_row]
    return task_tbl


def _build_loop_pallas(class_id: int, branches: Tuple[Callable, ...],
                       interpret: bool) -> Callable:
    """Wrap the Pallas ready-queue kernel in the same (slabs, payload)
    calling convention as the interpreter, so the session's dispatch path
    is executor-agnostic."""
    from ..kernels.ready_queue import ready_queue_call

    def run(slabs, payload):
        slab, done = ready_queue_call(
            slabs[class_id], payload["task_tbl"], payload["dep_tbl"],
            payload["ring0"], payload["rem0"], payload["tail0"],
            branches=branches, interpret=interpret)
        out = list(slabs)
        out[class_id] = slab
        return tuple(out), done

    return run


class DeviceWindowRunner:
    """Compile once, then execute entire task streams in ONE dispatch.

    The arena path (``execute`` / ``run``) handles the real workloads:
    mixed shape classes, variable arity, multi-output tasks, row-view
    aliasing. It conforms to the ``make_scheduler`` contract — ``run``
    takes a task iterable and returns a :class:`SchedulerReport` whose
    window stats come from the planning pass (the dependency checks that
    actually happened), ``exec_stats.dispatches == 1`` per stream, and
    arena occupancy lands in ``report.arena_stats``.
    """

    def __init__(
        self,
        registry: Optional[DeviceOpRegistry] = None,
        window_size: int = 32,
        plan_mode: str = "wave",
        max_group: Optional[int] = None,
        pad_multiple: int = 8,
        loop_pallas: Optional[bool] = None,
    ):
        if plan_mode not in PLAN_MODES:
            raise ValueError(f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
        self.registry = registry if registry is not None else DeviceOpRegistry(strict=False)
        self.window_size = window_size
        self.plan_mode = plan_mode
        self.max_group = max_group
        self.pad_multiple = pad_multiple
        # plan_mode="loop" executor selection: None = Pallas on TPU when a
        # stream is eligible (interpreter elsewhere), True = force the
        # Pallas kernel (interpret mode off-TPU; still requires
        # eligibility), False = lax.while_loop interpreter always.
        self.loop_pallas = loop_pallas
        self._compiled: Dict[Tuple, Tuple[Callable, Any]] = {}
        self._compiled_uniform: Dict[Tuple, Callable] = {}
        self.stats: Dict[str, Any] = {}

    def session(self) -> "DeviceSession":
        """Open a persistent :class:`DeviceSession` sharing this runner's
        opcode registry (each session owns its own arena — buffer rows bind
        to one session's slabs for its lifetime)."""
        return DeviceSession(window_size=self.window_size,
                             registry=self.registry,
                             plan_mode=self.plan_mode,
                             max_group=self.max_group,
                             pad_multiple=self.pad_multiple,
                             loop_pallas=self.loop_pallas)

    # -- shared planning ---------------------------------------------------
    def _plan(self, tasks: Sequence[Task]):
        if self.plan_mode == "frontier":
            return plan_frontier(tasks, self.window_size, self.max_group,
                                 return_window=True)
        return plan_waves(tasks, self.window_size, return_window=True)

    # -- arena path (the real workloads) -----------------------------------
    def run(self, stream: Iterable[Task]) -> SchedulerReport:
        """`make_scheduler` contract: task iterable in, report out."""
        return self.execute(list(stream))

    def execute(
        self,
        tasks: Sequence[Task],
        buffers: Optional[Sequence] = None,
    ) -> SchedulerReport:
        from .executors import ExecStats

        if self.plan_mode == "loop":
            return self._execute_loop(list(tasks), buffers)
        tasks = list(tasks)
        t0 = time.perf_counter()
        plan, window = self._plan(tasks)

        arena = SlabArena(pad_multiple=self.pad_multiple)
        if buffers is not None:
            for b in buffers:
                arena.add(b)
        arena.add_tasks(tasks)
        steps = lower_plan(plan, self.registry, arena)
        plan_time = time.perf_counter() - t0

        stats = ExecStats()
        key = (
            tuple(st.spec for st in steps),
            tuple((c.padded_shape, c.dtype, len(arena.rows(i)))
                  for i, c in enumerate(arena.classes)),
        )
        cached = self._compiled.get(key)
        if cached is None:
            cached = _build_program(steps)
            self._compiled[key] = cached
            stats.compiles += 1
        run_fn, runs = cached

        slabs = arena.pack()
        tables = _run_tables(steps, runs)
        t1 = time.perf_counter()
        out_slabs = run_fn(tuple(slabs), tables)
        jax.block_until_ready(out_slabs)
        exec_time = time.perf_counter() - t1
        written = [operand_base(op) for t in tasks for op in t.outputs]
        arena.unpack(out_slabs, only=None if buffers is not None else written)

        stats.dispatches = 1  # the whole stream was one launch
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(w) for w in plan]
        stats.exec_seconds = exec_time
        report = SchedulerReport(
            window, stats, plan_time + exec_time,
            [[t.tid for t in w] for w in plan],
        )
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.plan_active_fraction = plan_active_fraction(plan)  # type: ignore[attr-defined]
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": arena.n_classes(),
            "total_waste_frac": round(arena.total_waste_frac(), 4),
            "per_class": arena.padding_waste(),
            "device_steps": len(steps),
        }
        return report

    def _execute_loop(
        self,
        tasks: List[Task],
        buffers: Optional[Sequence] = None,
    ) -> SchedulerReport:
        """plan_mode="loop": lower the whole stream as ONE ready-queue
        program — no host-side wave/frontier schedule at all; the device
        discovers execution order from the dependency arrays. The planning
        window still runs symbolically for its stats (the dependency
        checks are real either way), and the one host sync at the end
        asserts every completion flag — the queue provably drained."""
        t0 = time.perf_counter()
        _, window = plan_waves(tasks, self.window_size, return_window=True)

        arena = SlabArena(pad_multiple=self.pad_multiple)
        if buffers is not None:
            for b in buffers:
                arena.add(b)
        arena.add_tasks(tasks)
        program = lower_epoch_program(tasks, self.registry, arena)
        parts = None
        if self.loop_pallas is None:
            if jax.default_backend() == "tpu":
                parts = _loop_pallas_parts(program, self.registry, arena)
        elif self.loop_pallas:
            parts = _loop_pallas_parts(program, self.registry, arena)
        plan_time = time.perf_counter() - t0

        stats = ExecStats()
        key = ("loop", program.specs, program.dep_tbl.shape[1],
               parts is not None,
               tuple((c.padded_shape, c.dtype, len(arena.rows(i)))
                     for i, c in enumerate(arena.classes)))
        run_fn = self._compiled.get(key)
        if run_fn is None:
            if parts is not None:
                run_fn = _build_loop_pallas(
                    parts[0], parts[1],
                    interpret=jax.default_backend() != "tpu")
            else:
                run_fn = _build_loop_interpreter(program.specs, program.fns)
            self._compiled[key] = run_fn
            stats.compiles += 1
        payload = program.payload()
        if parts is not None:
            payload["task_tbl"] = jnp.asarray(_loop_task_table(program))

        slabs = arena.pack()
        t1 = time.perf_counter()
        out_slabs, done = run_fn(tuple(slabs), payload)
        jax.block_until_ready(out_slabs)
        exec_time = time.perf_counter() - t1
        done_host = np.asarray(done)
        if not bool(done_host.all()):
            missing = [program.tids[i]
                       for i in np.flatnonzero(done_host == 0)]
            raise RuntimeError(
                f"ready-queue epoch stalled: tasks {missing} never became "
                "ready (dependency arrays disagree with program order)")
        written = [operand_base(op) for t in tasks for op in t.outputs]
        arena.unpack(out_slabs, only=None if buffers is not None else written)

        stats.dispatches = 1
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(tasks)]
        stats.exec_seconds = exec_time
        report = SchedulerReport(
            window, stats, plan_time + exec_time,
            [[t.tid for t in tasks]],
        )
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        # Dense by construction: every table column holds a real task.
        report.plan_active_fraction = 1.0  # type: ignore[attr-defined]
        report.loop_executor = (  # type: ignore[attr-defined]
            "pallas" if parts is not None else "interpreter")
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": arena.n_classes(),
            "total_waste_frac": round(arena.total_waste_frac(), 4),
            "per_class": arena.padding_waste(),
            "device_steps": len(program.specs),
        }
        return report

    # -- legacy uniform path (seed behaviour, kept for the toy universe) ---
    def _uniform_interpreter(self):
        branches = self.registry.branches

        def step(slab, wave):
            # slab: [rows+1, D]; wave tables: opcode [S], ins [S,3], outs [S], active [S]
            def slot(opcode, in_ids, out_id, act):
                x = slab[in_ids[0]]
                y = slab[in_ids[1]]
                z = slab[in_ids[2]]
                res = jax.lax.switch(opcode, branches, x, y, z)
                return jnp.where(act, res, slab[out_id]), out_id

            results, out_ids = jax.vmap(slot)(
                wave["opcode"], wave["ins"], wave["outs"], wave["active"]
            )
            slab = slab.at[out_ids].set(results)
            return slab, None

        def run(slab, plan):
            slab, _ = jax.lax.scan(step, slab, plan)
            return slab

        return run

    def execute_uniform(
        self,
        tasks: Sequence[Task],
        buffers: Sequence,  # core.buffers.Buffer, uniform padded shape (D,)
    ) -> SchedulerReport:
        """The seed's single-shape-class interpreter (lax.switch over
        registry branches, arity <= 3, single output). Kept as the legacy
        reference; `execute` is the general path."""
        from .executors import ExecStats

        t0 = time.perf_counter()
        plan, window = self._plan(tasks)
        plan_time = time.perf_counter() - t0

        buffer_index = {b.name: i for i, b in enumerate(buffers)}
        n_rows = len(buffers)
        tables = compile_wave_plan(plan, self.registry, buffer_index, n_rows)

        d = int(buffers[0].shape[-1])
        key = (tables["opcode"].shape, d, len(self.registry))
        run = self._compiled_uniform.get(key)
        if run is None:
            run = jax.jit(self._uniform_interpreter())
            self._compiled_uniform[key] = run
        slab = jnp.stack([jnp.asarray(b.value) for b in buffers]
                         + [jnp.zeros((d,), dtype=buffers[0].value.dtype)])
        dev_plan = {k: jnp.asarray(v) for k, v in tables.items()}
        t1 = time.perf_counter()
        slab = run(slab, dev_plan)
        slab.block_until_ready()
        exec_time = time.perf_counter() - t1
        for i, b in enumerate(buffers):
            b.value = slab[i]

        stats = ExecStats()
        stats.dispatches = 1
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(w) for w in plan]
        stats.exec_seconds = exec_time
        report = SchedulerReport(window, stats, plan_time + exec_time,
                                 [[t.tid for t in w] for w in plan])
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.plan_active_fraction = plan_active_fraction(plan)  # type: ignore[attr-defined]
        return report


# ---------------------------------------------------------------------------
# Persistent device window: the live-session form of the ACS-HW analogue
# ---------------------------------------------------------------------------

def _device_lowerable(task: Task) -> bool:
    """True iff every operand can live in the slab arena: array-valued (or
    not-yet-produced) buffers whose values match their declared shapes.
    Opaque pytree values (e.g. serving KV-cache tuples) and raw byte views
    fall back to the host path inside the epoch."""
    for op in tuple(task.inputs) + tuple(task.outputs):
        if isinstance(op, BufferView) and op.row_start is None:
            return False
        base = operand_base(op)
        val = base.value
        if val is None:
            continue
        shape = getattr(val, "shape", None)
        if shape is None or getattr(val, "dtype", None) is None:
            return False
        if tuple(shape) != tuple(base.shape):
            return False
    return True


def _array_ready(arr: Any) -> bool:
    """Non-blocking completion probe for an async-dispatched jax array
    (True = the producing computation landed). Arrays without the probe
    (older jax, plain numpy from a host fallback) count as ready — the
    blocking retire path still guarantees correctness."""
    try:
        return bool(arr.is_ready())
    except AttributeError:
        return True


class DeviceSession(SchedulerSession):
    """Persistent device-resident window: the rolling, live-fed ACS-HW
    analogue (DESIGN §2 A3).

    Producers ``submit()`` tasks (or feed a ``TaskStream(sink=session)``)
    at any time; each ``poll``/``drive`` drains everything admitted so far
    as one **epoch**:

    1. the live window is planned symbolically (wave fronts or frontier
       groups, exactly like the per-stream runner) — cross-epoch RAW/WAR
       edges were already resolved at insertion by the window, and epoch
       ordering retires them;
    2. the epoch's slice is lowered against the **session-lifetime arena**:
       slabs stay device-resident across epochs (only rows for newly seen
       buffers are appended), and a **structure-keyed plan cache** maps a
       recurring (signatures × arena addresses) slice straight to its
       lowered tables and compiled program — re-lowering is skipped
       entirely, the common case for RL sim steps and decode chains;
    3. the slice executes in ONE dispatch; host values re-sync only at
       retire boundaries (an epoch whose tasks have listeners, completion
       callbacks, or tickets; an explicit ``flush``/``close``/``sync``) —
       ``host_syncs`` counts them.

    Tasks whose operands cannot live in the arena (opaque pytree values,
    raw byte views) execute host-side *within* the epoch, interleaved in
    plan order with slab re-sync at each device/host transition — so the
    session still accepts any workload the host sessions accept.

    Device residency is a CONTRACT with the producer: while the session is
    open, buffers it has packed must be written only *through submitted
    tasks* — a direct host-side write to ``buf.value`` between epochs is
    invisible to the slabs (the host sessions would honor it) and the
    stale row wins. Symmetrically, reading ``buf.value`` after a bare
    ``poll()`` (no callback/ticket on the task) may observe a pre-epoch
    value until the next retire-boundary sync; call ``sync()`` (or
    ``flush``/``close``) before trusting direct reads.

    ``plan_mode="loop"`` replaces the host-scheduled step table with the
    **device-resident ready-queue executor** (DESIGN §2 A3): the epoch's
    tasks lower to per-spec address tables plus exact dependency arrays
    (`lower_epoch_program`), and a single ``lax.while_loop`` dispatch (or
    the Pallas kernel in ``kernels/ready_queue.py`` when the stream is
    switch-branch eligible) pops tasks as their on-device counters hit
    zero — retirement wakes dependents without ANY host round-trip, and
    tasks only transitively ready at launch still run in that dispatch.

    Per-epoch stats land in ``epoch_log`` and the aggregate in
    ``session_stats()`` / ``report.session_stats``: epochs, device
    dispatches (``loop_dispatches`` for ready-queue ones), plan-cache
    hits/misses, host syncs (d2h/h2d split, per stream tag), padding
    waste.
    """

    def __init__(
        self,
        window_size: int = 32,
        registry: Optional[DeviceOpRegistry] = None,
        plan_mode: str = "wave",
        max_group: Optional[int] = None,
        pad_multiple: int = 8,
        compact_waste: float = 0.5,
        compact_min_rows: int = 8,
        plan_cache_limit: Optional[int] = 512,
        history_limit: Optional[int] = None,
        loop_pallas: Optional[bool] = None,
        device: Optional[Any] = None,
        pad_payloads: bool = False,
    ):
        if plan_mode not in PLAN_MODES:
            raise ValueError(
                f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
        super().__init__(window_size, history_limit=history_limit)
        self.registry = registry if registry is not None else DeviceOpRegistry(strict=False)
        self.plan_mode = plan_mode
        self.max_group = max_group
        # Optional jax.Device pin: slabs are committed there before each
        # dispatch, so jit execution (and every uncommitted payload array)
        # follows — this is what gives MeshDeviceSession's shards their
        # own dispatch streams. None keeps JAX's default placement.
        self.device = device
        # "loop" executor selection (see DeviceWindowRunner): None = Pallas
        # on TPU when eligible, True = force (interpret mode off-TPU),
        # False = lax.while_loop interpreter always.
        self.loop_pallas = loop_pallas
        # Opt-in payload shape-bucketing (interpreter path only): pads
        # epoch size, dep width and per-spec counts to pow2 buckets so a
        # serving stream whose per-epoch task counts wander does not
        # recompile every epoch. OFF by default because a bucketed program
        # is a DIFFERENT XLA program than the exact one — same math, but
        # compiler fusion may round differently at the last ulp, so exact
        # payloads are required wherever bit-identity with the serial
        # baseline is asserted. Benchmarks enable it on every session of
        # an A/B pair (single and mesh alike), so ratios stay fair.
        self.pad_payloads = pad_payloads
        self.arena = SlabArena(pad_multiple=pad_multiple,
                               compact_waste=compact_waste,
                               compact_min_rows=compact_min_rows)
        self._slabs: Optional[List[Any]] = None
        # id(Buffer) -> Buffer whose freshest value lives device-side
        # (slab newer than host) / host-side (host newer than slab).
        self._device_dirty: Dict[int, Buffer] = {}
        self._host_dirty: Dict[int, Buffer] = {}
        # id(Buffer) -> stream tag to attribute the pending h2d refresh to
        # (mesh staged edges tag their destination half "mesh-transfer").
        self._host_dirty_tags: Dict[int, str] = {}
        # structure key (plan signatures x arena addresses) -> lowered
        # (run_fn, tables, n_steps, class_gens): the session-scope plan
        # cache. Entries carry the arena generation of every class they
        # address; a compaction moves rows, so entries touching a compacted
        # class are invalidated (eagerly at compaction, and belt-and-braces
        # on hit via the recorded generations). Insertion order doubles as
        # LRU order (hits reinsert), bounded by plan_cache_limit.
        self._plan_cache: Dict[Tuple, Tuple] = {}
        self.plan_cache_limit = plan_cache_limit
        self.plan_cache_evictions = 0
        self.plan_cache_invalidations = 0
        # static step-spec structure -> compiled program (shared across
        # plan-cache entries that differ only in row addressing).
        self._programs: Dict[Tuple, Tuple[Callable, Any]] = {}
        self.stats = ExecStats()
        # In-epoch host-fallback path: a plain serial executor whose stats
        # object IS this session's, so its per-task dispatch/compile/jit
        # bookkeeping lands in the one report without duplication.
        self._host_exec = SerialExecutor()
        self._host_exec.stats = self.stats
        self.epochs = 0
        self.device_dispatches = 0
        self.loop_dispatches = 0  # ready-queue dispatches (subset of device)
        self.host_task_dispatches = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        # Host<->device transition accounting (DESIGN §2 A3: the O(1)
        # claim is only honest if EVERY transition is counted, in both
        # directions): `host_syncs` totals d2h slab read-backs plus h2d
        # row refreshes forced by host-fallback writes; the split and a
        # per-stream-tag attribution ride along for the benchmarks.
        self.host_syncs = 0
        self.host_syncs_d2h = 0
        self.host_syncs_h2d = 0
        self.host_syncs_by_tag: Dict[str, int] = {}
        # Mesh d2d edge accounting: rows peer-copied out of / into this
        # session's slabs without a host round-trip, and device-dirty
        # claims dropped because another shard took write ownership.
        self.d2d_row_exports = 0
        self.d2d_row_imports = 0
        self.row_invalidations = 0
        # Overlapped-drain surface (mesh): launch() dispatches epochs with
        # retirement DEFERRED — each device segment parks here with its
        # output slabs as completion probes until poll_inflight() retires
        # it (FIFO, preserving program-order retirement).
        self._inflight: deque = deque()
        self._defer_retire = False
        self.epoch_log: Any = ([] if history_limit is None
                               else deque(maxlen=history_limit))

    # -- epoch planning ----------------------------------------------------
    def _plan_epoch(self) -> List[List[Task]]:
        """Drain the live window symbolically into this epoch's plan:
        wave fronts or one homogeneous frontier group per step. The window
        retires (and refills from the FIFO) during planning — execution
        follows, then retirement callbacks fire. The replanning is cheap
        by construction: upstream sets were resolved incrementally by the
        scoreboard at submit time, and each retire-and-refill here costs
        O(own segments + out-degree), not a window rescan — so epoch
        planning at window 256 does not melt the admission path.

        QoS threading (DESIGN §13): ``ready_tasks()`` is priority-
        bucketed, so each planning step's frontier opens with the most
        urgent READY kernels — frontier-mode epochs pick their leading
        signature group from the urgent end, wave-mode fronts list
        urgent work first. ``plan_mode="loop"`` epochs are unaffected:
        they drain via ``drain_program_order()`` (seq-sorted, priority-
        oblivious), keeping the §2-A3 loop lowering program-order-
        correct — on-device, the ready ring still discovers whatever
        concurrency exists regardless of class."""
        plan: List[List[Task]] = []
        while not self.window.idle():
            ready = self.window.ready_tasks()
            if not ready:
                raise RuntimeError(
                    "device session stall: no READY kernels but window non-empty")
            if self.plan_mode == "frontier":
                group = group_by_signature(ready)[0]
                if self.max_group is not None:
                    group = group[: self.max_group]
            else:
                group = ready
            for t in group:
                self.window.mark_executing(t)
            self.window.retire_many(group)
            plan.append(group)
        return plan

    # -- sync bookkeeping --------------------------------------------------
    @staticmethod
    def _tags_of(tasks: Iterable[Task]) -> Tuple[str, ...]:
        return tuple({getattr(t, "stream_tag", None) or "untagged"
                      for t in tasks})

    def _count_sync(self, direction: str, tags: Iterable[str]) -> None:
        self.host_syncs += 1
        if direction == "d2h":
            self.host_syncs_d2h += 1
        else:
            self.host_syncs_h2d += 1
        for tag in tags or ("untagged",):
            self.host_syncs_by_tag[tag] = self.host_syncs_by_tag.get(tag, 0) + 1

    def _sync_to_host(self, buffers: Iterable[Buffer],
                      tags: Iterable[str] = ()) -> None:
        """Write the given buffers' slab rows back to host values (ONE
        blocking sync, counted; ``tags`` attributes it to the stream tags
        that forced it)."""
        bufs = [b for b in buffers if id(b) in self._device_dirty]
        if not bufs or self._slabs is None:
            return
        jax.block_until_ready(self._slabs)
        self.arena.unpack(self._slabs, only=bufs)
        for b in bufs:
            del self._device_dirty[id(b)]
        self._count_sync("d2h", tuple(tags))

    def sync(self) -> None:
        """Force every device-resident value back to host buffers."""
        with self._lock:
            self._sync_to_host(list(self._device_dirty.values()),
                               tags=("sync",))

    def sync_buffers(self, buffers: Iterable[Buffer],
                     tags: Iterable[str] = ("transfer",)) -> None:
        """Sync just the given buffers' device values back to host (one
        counted d2h when any is device-dirty). The mesh session stages a
        cross-shard edge as: owner ``sync_buffers`` -> destination
        ``mark_host_dirty`` -> destination's next dispatch re-uploads."""
        with self._lock:
            self._sync_to_host(list(buffers), tags=tuple(tags))

    def mark_host_dirty(self, buf: Buffer, tag: Optional[str] = None) -> None:
        """Tell this session the buffer's HOST value is now authoritative
        (another shard produced it, or the producer rewrote it between
        epochs): drop any stale device-dirty claim and schedule a row
        refresh at the next dispatch. No-op for buffers this session's
        arena has never packed — their next pack reads host values anyway.
        ``tag`` attributes the eventual h2d refresh to the stream that
        forced it (the mesh staged path passes ``"mesh-transfer"`` so both
        halves of a staged edge land in the per-tag sync audit)."""
        with self._lock:
            self._device_dirty.pop(id(buf), None)
            if buf in self.arena:
                self._host_dirty[id(buf)] = buf
                if tag is not None:
                    self._host_dirty_tags[id(buf)] = tag

    # -- d2d row transfer (mesh ShardLink halves) ---------------------------
    def export_row(self, buf: Buffer) -> Optional[Any]:
        """The device-resident slab row holding ``buf``'s authoritative
        padded value, for a peer shard to import without a host hop — or
        ``None`` when this session holds no device-authoritative copy
        (host value current, row never materialized, or pending a host
        refresh), in which case the caller must take the host-staged
        path. The export is a lazy slice: it does NOT block on in-flight
        dispatches — the receiving ``.at[row].set`` stays async too."""
        with self._lock:
            if self._slabs is None or id(buf) not in self._device_dirty:
                return None
            addr = self.arena.addr_of(buf)
            if addr is None:
                return None
            cid, _row = addr
            try:
                row = self.arena.export_row(
                    self._slabs, buf,
                    expected_generation=self.arena.class_generation(cid))
            except RuntimeError:
                return None
            self.d2d_row_exports += 1
            return row

    def import_row(self, buf: Buffer, value: Any) -> bool:
        """Receive a peer shard's exported slab row directly into this
        session's slab (d2d edge): the row becomes device-authoritative
        here — exactly the state a local dispatch write leaves — so every
        downstream sync/observer path behaves identically. Returns False
        (caller falls back to host staging) when this session has no
        pinned device to commit the peer value onto."""
        with self._lock:
            if self.device is None:
                return False
            self.arena.add(buf)
            cid, _row = self.arena.addr_of(buf)
            # Materialize any not-yet-packed rows first (admission upload,
            # not a counted sync): a first-touch import needs its row
            # inside the packed watermark. Then pin, so the functional
            # .at[].set commits onto this shard's device.
            self._slabs = self.arena.pack_incremental(self._slabs,
                                                      device=self.device)
            self._slabs = [jax.device_put(s, self.device)
                           for s in self._slabs]
            self._slabs = self.arena.import_row(
                self._slabs, buf, value,
                expected_generation=self.arena.class_generation(cid))
            self._host_dirty.pop(id(buf), None)
            self._host_dirty_tags.pop(id(buf), None)
            self._device_dirty[id(buf)] = buf
            self.d2d_row_imports += 1
            return True

    def invalidate_row(self, buf: Buffer) -> bool:
        """Drop any authoritative claim this session holds on ``buf`` —
        the write-owner invalidation half of the mesh protocol: when
        another shard takes write ownership, every superseded copy must
        stop asserting its (now stale) value, or a later sync here would
        clobber the fresh one. The slab row keeps its bits; a future read
        on this shard re-stages through the link first."""
        with self._lock:
            had = self._device_dirty.pop(id(buf), None) is not None
            self._host_dirty.pop(id(buf), None)
            self._host_dirty_tags.pop(id(buf), None)
            if had:
                self.row_invalidations += 1
            return had

    # -- row lifecycle -------------------------------------------------------
    def release_buffer(self, buf: Buffer) -> bool:
        """Release a buffer the producer is done with: its arena row joins
        the class free-list for recycling and its dirty-tracking entries
        drop. The caller guarantees no pending or future task references
        the buffer (serving wires this to ``BufferPool.free`` via a free
        hook, which fires after the owning request retired). The device
        value is NOT synced back — a released buffer owes no host value."""
        with self._lock:
            self._device_dirty.pop(id(buf), None)
            self._host_dirty.pop(id(buf), None)
            self._host_dirty_tags.pop(id(buf), None)
            return self.arena.free(buf)

    def _maybe_compact(self) -> None:
        """Compact classes whose dead-row waste crossed the arena threshold
        (called with the lock held, between dispatches). Cached plans hold
        static row addresses, so every plan-cache entry addressing a
        compacted class is dropped — exactly those, never the full cache:
        entries over untouched classes stay valid and keep hitting."""
        cids = self.arena.needs_compaction()
        if not cids:
            return
        self._slabs, moved = self.arena.compact(self._slabs, cids)
        stale = [k for k, entry in self._plan_cache.items()
                 if any(cid in moved for cid, _ in entry[3])]
        for k in stale:
            del self._plan_cache[k]
        self.plan_cache_invalidations += len(stale)

    # Observers registered AFTER an unwatched epoch retired their task hit
    # the base class's fire-immediately paths — sync first, so a late
    # callback/ticket holder reads host values as fresh as an early one's.
    def _pre_observe_retired(self, task: Task) -> None:
        self._sync_to_host(list(self._device_dirty.values()),
                           tags=self._tags_of([task]))

    # -- device / host halves ----------------------------------------------
    def _structure_key(self, dev_plan: Sequence[Sequence[Task]]) -> Tuple:
        def opkey(op):
            a = self.arena.address(op)
            return (a.class_id, a.row, a.row_start, a.row_count)

        return tuple(
            tuple(
                (t.signature,
                 tuple(opkey(o) for o in t.inputs),
                 tuple(opkey(o) for o in t.outputs))
                for t in step
            )
            for step in dev_plan
        )

    def _execute_device(self, dev_plan: List[List[Task]]) -> None:
        self._maybe_compact()
        tasks = [t for step in dev_plan for t in step]
        self.arena.add_tasks(tasks)
        key = (self.plan_mode, self._structure_key(dev_plan))
        cached = self._plan_cache.get(key)
        if cached is not None and any(
                self.arena.class_generation(cid) != gen
                for cid, gen in cached[3]):
            # A compaction moved this entry's rows after it was built (the
            # eager sweep should have caught it — this is the safety net).
            del self._plan_cache[key]
            self.plan_cache_invalidations += 1
            cached = None
        if cached is None:
            steps = lower_plan(dev_plan, self.registry, self.arena)
            # Program cache keys on step structure alone: jit retraces by
            # itself when slab shapes grow, so keying on the arena layout
            # would only manufacture duplicate jit wrappers.
            spec_key = tuple(st.spec for st in steps)
            prog = self._programs.get(spec_key)
            if prog is None:
                prog = _build_program(steps)
                self._programs[spec_key] = prog
                self.stats.compiles += 1
            run_fn, runs = prog
            tables = _run_tables(steps, runs)
            class_ids = sorted({
                spec.class_id for st in steps
                for spec in st.spec.inputs + st.spec.outputs})
            gens = tuple(
                (cid, self.arena.class_generation(cid)) for cid in class_ids)
            cached = (run_fn, tables, len(steps), gens)
            self._plan_cache[key] = cached
            self.plan_cache_misses += 1
            if self.plan_cache_limit is not None and \
                    len(self._plan_cache) > self.plan_cache_limit:
                self._plan_cache.pop(next(iter(self._plan_cache)))
                self.plan_cache_evictions += 1
        else:
            # LRU touch: reinsertion moves the entry to the young end.
            self._plan_cache[key] = self._plan_cache.pop(key)
            self.plan_cache_hits += 1
        run_fn, tables, n_steps, _ = cached

        # Persistent slabs: append rows for newly seen buffers, refresh
        # rows whose host values changed since they were packed.
        self._refresh_slabs(tasks)

        out = run_fn(tuple(self._slabs), tables)
        self._slabs = list(out)
        self.device_dispatches += 1
        self.stats.dispatches += 1
        self.stats.tasks_run += len(tasks)
        for step in dev_plan:
            self.stats.wave_widths.append(len(step))
        for t in tasks:
            for op in t.outputs:
                b = operand_base(op)
                self._device_dirty[id(b)] = b
                self._host_dirty.pop(id(b), None)

    def _refresh_slabs(self, tasks: List[Task]) -> None:
        """Bring the slabs up to date before a device dispatch: append rows
        for newly seen buffers (admission upload — not a sync round-trip)
        and refresh rows whose host values changed since packing. The
        refresh IS a host->device transition (the opaque-operand fallback
        wrote those buffers host-side), so it counts toward host_syncs."""
        self._slabs = self.arena.pack_incremental(self._slabs,
                                                  device=self.device)
        stale = [b for b in self._host_dirty.values() if b in self.arena]
        if stale:
            self._slabs = self.arena.update_rows(self._slabs, stale)
            tags = set(self._tags_of(tasks))
            for b in stale:
                del self._host_dirty[id(b)]
                forced = self._host_dirty_tags.pop(id(b), None)
                if forced is not None:
                    tags.add(forced)
            self._count_sync("h2d", tuple(tags))
        if self.device is not None:
            # Commit to the pinned device (no-op for rows already there);
            # dispatch then executes on it regardless of JAX's default.
            self._slabs = [jax.device_put(s, self.device)
                           for s in self._slabs]

    def _execute_host_step(self, tasks: List[Task]) -> None:
        """In-epoch host fallback (opaque operands): per-task jit dispatch,
        reading fresh values back from the slabs first when a device step
        produced them. Retirement fires per task, so chained callbacks
        (serving decode harvests) observe each intermediate value exactly
        as they would under the host sessions."""
        need: Dict[int, Buffer] = {}
        for t in tasks:
            for op in tuple(t.inputs) + tuple(t.outputs):
                base = operand_base(op)
                if id(base) in self._device_dirty:
                    need[id(base)] = base
        if need:
            self._sync_to_host(need.values(), tags=self._tags_of(tasks))
        for task in tasks:
            self._host_exec.execute_wave([task])
            self.host_task_dispatches += 1
            for op in task.outputs:
                b = operand_base(op)
                self._host_dirty[id(b)] = b
                self._device_dirty.pop(id(b), None)
            self.waves.append([task.tid])
            self._note_retired(task)

    def _drain_epoch_ordered(self) -> List[Task]:
        """Drain the live window into program order (the ready-queue
        lowering needs a topological order) — see
        :meth:`SchedulingWindow.drain_program_order`."""
        return self.window.drain_program_order()

    def _execute_device_loop(self, tasks: List[Task]) -> None:
        """Dispatch one program-order run of device-lowerable tasks as a
        single ready-queue program: the device pops tasks as their
        counters hit zero — the host never decides a wake-up. Rides the
        same structure-keyed plan cache as the fixed-table path (payload
        arrays are cached device-side, so a recurring stream re-uploads
        nothing) and the same spec-keyed program cache."""
        self._maybe_compact()
        self.arena.add_tasks(tasks)
        key = ("loop", self._structure_key([tasks]))
        cached = self._plan_cache.get(key)
        if cached is not None and any(
                self.arena.class_generation(cid) != gen
                for cid, gen in cached[3]):
            del self._plan_cache[key]
            self.plan_cache_invalidations += 1
            cached = None
        if cached is None:
            program = lower_epoch_program(tasks, self.registry, self.arena)
            parts = None
            if self.loop_pallas is None:
                if jax.default_backend() == "tpu":
                    parts = _loop_pallas_parts(program, self.registry,
                                               self.arena)
            elif self.loop_pallas:
                parts = _loop_pallas_parts(program, self.registry, self.arena)
            # Interpreter payloads are bucket-padded only when the session
            # opted in (shape quantization — see _padded_loop_payload);
            # the Pallas fori_loop pops exactly n tasks, so the fast path
            # always keeps the exact payload.
            if parts is None and self.pad_payloads:
                payload = _padded_loop_payload(program)
            else:
                payload = program.payload()
                if parts is not None:
                    payload["task_tbl"] = jnp.asarray(
                        _loop_task_table(program))
            spec_key = ("loop", program.specs,
                        payload["dep_tbl"].shape[1], parts is not None)
            prog = self._programs.get(spec_key)
            if prog is None:
                if parts is not None:
                    prog = _build_loop_pallas(
                        parts[0], parts[1],
                        interpret=jax.default_backend() != "tpu")
                else:
                    prog = _build_loop_interpreter(program.specs, program.fns)
                self._programs[spec_key] = prog
                self.stats.compiles += 1
            class_ids = sorted({
                sp.class_id for st in program.specs
                for sp in st.inputs + st.outputs})
            gens = tuple(
                (cid, self.arena.class_generation(cid)) for cid in class_ids)
            cached = (prog, payload, len(program.specs), gens)
            self._plan_cache[key] = cached
            self.plan_cache_misses += 1
            if self.plan_cache_limit is not None and \
                    len(self._plan_cache) > self.plan_cache_limit:
                self._plan_cache.pop(next(iter(self._plan_cache)))
                self.plan_cache_evictions += 1
        else:
            self._plan_cache[key] = self._plan_cache.pop(key)
            self.plan_cache_hits += 1
        run_fn, payload, _, _ = cached

        self._refresh_slabs(tasks)
        out, _done = run_fn(tuple(self._slabs), payload)
        self._slabs = list(out)
        self.device_dispatches += 1
        self.loop_dispatches += 1
        self.stats.dispatches += 1
        self.stats.tasks_run += len(tasks)
        self.stats.wave_widths.append(len(tasks))
        for t in tasks:
            for op in t.outputs:
                b = operand_base(op)
                self._device_dirty[id(b)] = b
                self._host_dirty.pop(id(b), None)

    def _run_epoch_loop(self) -> None:
        """The plan_mode="loop" epoch: split the program-order drain into
        maximal contiguous device-lowerable runs — each run is ONE
        ready-queue dispatch (order decided on device); opaque-operand
        runs interleave on the host path in between. Program order is
        topological, so run ordering preserves every cross-run edge."""
        order = self._drain_epoch_ordered()
        syncs_before = self.host_syncs
        hits_before = self.plan_cache_hits
        n_device_dispatches = 0
        n_host_tasks = 0
        for lowerable, grp in itertools.groupby(order, key=_device_lowerable):
            run = list(grp)
            if lowerable:
                self._execute_device_loop(run)
                n_device_dispatches += 1
                self._retire_device_segment([run])
            else:
                n_host_tasks += len(run)
                self._execute_host_step(run)
        self.epochs += 1
        self.epoch_log.append({
            "epoch": self.epochs,
            "tasks": len(order),
            "plan_steps": n_device_dispatches + n_host_tasks,
            "device_dispatches": n_device_dispatches,
            "host_tasks": n_host_tasks,
            "plan_cache_hits": self.plan_cache_hits - hits_before,
            "host_syncs": self.host_syncs - syncs_before,
        })

    # -- the epoch ----------------------------------------------------------
    def _pump(self) -> bool:
        # Segments a prior launch() left in flight retire first (blocking:
        # _pump must make progress) — flush/close after a launch drains
        # cleanly instead of stalling on a window that looks idle.
        progressed = False
        if self._inflight:
            progressed = self._drain_inflight(block=True) > 0
        if self.window.idle():
            return progressed
        if self.plan_mode == "loop":
            self._run_epoch_loop()
        else:
            self._run_epoch()
        return True

    # -- overlapped drain (mesh pump) ---------------------------------------
    def launch(self) -> bool:
        """Dispatch everything admitted so far WITHOUT retiring device
        segments: each device dispatch is enqueued async and parked on the
        in-flight queue; its retirement — observer sync, callbacks,
        outstanding accounting — happens at :meth:`poll_inflight`. This is
        the mesh session's overlapped-drain hook: launching every involved
        shard back-to-back puts independent shards' epochs in flight
        concurrently before anyone blocks. Host-fallback tasks still
        execute and retire inline (their operand syncs block anyway).
        Returns True when anything is in flight or was dispatched."""
        with self._lock:
            if self.window.idle():
                return bool(self._inflight)
            self._defer_retire = True
            try:
                if self.plan_mode == "loop":
                    self._run_epoch_loop()
                else:
                    self._run_epoch()
            finally:
                self._defer_retire = False
            return True

    @property
    def inflight_segments(self) -> int:
        with self._lock:
            return len(self._inflight)

    def poll_inflight(self, block: bool = False) -> int:
        """Retire in-flight device segments whose dispatches have landed,
        oldest-first (program-order retirement). Non-blocking by default:
        stops at the first segment whose output slabs are not ready.
        ``block=True`` forces the oldest segment to completion first.
        Returns the number of tasks retired."""
        with self._lock:
            return self._drain_inflight(block=block)

    def _drain_inflight(self, block: bool) -> int:
        retired = 0
        while self._inflight:
            dev_plan, probes = self._inflight[0]
            if not block and not all(_array_ready(p) for p in probes):
                break
            if block:
                jax.block_until_ready(list(probes))
            self._inflight.popleft()
            self._retire_device_segment(dev_plan)
            retired += sum(len(step) for step in dev_plan)
            block = False  # only force the oldest; the rest must be ready
        return retired

    def _retire_device_segment(self, dev_plan: List[List[Task]]) -> None:
        """Retire a just-dispatched device segment. Retirement observers —
        listeners, per-task callbacks, ticket holders — read host values,
        so a watched segment syncs the slabs back first (one blocking sync
        — the retire boundary); observation granularity is the segment,
        since intermediate slab states inside its single dispatch are
        never materialized. Under a deferred launch the segment parks on
        the in-flight queue instead, with the dispatch's output slabs as
        completion probes; poll_inflight re-enters here to finish the
        job."""
        if self._defer_retire:
            self._inflight.append((dev_plan, tuple(self._slabs or ())))
            return
        watched = bool(self._listeners) or any(
            t.tid in self._watchers or t.tid in self._tickets
            for step in dev_plan for t in step)
        if watched:
            self._sync_to_host(
                list(self._device_dirty.values()),
                tags=self._tags_of(t for step in dev_plan for t in step))
        for step in dev_plan:
            self.waves.append([t.tid for t in step])
            for t in step:
                self._note_retired(t)

    def _run_epoch(self) -> None:
        plan = self._plan_epoch()
        syncs_before = self.host_syncs
        hits_before = self.plan_cache_hits
        n_device_dispatches = 0
        n_host_tasks = 0
        # Walk the plan in order, batching maximal runs of device-lowerable
        # steps into single dispatches; tasks within one plan step are
        # independent, so splitting a step between the device and host
        # halves preserves every cross-step dependency (plan order).
        pending: List[List[Task]] = []
        for step in plan:
            dev = [t for t in step if _device_lowerable(t)]
            host = [t for t in step if not _device_lowerable(t)]
            if dev:
                pending.append(dev)
            if host:
                if pending:
                    self._execute_device(pending)
                    n_device_dispatches += 1
                    self._retire_device_segment(pending)
                    pending = []
                n_host_tasks += len(host)
                self._execute_host_step(host)
        if pending:
            self._execute_device(pending)
            n_device_dispatches += 1
            self._retire_device_segment(pending)

        self.epochs += 1
        self.epoch_log.append({
            "epoch": self.epochs,
            "tasks": sum(len(step) for step in plan),
            "plan_steps": len(plan),
            "device_dispatches": n_device_dispatches,
            "host_tasks": n_host_tasks,
            "plan_cache_hits": self.plan_cache_hits - hits_before,
            "host_syncs": self.host_syncs - syncs_before,
        })

    # -- lifecycle ----------------------------------------------------------
    def flush(self) -> None:
        """Drain everything submitted so far, then sync device-resident
        values back to host buffers (the observable retire boundary)."""
        super().flush()
        self.sync()

    def session_stats(self) -> Dict[str, Any]:
        """Aggregate session counters (the per-epoch detail is in
        ``epoch_log``)."""
        with self._lock:
            return {
                "plan_mode": self.plan_mode,
                "epochs": self.epochs,
                "device_dispatches": self.device_dispatches,
                "loop_dispatches": self.loop_dispatches,
                "host_task_dispatches": self.host_task_dispatches,
                "plan_cache_hits": self.plan_cache_hits,
                "plan_cache_misses": self.plan_cache_misses,
                "plan_cache_entries": len(self._plan_cache),
                "plan_cache_evictions": self.plan_cache_evictions,
                "plan_cache_invalidations": self.plan_cache_invalidations,
                "compiled_programs": len(self._programs),
                "host_syncs": self.host_syncs,
                "host_syncs_d2h": self.host_syncs_d2h,
                "host_syncs_h2d": self.host_syncs_h2d,
                "host_syncs_by_tag": dict(self.host_syncs_by_tag),
                "d2d_row_exports": self.d2d_row_exports,
                "d2d_row_imports": self.d2d_row_imports,
                "row_invalidations": self.row_invalidations,
                "n_classes": self.arena.n_classes(),
                "padding_waste_frac": round(self.arena.total_waste_frac(), 4),
                # row lifecycle (DESIGN §2 A3 gap (2))
                "slab_bytes": self.arena.slab_bytes(),
                "arena_generation": self.arena.generation,
                "arena_live_rows": self.arena.live_rows(),
                "arena_free_rows": self.arena.free_rows(),
                "arena_recycled_rows": self.arena.recycled_rows,
                "arena_compactions": self.arena.compactions,
                # dependency-engine accounting (probe vs pairwise-equiv)
                "dep_checks": self.window.stats.dep_checks,
                "scoreboard_probes": self.window.stats.scoreboard_probes,
            }

    def _finalize(self) -> SchedulerReport:
        wall = time.perf_counter() - self._t0
        self.stats.exec_seconds = wall
        report = SchedulerReport(self.window, self.stats, wall, self.waves)
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.session_stats = self.session_stats()  # type: ignore[attr-defined]
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": self.arena.n_classes(),
            "total_waste_frac": round(self.arena.total_waste_frac(), 4),
            "per_class": self.arena.padding_waste(),
            "device_steps": sum(e["plan_steps"] for e in self.epoch_log),
        }
        return report
