"""ACS-HW analogue: the scheduling window lives on the device (DESIGN §2 A3).

The paper's ACS-HW moves the window into GPU hardware so that kernel
completion -> upstream update -> ready dispatch never round-trips to the
CPU. A TPU has no command processor we can extend, so the TPU-idiomatic
equivalent is a *device-resident window interpreter*:

1. The host runs the (cheap, windowed) dependency analysis ONCE per stream
   and emits a plan (wave-synchronous or frontier-grouped — `plan_waves` /
   `plan_frontier`), then lowers it over a **shape-class slab arena**
   (`core/arena.py`): every step is one homogeneous task group with a
   static ``(opcode, arity, input/output shape classes)`` spec plus dense
   int32 row tables — the moral equivalent of the upstream-id SRAM tables
   of Fig 20, generalized from one uniform ``(D,)`` shape to the real
   sim/dyn workloads (mixed shapes and dtypes, variable arity, row-view
   aliasing, multi-output tasks).
2. A single compiled program walks the steps (runs of identical step specs
   are compressed into ``lax.scan``s), gathering operand rows from the
   per-class slabs (cross-class gathers — inputs and outputs of one step
   may live in different slabs), applying the step's kernel (vmapped over
   the group), and scattering results back.

Host involvement: ONE dispatch for the whole stream — vs one per kernel
(serial) or one per wave (ACS-SW). This is exactly the communication
reduction ACS-HW claims, realized with jax control flow instead of SRAM
next to a command processor.

:class:`DeviceWindowRunner` is the *closed-batch* form: each ``run`` plans,
lowers, packs a fresh arena, and dispatches once. :class:`DeviceSession`
is the *persistent* form (DESIGN §2 A3): a live
:class:`~.session.SchedulerSession` whose window accepts ``submit``-ed
tasks at any time and drains them in **epochs** — each epoch lowers only
the newly admitted window slice against a session-lifetime
:class:`~.arena.SlabArena` (slabs stay device-resident across epochs;
host values re-sync only at retire boundaries) with a structure-keyed plan
cache at session scope, so recurring stream shapes skip re-lowering
entirely. That is the rolling-window half of ACS-HW the per-stream runner
cannot express: the dependency state and the operands live beside the
device for the whole program, and a new submission costs one epoch
dispatch, not a re-plan/repack of the world.

The seed's uniform-shape interpreter survives as the *legacy path*
(`compile_wave_plan` + `DeviceWindowRunner.execute_uniform`): operands
must share one padded shape ``(D,)``, opcodes must be arity-<=3 registry
branches. It now refuses over-arity tasks loudly instead of silently
truncating operand lists.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .arena import SlabArena
from .buffers import Buffer, BufferView
from .executors import ExecStats, SerialExecutor, group_by_signature
from .scheduler import PLAN_MODES, SchedulerReport
from .session import RetireCallback, SchedulerSession, TaskTicket
from .task import Task, operand_base, operand_shape
from .window import SchedulingWindow

__all__ = [
    "DeviceOpRegistry",
    "compile_wave_plan",
    "plan_waves",
    "plan_frontier",
    "plan_active_fraction",
    "lower_plan",
    "DeviceStep",
    "DeviceWindowRunner",
    "DeviceSession",
]

MAX_ARITY = 3  # legacy uniform-slab path only; the arena path has no limit


class DeviceOpRegistry:
    """The device interpreter's fixed opcode table (the paper's HW window
    supports a finite kernel set burned in next to the command processor).

    ``register`` assigns each kernel name a stable opcode. ``strict``
    registries refuse to lower tasks whose opcode was never registered —
    the faithful HW behaviour; non-strict registries auto-register on
    first sight (the software-managed table `make_scheduler("device")`
    uses, so any workload runs out of the box). During lowering the
    registry also records which shape classes each opcode was dispatched
    over (``classes_seen``) — the per-class registration benchmarks print.
    """

    def __init__(self, strict: bool = True) -> None:
        self._ops: List[Tuple[str, Optional[Callable]]] = []
        self._index: Dict[str, int] = {}
        self.strict = strict
        # opcode name -> set of (input class labels, output class labels)
        self.classes_seen: Dict[str, set] = {}

    def register(self, name: str, fn: Optional[Callable] = None) -> int:
        """Register ``name`` (idempotent). ``fn`` is the legacy uniform-path
        branch ``fn(x, y, z) -> out``; the arena path executes each task
        group's own wrapper-resolved callable and ignores it.

        Re-registering a known name upgrades an fn-less entry with the
        supplied branch fn; supplying a *different* fn for a name that
        already has one is a conflict and raises."""
        idx = self._index.get(name)
        if idx is not None:
            stored = self._ops[idx][1]
            if fn is not None:
                if stored is None:
                    self._ops[idx] = (name, fn)
                elif stored is not fn:
                    raise ValueError(
                        f"opcode {name!r} already registered with a different "
                        "branch fn; device opcodes are fixed per registry"
                    )
            return idx
        idx = len(self._ops)
        self._ops.append((name, fn))
        self._index[name] = idx
        return idx

    def opcode(self, name: str) -> int:
        idx = self._index.get(name)
        if idx is None:
            if not self.strict:
                return self.register(name)
            raise KeyError(
                f"opcode {name!r} is not in the device registry "
                f"(registered: {sorted(self._index) or 'none'}); register it "
                "or build the runner with an auto-registering registry"
            )
        return idx

    def note_classes(self, name: str, in_labels: Tuple[str, ...],
                     out_labels: Tuple[str, ...]) -> None:
        self.classes_seen.setdefault(name, set()).add((in_labels, out_labels))

    @property
    def branches(self) -> List[Callable]:
        """Legacy uniform-path branch table (registration order). Opcode
        ints index this list inside ``lax.switch``, so every registered
        name must carry a branch fn to use the uniform interpreter."""
        missing = [n for n, fn in self._ops if fn is None]
        if missing:
            raise ValueError(
                "legacy uniform path needs an fn(x, y, z) branch for every "
                f"registered opcode; missing: {missing} (real kernels are "
                "registered fn-less — run them through the arena path)"
            )
        return [fn for _, fn in self._ops]

    def __contains__(self, name: str) -> bool:
        return name in self._index

    def __len__(self) -> int:
        return len(self._ops)


# ---------------------------------------------------------------------------
# Planning: run the windowed scheduler symbolically (no execution)
# ---------------------------------------------------------------------------

def plan_waves(tasks: Sequence[Task], window_size: int = 32,
               return_window: bool = False):
    """Run the windowed scheduler symbolically to obtain the wave plan.

    Planning cost rides the window's interval scoreboard: each insertion
    probes only its own segments' intervals, so planning at window
    128-512 costs barely more per task than at 32 (the seed's pairwise
    scan made large planning windows quadratic-feeling — see
    ``benchmarks/bench_window_size.py``).

    With ``return_window=True`` also returns the planning
    :class:`SchedulingWindow`, whose stats (dep checks, scoreboard
    probes, occupancy) are the real numbers behind the plan — the runner
    reports them instead of a fresh all-zero window.
    """
    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    waves: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning waves")
        for t in ready:
            window.mark_executing(t)
        waves.append(ready)
        window.retire_many(ready)
    return (waves, window) if return_window else waves


def plan_frontier(
    tasks: Sequence[Task], window_size: int = 32, max_group: Optional[int] = None,
    return_window: bool = False,
):
    """Frontier-plan mode: one homogeneous group per device step.

    Wave planning retires an entire front per step, so every step is
    padded to the *widest wave* and a slow-to-unblock kernel stretches the
    whole table. The frontier plan instead retires one homogeneous group at
    a time, re-collecting the READY set between groups — newly unblocked
    kernels join the very next step rather than waiting out the front.
    Steps are narrower but denser (higher active-slot fraction).
    """
    from .executors import group_by_signature

    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    groups: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning frontier groups")
        group = group_by_signature(ready)[0]
        if max_group is not None:
            group = group[:max_group]
        for t in group:
            window.mark_executing(t)
        window.retire_many(group)
        groups.append(group)
    return (groups, window) if return_window else groups


def plan_active_fraction(plan: Sequence[Sequence[Task]]) -> float:
    """Fraction of (step, slot) table cells holding a real kernel — the
    padding-waste metric the frontier plan improves."""
    if not plan:
        return 1.0
    max_w = max(len(step) for step in plan)
    return sum(len(step) for step in plan) / (len(plan) * max_w)


# ---------------------------------------------------------------------------
# Legacy lowering: one uniform (D,) shape class, arity <= 3
# ---------------------------------------------------------------------------

def compile_wave_plan(
    waves: Sequence[Sequence[Task]],
    registry: DeviceOpRegistry,
    buffer_index: Dict[str, int],
    n_rows: int,
) -> Dict[str, np.ndarray]:
    """Lower a wave schedule to dense dispatch tables (the 'SRAM' image).

    Legacy single-class path: every operand indexes one uniform slab and
    arity is capped at ``MAX_ARITY``. Over-arity tasks are an error here —
    the arena path (`lower_plan`) is the one without the limit.
    """
    n_waves = len(waves)
    max_w = max((len(w) for w in waves), default=1)
    dummy = n_rows  # slab has one extra scratch row
    opc = np.zeros((n_waves, max_w), dtype=np.int32)
    ins = np.full((n_waves, max_w, MAX_ARITY), dummy, dtype=np.int32)
    outs = np.full((n_waves, max_w), dummy, dtype=np.int32)
    active = np.zeros((n_waves, max_w), dtype=bool)
    for wi, wave in enumerate(waves):
        for si, task in enumerate(wave):
            if len(task.inputs) > MAX_ARITY:
                raise ValueError(
                    f"task {task.opcode}#{task.tid} has {len(task.inputs)} "
                    f"operands but the legacy uniform-slab path supports at "
                    f"most {MAX_ARITY}; use the arena path "
                    "(DeviceWindowRunner.execute) for variable arity"
                )
            if len(task.outputs) != 1:
                raise ValueError(
                    f"task {task.opcode}#{task.tid} has {len(task.outputs)} "
                    "outputs but the legacy uniform-slab path supports "
                    "exactly one; use the arena path "
                    "(DeviceWindowRunner.execute) for multi-output tasks"
                )
            opc[wi, si] = registry.opcode(task.opcode)
            for ai, op in enumerate(task.inputs):
                ins[wi, si, ai] = buffer_index[op.buffer.name if hasattr(op, "buffer") else op.name]
            outs[wi, si] = buffer_index[
                task.outputs[0].buffer.name if hasattr(task.outputs[0], "buffer") else task.outputs[0].name
            ]
            active[wi, si] = True
    return {"opcode": opc, "ins": ins, "outs": outs, "active": active}


# ---------------------------------------------------------------------------
# Arena lowering: per-class tables, variable arity, multi-output, views
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _OperandSpec:
    """Static half of one operand column (shared by the whole group)."""

    class_id: int
    true_shape: Tuple[int, ...]
    is_view: bool
    view_rows: int  # leading-axis rows covered when is_view


@dataclasses.dataclass(frozen=True)
class _StepSpec:
    """Static half of one device step: what gets compiled."""

    opcode: int
    width: int
    inputs: Tuple[_OperandSpec, ...]
    outputs: Tuple[_OperandSpec, ...]
    signature: Tuple  # group Task.signature — compile-cache identity


@dataclasses.dataclass
class DeviceStep:
    """One lowered step: one homogeneous task group, dense row tables.

    ``in_rows``/``out_rows`` are ``[n_operands, width]`` int32 slab row
    ids; ``*_starts`` carry the leading-axis offset for view operands
    (zero otherwise). The spec (opcode, width, shape classes) is static —
    identical specs across streams reuse one compiled program.
    """

    spec: _StepSpec
    fn: Callable
    in_rows: np.ndarray
    in_starts: np.ndarray
    out_rows: np.ndarray
    out_starts: np.ndarray
    tids: Tuple[int, ...]

    def tables(self) -> Dict[str, np.ndarray]:
        return {
            "in_rows": self.in_rows, "in_starts": self.in_starts,
            "out_rows": self.out_rows, "out_starts": self.out_starts,
        }


def _operand_spec(arena: SlabArena, op) -> Tuple[_OperandSpec, int, int]:
    """Returns (static spec, row, start) for one operand occurrence."""
    addr = arena.address(op)
    return (
        _OperandSpec(
            class_id=addr.class_id,
            true_shape=tuple(operand_shape(op)),
            is_view=addr.is_view,
            view_rows=addr.row_count if addr.is_view else 0,
        ),
        addr.row,
        addr.row_start,
    )


def _lowering_groups(wave: Sequence[Task], arena: SlabArena) -> List[List[Task]]:
    """Partition one plan step into arena-homogeneous groups, oldest-first.

    ``Task.signature`` alone is NOT enough here: it encodes operand value
    shapes, so a full ``(2, 4)`` buffer and a 2-row view of an ``(8, 4)``
    buffer are signature-equal (host executors batch them fine — they are
    value-based) yet need different gather/scatter code. The grouping key
    therefore also carries each operand's static arena addressing
    (class id, view-ness, view extent)."""

    def opkey(op):
        addr = arena.address(op)
        return (addr.class_id, addr.is_view, addr.row_count)

    groups: Dict[Tuple, List[Task]] = {}
    order: List[Tuple] = []
    for t in wave:
        key = (
            t.signature,
            tuple(opkey(o) for o in t.inputs),
            tuple(opkey(o) for o in t.outputs),
        )
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(t)
    return [groups[k] for k in order]


def lower_plan(
    plan: Sequence[Sequence[Task]],
    registry: DeviceOpRegistry,
    arena: SlabArena,
) -> List[DeviceStep]:
    """Lower a wave/frontier plan to arena-addressed device steps.

    Shared by both plan modes: each plan step (a wave, or an already
    homogeneous frontier group) is partitioned into arena-homogeneous
    groups (`_lowering_groups` — signature plus static arena addressing;
    tasks within a plan step are independent by construction, so sub-step
    order is free) and each group becomes one :class:`DeviceStep` with
    static (opcode, arity, shape classes) and dense per-operand row
    tables.
    """
    steps: List[DeviceStep] = []
    for wave in plan:
        for group in _lowering_groups(wave, arena):
            head = group[0]
            opcode = registry.opcode(head.opcode)
            n_in, n_out = len(head.inputs), len(head.outputs)
            width = len(group)
            in_specs: List[_OperandSpec] = []
            out_specs: List[_OperandSpec] = []
            in_rows = np.zeros((n_in, width), np.int32)
            in_starts = np.zeros((n_in, width), np.int32)
            out_rows = np.zeros((n_out, width), np.int32)
            out_starts = np.zeros((n_out, width), np.int32)
            for gi, task in enumerate(group):
                for i, op in enumerate(task.inputs):
                    spec, row, start = _operand_spec(arena, op)
                    in_rows[i, gi], in_starts[i, gi] = row, start
                    if gi == 0:
                        in_specs.append(spec)
                for o, op in enumerate(task.outputs):
                    spec, row, start = _operand_spec(arena, op)
                    out_rows[o, gi], out_starts[o, gi] = row, start
                    if gi == 0:
                        out_specs.append(spec)
            labels = tuple(arena.classes[s.class_id].label for s in in_specs)
            out_labels = tuple(arena.classes[s.class_id].label for s in out_specs)
            registry.note_classes(head.opcode, labels, out_labels)
            steps.append(
                DeviceStep(
                    spec=_StepSpec(opcode, width, tuple(in_specs),
                                   tuple(out_specs), head.signature),
                    fn=head.fn,
                    in_rows=in_rows, in_starts=in_starts,
                    out_rows=out_rows, out_starts=out_starts,
                    tids=tuple(t.tid for t in group),
                )
            )
    return steps


def _gather_operand(slabs, spec: _OperandSpec, rows, starts, width: int):
    """Gather one operand column: ``[width, *true_shape]`` (or unbatched
    when width == 1)."""
    slab = slabs[spec.class_id]
    if spec.is_view:
        rest = tuple(slab.shape[2:])  # padded row shape beyond the view axis
        zeros = (0,) * len(rest)

        def one(row, start):
            return jax.lax.dynamic_slice(
                slab[row], (start,) + zeros, (spec.view_rows,) + rest
            )

        vals = jax.vmap(one)(rows, starts) if width > 1 else one(rows[0], starts[0])
    else:
        vals = slab[rows] if width > 1 else slab[rows[0]]
    trim = tuple(slice(0, s) for s in spec.true_shape)
    if width > 1:
        trim = (slice(None),) + trim
    return vals[trim]


def _pad_value(val, target_shape: Tuple[int, ...]):
    if tuple(val.shape) == tuple(target_shape):
        return val
    pads = [(0, p - s) for s, p in zip(val.shape, target_shape)]
    return jnp.pad(val, pads)


def _scatter_operand(slabs, spec: _OperandSpec, rows, starts, width: int, val):
    """Scatter one output column back into its class slab."""
    slab = slabs[spec.class_id]
    padded_row = tuple(slab.shape[1:])
    if spec.is_view:
        # A view write updates a sub-interval of its parent's row. Within a
        # step two view writes may target the SAME parent row (disjoint
        # intervals — overlap would be a WAW hazard and land in different
        # steps), so the update must be sequential, not a vectorized
        # scatter that would drop all but one update to a duplicated row.
        target = (spec.view_rows,) + padded_row[1:]
        zeros = (0,) * (len(padded_row) - 1)
        for g in range(width):
            v = _pad_value(val[g] if width > 1 else val, target)
            row = rows[g]
            updated = jax.lax.dynamic_update_slice(
                slab[row], v.astype(slab.dtype), (starts[g],) + zeros
            )
            slab = slab.at[row].set(updated)
    else:
        if width > 1:
            v = jax.vmap(lambda x: _pad_value(x, padded_row))(val)
            slab = slab.at[rows].set(v.astype(slab.dtype))
        else:
            slab = slab.at[rows[0]].set(_pad_value(val, padded_row).astype(slab.dtype))
    out = list(slabs)
    out[spec.class_id] = slab
    return out


def _apply_step(slabs, spec: _StepSpec, fn: Callable, tables):
    ins = [
        _gather_operand(slabs, s, tables["in_rows"][i], tables["in_starts"][i],
                        spec.width)
        for i, s in enumerate(spec.inputs)
    ]
    out = jax.vmap(fn)(*ins) if spec.width > 1 else fn(*ins)
    outs = tuple(out) if isinstance(out, (tuple, list)) else (out,)
    if len(outs) != len(spec.outputs):
        raise ValueError(
            f"device step opcode {spec.opcode}: kernel returned {len(outs)} "
            f"values for {len(spec.outputs)} outputs"
        )
    for o, s in enumerate(spec.outputs):
        slabs = _scatter_operand(slabs, s, tables["out_rows"][o],
                                 tables["out_starts"][o], spec.width, outs[o])
    return slabs


def _build_program(
    steps: Sequence[DeviceStep],
) -> Tuple[Callable, List[Tuple[_StepSpec, Callable, int]]]:
    """Returns (jitted program, run segmentation). The program executes
    every lowered step; the segmentation tells `_run_tables` how to stack
    the per-step tables the program expects.

    Runs of consecutive steps with an identical static spec (the recurring
    structure of sim streams) collapse into a single ``lax.scan`` over
    their stacked row tables, bounding trace size by the number of
    *distinct* step specs in a run-length sense rather than total steps.
    """
    runs: List[Tuple[_StepSpec, Callable, int]] = []  # (spec, fn, run length)
    for st in steps:
        if runs and runs[-1][0] == st.spec:
            spec, fn, n = runs[-1]
            runs[-1] = (spec, fn, n + 1)
        else:
            runs.append((st.spec, st.fn, 1))

    def run_program(slabs, run_tables):
        slabs = list(slabs)
        for (spec, fn, length), tables in zip(runs, run_tables):
            if length == 1:
                slabs = _apply_step(slabs, spec, fn, tables)
            else:
                def body(carry, tbl, _spec=spec, _fn=fn):
                    return tuple(_apply_step(list(carry), _spec, _fn, tbl)), None

                carry, _ = jax.lax.scan(body, tuple(slabs), tables)
                slabs = list(carry)
        return tuple(slabs)

    return jax.jit(run_program), runs


def _run_tables(steps: Sequence[DeviceStep],
                runs: Sequence[Tuple[_StepSpec, Callable, int]]) -> List[Dict]:
    """Stack each run's per-step tables: [T, n_operands, width] for scans,
    plain [n_operands, width] for singleton runs."""
    tables: List[Dict] = []
    idx = 0
    for _, _, length in runs:
        chunk = steps[idx: idx + length]
        idx += length
        if length == 1:
            tables.append({k: jnp.asarray(v) for k, v in chunk[0].tables().items()})
        else:
            tables.append({
                k: jnp.asarray(np.stack([s.tables()[k] for s in chunk]))
                for k in chunk[0].tables()
            })
    return tables


class DeviceWindowRunner:
    """Compile once, then execute entire task streams in ONE dispatch.

    The arena path (``execute`` / ``run``) handles the real workloads:
    mixed shape classes, variable arity, multi-output tasks, row-view
    aliasing. It conforms to the ``make_scheduler`` contract — ``run``
    takes a task iterable and returns a :class:`SchedulerReport` whose
    window stats come from the planning pass (the dependency checks that
    actually happened), ``exec_stats.dispatches == 1`` per stream, and
    arena occupancy lands in ``report.arena_stats``.
    """

    def __init__(
        self,
        registry: Optional[DeviceOpRegistry] = None,
        window_size: int = 32,
        plan_mode: str = "wave",
        max_group: Optional[int] = None,
        pad_multiple: int = 8,
    ):
        if plan_mode not in PLAN_MODES:
            raise ValueError(f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
        self.registry = registry if registry is not None else DeviceOpRegistry(strict=False)
        self.window_size = window_size
        self.plan_mode = plan_mode
        self.max_group = max_group
        self.pad_multiple = pad_multiple
        self._compiled: Dict[Tuple, Tuple[Callable, Any]] = {}
        self._compiled_uniform: Dict[Tuple, Callable] = {}
        self.stats: Dict[str, Any] = {}

    def session(self) -> "DeviceSession":
        """Open a persistent :class:`DeviceSession` sharing this runner's
        opcode registry (each session owns its own arena — buffer rows bind
        to one session's slabs for its lifetime)."""
        return DeviceSession(window_size=self.window_size,
                             registry=self.registry,
                             plan_mode=self.plan_mode,
                             max_group=self.max_group,
                             pad_multiple=self.pad_multiple)

    # -- shared planning ---------------------------------------------------
    def _plan(self, tasks: Sequence[Task]):
        if self.plan_mode == "frontier":
            return plan_frontier(tasks, self.window_size, self.max_group,
                                 return_window=True)
        return plan_waves(tasks, self.window_size, return_window=True)

    # -- arena path (the real workloads) -----------------------------------
    def run(self, stream: Iterable[Task]) -> SchedulerReport:
        """`make_scheduler` contract: task iterable in, report out."""
        return self.execute(list(stream))

    def execute(
        self,
        tasks: Sequence[Task],
        buffers: Optional[Sequence] = None,
    ) -> SchedulerReport:
        from .executors import ExecStats

        tasks = list(tasks)
        t0 = time.perf_counter()
        plan, window = self._plan(tasks)

        arena = SlabArena(pad_multiple=self.pad_multiple)
        if buffers is not None:
            for b in buffers:
                arena.add(b)
        arena.add_tasks(tasks)
        steps = lower_plan(plan, self.registry, arena)
        plan_time = time.perf_counter() - t0

        stats = ExecStats()
        key = (
            tuple(st.spec for st in steps),
            tuple((c.padded_shape, c.dtype, len(arena.rows(i)))
                  for i, c in enumerate(arena.classes)),
        )
        cached = self._compiled.get(key)
        if cached is None:
            cached = _build_program(steps)
            self._compiled[key] = cached
            stats.compiles += 1
        run_fn, runs = cached

        slabs = arena.pack()
        tables = _run_tables(steps, runs)
        t1 = time.perf_counter()
        out_slabs = run_fn(tuple(slabs), tables)
        jax.block_until_ready(out_slabs)
        exec_time = time.perf_counter() - t1
        written = [operand_base(op) for t in tasks for op in t.outputs]
        arena.unpack(out_slabs, only=None if buffers is not None else written)

        stats.dispatches = 1  # the whole stream was one launch
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(w) for w in plan]
        stats.exec_seconds = exec_time
        report = SchedulerReport(
            window, stats, plan_time + exec_time,
            [[t.tid for t in w] for w in plan],
        )
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.plan_active_fraction = plan_active_fraction(plan)  # type: ignore[attr-defined]
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": arena.n_classes(),
            "total_waste_frac": round(arena.total_waste_frac(), 4),
            "per_class": arena.padding_waste(),
            "device_steps": len(steps),
        }
        return report

    # -- legacy uniform path (seed behaviour, kept for the toy universe) ---
    def _uniform_interpreter(self):
        branches = self.registry.branches

        def step(slab, wave):
            # slab: [rows+1, D]; wave tables: opcode [S], ins [S,3], outs [S], active [S]
            def slot(opcode, in_ids, out_id, act):
                x = slab[in_ids[0]]
                y = slab[in_ids[1]]
                z = slab[in_ids[2]]
                res = jax.lax.switch(opcode, branches, x, y, z)
                return jnp.where(act, res, slab[out_id]), out_id

            results, out_ids = jax.vmap(slot)(
                wave["opcode"], wave["ins"], wave["outs"], wave["active"]
            )
            slab = slab.at[out_ids].set(results)
            return slab, None

        def run(slab, plan):
            slab, _ = jax.lax.scan(step, slab, plan)
            return slab

        return run

    def execute_uniform(
        self,
        tasks: Sequence[Task],
        buffers: Sequence,  # core.buffers.Buffer, uniform padded shape (D,)
    ) -> SchedulerReport:
        """The seed's single-shape-class interpreter (lax.switch over
        registry branches, arity <= 3, single output). Kept as the legacy
        reference; `execute` is the general path."""
        from .executors import ExecStats

        t0 = time.perf_counter()
        plan, window = self._plan(tasks)
        plan_time = time.perf_counter() - t0

        buffer_index = {b.name: i for i, b in enumerate(buffers)}
        n_rows = len(buffers)
        tables = compile_wave_plan(plan, self.registry, buffer_index, n_rows)

        d = int(buffers[0].shape[-1])
        key = (tables["opcode"].shape, d, len(self.registry))
        run = self._compiled_uniform.get(key)
        if run is None:
            run = jax.jit(self._uniform_interpreter())
            self._compiled_uniform[key] = run
        slab = jnp.stack([jnp.asarray(b.value) for b in buffers]
                         + [jnp.zeros((d,), dtype=buffers[0].value.dtype)])
        dev_plan = {k: jnp.asarray(v) for k, v in tables.items()}
        t1 = time.perf_counter()
        slab = run(slab, dev_plan)
        slab.block_until_ready()
        exec_time = time.perf_counter() - t1
        for i, b in enumerate(buffers):
            b.value = slab[i]

        stats = ExecStats()
        stats.dispatches = 1
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(w) for w in plan]
        stats.exec_seconds = exec_time
        report = SchedulerReport(window, stats, plan_time + exec_time,
                                 [[t.tid for t in w] for w in plan])
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.plan_active_fraction = plan_active_fraction(plan)  # type: ignore[attr-defined]
        return report


# ---------------------------------------------------------------------------
# Persistent device window: the live-session form of the ACS-HW analogue
# ---------------------------------------------------------------------------

def _device_lowerable(task: Task) -> bool:
    """True iff every operand can live in the slab arena: array-valued (or
    not-yet-produced) buffers whose values match their declared shapes.
    Opaque pytree values (e.g. serving KV-cache tuples) and raw byte views
    fall back to the host path inside the epoch."""
    for op in tuple(task.inputs) + tuple(task.outputs):
        if isinstance(op, BufferView) and op.row_start is None:
            return False
        base = operand_base(op)
        val = base.value
        if val is None:
            continue
        shape = getattr(val, "shape", None)
        if shape is None or getattr(val, "dtype", None) is None:
            return False
        if tuple(shape) != tuple(base.shape):
            return False
    return True


class DeviceSession(SchedulerSession):
    """Persistent device-resident window: the rolling, live-fed ACS-HW
    analogue (DESIGN §2 A3).

    Producers ``submit()`` tasks (or feed a ``TaskStream(sink=session)``)
    at any time; each ``poll``/``drive`` drains everything admitted so far
    as one **epoch**:

    1. the live window is planned symbolically (wave fronts or frontier
       groups, exactly like the per-stream runner) — cross-epoch RAW/WAR
       edges were already resolved at insertion by the window, and epoch
       ordering retires them;
    2. the epoch's slice is lowered against the **session-lifetime arena**:
       slabs stay device-resident across epochs (only rows for newly seen
       buffers are appended), and a **structure-keyed plan cache** maps a
       recurring (signatures × arena addresses) slice straight to its
       lowered tables and compiled program — re-lowering is skipped
       entirely, the common case for RL sim steps and decode chains;
    3. the slice executes in ONE dispatch; host values re-sync only at
       retire boundaries (an epoch whose tasks have listeners, completion
       callbacks, or tickets; an explicit ``flush``/``close``/``sync``) —
       ``host_syncs`` counts them.

    Tasks whose operands cannot live in the arena (opaque pytree values,
    raw byte views) execute host-side *within* the epoch, interleaved in
    plan order with slab re-sync at each device/host transition — so the
    session still accepts any workload the host sessions accept.

    Device residency is a CONTRACT with the producer: while the session is
    open, buffers it has packed must be written only *through submitted
    tasks* — a direct host-side write to ``buf.value`` between epochs is
    invisible to the slabs (the host sessions would honor it) and the
    stale row wins. Symmetrically, reading ``buf.value`` after a bare
    ``poll()`` (no callback/ticket on the task) may observe a pre-epoch
    value until the next retire-boundary sync; call ``sync()`` (or
    ``flush``/``close``) before trusting direct reads.

    Per-epoch stats land in ``epoch_log`` and the aggregate in
    ``session_stats()`` / ``report.session_stats``: epochs, device
    dispatches, plan-cache hits/misses, host syncs, padding waste.
    """

    def __init__(
        self,
        window_size: int = 32,
        registry: Optional[DeviceOpRegistry] = None,
        plan_mode: str = "wave",
        max_group: Optional[int] = None,
        pad_multiple: int = 8,
        compact_waste: float = 0.5,
        compact_min_rows: int = 8,
        plan_cache_limit: Optional[int] = 512,
        history_limit: Optional[int] = None,
    ):
        if plan_mode not in PLAN_MODES:
            raise ValueError(
                f"plan_mode must be one of {PLAN_MODES}, got {plan_mode!r}")
        super().__init__(window_size, history_limit=history_limit)
        self.registry = registry if registry is not None else DeviceOpRegistry(strict=False)
        self.plan_mode = plan_mode
        self.max_group = max_group
        self.arena = SlabArena(pad_multiple=pad_multiple,
                               compact_waste=compact_waste,
                               compact_min_rows=compact_min_rows)
        self._slabs: Optional[List[Any]] = None
        # id(Buffer) -> Buffer whose freshest value lives device-side
        # (slab newer than host) / host-side (host newer than slab).
        self._device_dirty: Dict[int, Buffer] = {}
        self._host_dirty: Dict[int, Buffer] = {}
        # structure key (plan signatures x arena addresses) -> lowered
        # (run_fn, tables, n_steps, class_gens): the session-scope plan
        # cache. Entries carry the arena generation of every class they
        # address; a compaction moves rows, so entries touching a compacted
        # class are invalidated (eagerly at compaction, and belt-and-braces
        # on hit via the recorded generations). Insertion order doubles as
        # LRU order (hits reinsert), bounded by plan_cache_limit.
        self._plan_cache: Dict[Tuple, Tuple] = {}
        self.plan_cache_limit = plan_cache_limit
        self.plan_cache_evictions = 0
        self.plan_cache_invalidations = 0
        # static step-spec structure -> compiled program (shared across
        # plan-cache entries that differ only in row addressing).
        self._programs: Dict[Tuple, Tuple[Callable, Any]] = {}
        self.stats = ExecStats()
        # In-epoch host-fallback path: a plain serial executor whose stats
        # object IS this session's, so its per-task dispatch/compile/jit
        # bookkeeping lands in the one report without duplication.
        self._host_exec = SerialExecutor()
        self._host_exec.stats = self.stats
        self.epochs = 0
        self.device_dispatches = 0
        self.host_task_dispatches = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        self.host_syncs = 0
        self.epoch_log: Any = ([] if history_limit is None
                               else deque(maxlen=history_limit))

    # -- epoch planning ----------------------------------------------------
    def _plan_epoch(self) -> List[List[Task]]:
        """Drain the live window symbolically into this epoch's plan:
        wave fronts or one homogeneous frontier group per step. The window
        retires (and refills from the FIFO) during planning — execution
        follows, then retirement callbacks fire. The replanning is cheap
        by construction: upstream sets were resolved incrementally by the
        scoreboard at submit time, and each retire-and-refill here costs
        O(own segments + out-degree), not a window rescan — so epoch
        planning at window 256 does not melt the admission path."""
        plan: List[List[Task]] = []
        while not self.window.idle():
            ready = self.window.ready_tasks()
            if not ready:
                raise RuntimeError(
                    "device session stall: no READY kernels but window non-empty")
            if self.plan_mode == "frontier":
                group = group_by_signature(ready)[0]
                if self.max_group is not None:
                    group = group[: self.max_group]
            else:
                group = ready
            for t in group:
                self.window.mark_executing(t)
            self.window.retire_many(group)
            plan.append(group)
        return plan

    # -- sync bookkeeping --------------------------------------------------
    def _sync_to_host(self, buffers: Iterable[Buffer]) -> None:
        """Write the given buffers' slab rows back to host values (ONE
        blocking sync, counted)."""
        bufs = [b for b in buffers if id(b) in self._device_dirty]
        if not bufs or self._slabs is None:
            return
        jax.block_until_ready(self._slabs)
        self.arena.unpack(self._slabs, only=bufs)
        for b in bufs:
            del self._device_dirty[id(b)]
        self.host_syncs += 1

    def sync(self) -> None:
        """Force every device-resident value back to host buffers."""
        with self._lock:
            self._sync_to_host(list(self._device_dirty.values()))

    # -- row lifecycle -------------------------------------------------------
    def release_buffer(self, buf: Buffer) -> bool:
        """Release a buffer the producer is done with: its arena row joins
        the class free-list for recycling and its dirty-tracking entries
        drop. The caller guarantees no pending or future task references
        the buffer (serving wires this to ``BufferPool.free`` via a free
        hook, which fires after the owning request retired). The device
        value is NOT synced back — a released buffer owes no host value."""
        with self._lock:
            self._device_dirty.pop(id(buf), None)
            self._host_dirty.pop(id(buf), None)
            return self.arena.free(buf)

    def _maybe_compact(self) -> None:
        """Compact classes whose dead-row waste crossed the arena threshold
        (called with the lock held, between dispatches). Cached plans hold
        static row addresses, so every plan-cache entry addressing a
        compacted class is dropped — exactly those, never the full cache:
        entries over untouched classes stay valid and keep hitting."""
        cids = self.arena.needs_compaction()
        if not cids:
            return
        self._slabs, moved = self.arena.compact(self._slabs, cids)
        stale = [k for k, entry in self._plan_cache.items()
                 if any(cid in moved for cid, _ in entry[3])]
        for k in stale:
            del self._plan_cache[k]
        self.plan_cache_invalidations += len(stale)

    # Observers registered AFTER an unwatched epoch retired their task hit
    # the base class's fire-immediately paths — sync first, so a late
    # callback/ticket holder reads host values as fresh as an early one's.
    def on_task_retired(self, task: Task, cb: RetireCallback) -> None:
        with self._lock:
            if self._is_retired(task.tid):
                self._sync_to_host(list(self._device_dirty.values()))
        super().on_task_retired(task, cb)

    def ticket(self, task: Task) -> TaskTicket:
        with self._lock:
            if self._is_retired(task.tid):
                self._sync_to_host(list(self._device_dirty.values()))
            return super().ticket(task)

    # -- device / host halves ----------------------------------------------
    def _structure_key(self, dev_plan: Sequence[Sequence[Task]]) -> Tuple:
        def opkey(op):
            a = self.arena.address(op)
            return (a.class_id, a.row, a.row_start, a.row_count)

        return tuple(
            tuple(
                (t.signature,
                 tuple(opkey(o) for o in t.inputs),
                 tuple(opkey(o) for o in t.outputs))
                for t in step
            )
            for step in dev_plan
        )

    def _execute_device(self, dev_plan: List[List[Task]]) -> None:
        self._maybe_compact()
        tasks = [t for step in dev_plan for t in step]
        self.arena.add_tasks(tasks)
        key = (self.plan_mode, self._structure_key(dev_plan))
        cached = self._plan_cache.get(key)
        if cached is not None and any(
                self.arena.class_generation(cid) != gen
                for cid, gen in cached[3]):
            # A compaction moved this entry's rows after it was built (the
            # eager sweep should have caught it — this is the safety net).
            del self._plan_cache[key]
            self.plan_cache_invalidations += 1
            cached = None
        if cached is None:
            steps = lower_plan(dev_plan, self.registry, self.arena)
            # Program cache keys on step structure alone: jit retraces by
            # itself when slab shapes grow, so keying on the arena layout
            # would only manufacture duplicate jit wrappers.
            spec_key = tuple(st.spec for st in steps)
            prog = self._programs.get(spec_key)
            if prog is None:
                prog = _build_program(steps)
                self._programs[spec_key] = prog
                self.stats.compiles += 1
            run_fn, runs = prog
            tables = _run_tables(steps, runs)
            class_ids = sorted({
                spec.class_id for st in steps
                for spec in st.spec.inputs + st.spec.outputs})
            gens = tuple(
                (cid, self.arena.class_generation(cid)) for cid in class_ids)
            cached = (run_fn, tables, len(steps), gens)
            self._plan_cache[key] = cached
            self.plan_cache_misses += 1
            if self.plan_cache_limit is not None and \
                    len(self._plan_cache) > self.plan_cache_limit:
                self._plan_cache.pop(next(iter(self._plan_cache)))
                self.plan_cache_evictions += 1
        else:
            # LRU touch: reinsertion moves the entry to the young end.
            self._plan_cache[key] = self._plan_cache.pop(key)
            self.plan_cache_hits += 1
        run_fn, tables, n_steps, _ = cached

        # Persistent slabs: append rows for newly seen buffers, refresh
        # rows whose host values changed since they were packed.
        self._slabs = self.arena.pack_incremental(self._slabs)
        stale = [b for b in self._host_dirty.values() if b in self.arena]
        if stale:
            self._slabs = self.arena.update_rows(self._slabs, stale)
            for b in stale:
                del self._host_dirty[id(b)]

        out = run_fn(tuple(self._slabs), tables)
        self._slabs = list(out)
        self.device_dispatches += 1
        self.stats.dispatches += 1
        self.stats.tasks_run += len(tasks)
        for step in dev_plan:
            self.stats.wave_widths.append(len(step))
        for t in tasks:
            for op in t.outputs:
                b = operand_base(op)
                self._device_dirty[id(b)] = b
                self._host_dirty.pop(id(b), None)

    def _execute_host_step(self, tasks: List[Task]) -> None:
        """In-epoch host fallback (opaque operands): per-task jit dispatch,
        reading fresh values back from the slabs first when a device step
        produced them. Retirement fires per task, so chained callbacks
        (serving decode harvests) observe each intermediate value exactly
        as they would under the host sessions."""
        need: Dict[int, Buffer] = {}
        for t in tasks:
            for op in tuple(t.inputs) + tuple(t.outputs):
                base = operand_base(op)
                if id(base) in self._device_dirty:
                    need[id(base)] = base
        if need:
            self._sync_to_host(need.values())
        for task in tasks:
            self._host_exec.execute_wave([task])
            self.host_task_dispatches += 1
            for op in task.outputs:
                b = operand_base(op)
                self._host_dirty[id(b)] = b
                self._device_dirty.pop(id(b), None)
            self.waves.append([task.tid])
            self._note_retired(task)

    # -- the epoch ----------------------------------------------------------
    def _pump(self) -> bool:
        if self.window.idle():
            return False
        self._run_epoch()
        return True

    def _retire_device_segment(self, dev_plan: List[List[Task]]) -> None:
        """Retire a just-dispatched device segment. Retirement observers —
        listeners, per-task callbacks, ticket holders — read host values,
        so a watched segment syncs the slabs back first (one blocking sync
        — the retire boundary); observation granularity is the segment,
        since intermediate slab states inside its single dispatch are
        never materialized."""
        watched = bool(self._listeners) or any(
            t.tid in self._watchers or t.tid in self._tickets
            for step in dev_plan for t in step)
        if watched:
            self._sync_to_host(list(self._device_dirty.values()))
        for step in dev_plan:
            self.waves.append([t.tid for t in step])
            for t in step:
                self._note_retired(t)

    def _run_epoch(self) -> None:
        plan = self._plan_epoch()
        syncs_before = self.host_syncs
        hits_before = self.plan_cache_hits
        n_device_dispatches = 0
        n_host_tasks = 0
        # Walk the plan in order, batching maximal runs of device-lowerable
        # steps into single dispatches; tasks within one plan step are
        # independent, so splitting a step between the device and host
        # halves preserves every cross-step dependency (plan order).
        pending: List[List[Task]] = []
        for step in plan:
            dev = [t for t in step if _device_lowerable(t)]
            host = [t for t in step if not _device_lowerable(t)]
            if dev:
                pending.append(dev)
            if host:
                if pending:
                    self._execute_device(pending)
                    n_device_dispatches += 1
                    self._retire_device_segment(pending)
                    pending = []
                n_host_tasks += len(host)
                self._execute_host_step(host)
        if pending:
            self._execute_device(pending)
            n_device_dispatches += 1
            self._retire_device_segment(pending)

        self.epochs += 1
        self.epoch_log.append({
            "epoch": self.epochs,
            "tasks": sum(len(step) for step in plan),
            "plan_steps": len(plan),
            "device_dispatches": n_device_dispatches,
            "host_tasks": n_host_tasks,
            "plan_cache_hits": self.plan_cache_hits - hits_before,
            "host_syncs": self.host_syncs - syncs_before,
        })

    # -- lifecycle ----------------------------------------------------------
    def flush(self) -> None:
        """Drain everything submitted so far, then sync device-resident
        values back to host buffers (the observable retire boundary)."""
        super().flush()
        self.sync()

    def session_stats(self) -> Dict[str, Any]:
        """Aggregate session counters (the per-epoch detail is in
        ``epoch_log``)."""
        with self._lock:
            return {
                "epochs": self.epochs,
                "device_dispatches": self.device_dispatches,
                "host_task_dispatches": self.host_task_dispatches,
                "plan_cache_hits": self.plan_cache_hits,
                "plan_cache_misses": self.plan_cache_misses,
                "plan_cache_entries": len(self._plan_cache),
                "plan_cache_evictions": self.plan_cache_evictions,
                "plan_cache_invalidations": self.plan_cache_invalidations,
                "compiled_programs": len(self._programs),
                "host_syncs": self.host_syncs,
                "n_classes": self.arena.n_classes(),
                "padding_waste_frac": round(self.arena.total_waste_frac(), 4),
                # row lifecycle (DESIGN §2 A3 gap (2))
                "slab_bytes": self.arena.slab_bytes(),
                "arena_generation": self.arena.generation,
                "arena_live_rows": self.arena.live_rows(),
                "arena_free_rows": self.arena.free_rows(),
                "arena_recycled_rows": self.arena.recycled_rows,
                "arena_compactions": self.arena.compactions,
                # dependency-engine accounting (probe vs pairwise-equiv)
                "dep_checks": self.window.stats.dep_checks,
                "scoreboard_probes": self.window.stats.scoreboard_probes,
            }

    def _finalize(self) -> SchedulerReport:
        wall = time.perf_counter() - self._t0
        self.stats.exec_seconds = wall
        report = SchedulerReport(self.window, self.stats, wall, self.waves)
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.session_stats = self.session_stats()  # type: ignore[attr-defined]
        report.arena_stats = {  # type: ignore[attr-defined]
            "n_classes": self.arena.n_classes(),
            "total_waste_frac": round(self.arena.total_waste_frac(), 4),
            "per_class": self.arena.padding_waste(),
            "device_steps": sum(e["plan_steps"] for e in self.epoch_log),
        }
        return report
