"""ACS-HW analogue: the scheduling window lives on the device (DESIGN §2 A3).

The paper's ACS-HW moves the window into GPU hardware so that kernel
completion -> upstream update -> ready dispatch never round-trips to the
CPU. A TPU has no command processor we can extend, so the TPU-idiomatic
equivalent is a *device-resident window interpreter*:

1. The host runs the (cheap, windowed) dependency analysis ONCE per stream
   and emits a **wave plan**: dense int32 tables
   ``opcode[wave, slot]``, ``in0/in1/in2[wave, slot]``, ``out[wave, slot]``
   over a slab of uniform-shaped buffers — the moral equivalent of the
   upstream-id SRAM tables of Fig 20.
2. A single compiled program ``lax.scan``s over waves; within a wave every
   slot evaluates ``lax.switch(opcode)(slab[in0], slab[in1], slab[in2])``
   (vmapped — slots in a wave are independent by construction) and
   scatters results back into the slab. Inactive slots write to a dummy
   row.

Host involvement: ONE dispatch for the whole stream — vs one per kernel
(serial) or one per wave (ACS-SW). This is exactly the communication
reduction ACS-HW claims, realized with jax.lax control flow instead of
SRAM next to a command processor.

Constraint (like the paper's HW window): operands must share one padded
shape ``(D,)`` and opcodes must come from a fixed registry. The sim/ and
dyn/ workloads satisfy this by padding (their kernels are small, so slab
padding waste is bounded and reported).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .scheduler import SchedulerReport
from .task import Task, operand_shape
from .window import SchedulingWindow

__all__ = [
    "DeviceOpRegistry",
    "compile_wave_plan",
    "plan_waves",
    "plan_frontier",
    "DeviceWindowRunner",
]

MAX_ARITY = 3


class DeviceOpRegistry:
    """Fixed opcode table for the device interpreter (uniform arity)."""

    def __init__(self) -> None:
        self._ops: List[Tuple[str, Callable]] = []
        self._index: Dict[str, int] = {}

    def register(self, name: str, fn: Callable) -> int:
        """``fn(x, y, z) -> out`` over uniform ``(D,)`` operands; unused
        operands receive the dummy row."""
        if name in self._index:
            return self._index[name]
        idx = len(self._ops)
        self._ops.append((name, fn))
        self._index[name] = idx
        return idx

    def opcode(self, name: str) -> int:
        return self._index[name]

    @property
    def branches(self) -> List[Callable]:
        return [fn for _, fn in self._ops]

    def __len__(self) -> int:
        return len(self._ops)


def plan_waves(tasks: Sequence[Task], window_size: int = 32) -> List[List[Task]]:
    """Run the windowed scheduler symbolically to obtain the wave plan."""
    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    waves: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning waves")
        for t in ready:
            window.mark_executing(t)
        waves.append(ready)
        window.retire_many(ready)
    return waves


def plan_frontier(
    tasks: Sequence[Task], window_size: int = 32, max_group: Optional[int] = None
) -> List[List[Task]]:
    """Frontier-plan mode: one homogeneous group per device step.

    Wave planning retires an entire front per scan step, so every step is
    padded to the *widest wave* and a slow-to-unblock kernel stretches the
    whole table. The frontier plan instead retires one homogeneous group at
    a time, re-collecting the READY set between groups — newly unblocked
    kernels join the very next step rather than waiting out the front.
    Steps are narrower but denser (higher active-slot fraction), which is
    what the ``lax.scan`` interpreter pays for: inactive slots still
    evaluate ``lax.switch`` against the dummy row.
    """
    from .executors import group_by_signature

    window = SchedulingWindow(window_size)
    window.submit_all(tasks)
    groups: List[List[Task]] = []
    while not window.drained():
        ready = window.ready_tasks()
        if not ready:
            raise RuntimeError("stall while planning frontier groups")
        group = group_by_signature(ready)[0]
        if max_group is not None:
            group = group[:max_group]
        for t in group:
            window.mark_executing(t)
        window.retire_many(group)
        groups.append(group)
    return groups


def plan_active_fraction(plan: Sequence[Sequence[Task]]) -> float:
    """Fraction of (step, slot) table cells holding a real kernel — the
    padding-waste metric the frontier plan improves."""
    if not plan:
        return 1.0
    max_w = max(len(step) for step in plan)
    return sum(len(step) for step in plan) / (len(plan) * max_w)


def compile_wave_plan(
    waves: Sequence[Sequence[Task]],
    registry: DeviceOpRegistry,
    buffer_index: Dict[str, int],
    n_rows: int,
) -> Dict[str, np.ndarray]:
    """Lower a wave schedule to dense dispatch tables (the 'SRAM' image)."""
    n_waves = len(waves)
    max_w = max((len(w) for w in waves), default=1)
    dummy = n_rows  # slab has one extra scratch row
    opc = np.zeros((n_waves, max_w), dtype=np.int32)
    ins = np.full((n_waves, max_w, MAX_ARITY), dummy, dtype=np.int32)
    outs = np.full((n_waves, max_w), dummy, dtype=np.int32)
    active = np.zeros((n_waves, max_w), dtype=bool)
    for wi, wave in enumerate(waves):
        for si, task in enumerate(wave):
            opc[wi, si] = registry.opcode(task.opcode)
            for ai, op in enumerate(task.inputs[:MAX_ARITY]):
                ins[wi, si, ai] = buffer_index[op.buffer.name if hasattr(op, "buffer") else op.name]
            outs[wi, si] = buffer_index[
                task.outputs[0].buffer.name if hasattr(task.outputs[0], "buffer") else task.outputs[0].name
            ]
            active[wi, si] = True
    return {"opcode": opc, "ins": ins, "outs": outs, "active": active}


class DeviceWindowRunner:
    """Compile once, then execute entire task streams in ONE dispatch."""

    def __init__(
        self,
        registry: DeviceOpRegistry,
        window_size: int = 32,
        plan_mode: str = "wave",
        max_group: Optional[int] = None,
    ):
        if plan_mode not in ("wave", "frontier"):
            raise ValueError(f"plan_mode must be 'wave' or 'frontier', got {plan_mode!r}")
        self.registry = registry
        self.window_size = window_size
        self.plan_mode = plan_mode
        self.max_group = max_group
        self._compiled: Dict[Tuple, Callable] = {}
        self.stats: Dict[str, Any] = {}

    def _interpreter(self):
        branches = self.registry.branches

        def step(slab, wave):
            # slab: [rows+1, D]; wave tables: opcode [S], ins [S,3], outs [S], active [S]
            def slot(opcode, in_ids, out_id, act):
                x = slab[in_ids[0]]
                y = slab[in_ids[1]]
                z = slab[in_ids[2]]
                res = jax.lax.switch(opcode, branches, x, y, z)
                return jnp.where(act, res, slab[out_id]), out_id

            results, out_ids = jax.vmap(slot)(
                wave["opcode"], wave["ins"], wave["outs"], wave["active"]
            )
            slab = slab.at[out_ids].set(results)
            return slab, None

        def run(slab, plan):
            slab, _ = jax.lax.scan(step, slab, plan)
            return slab

        return run

    def execute(
        self,
        tasks: Sequence[Task],
        buffers: Sequence,  # core.buffers.Buffer, uniform padded shape (D,)
    ) -> SchedulerReport:
        t0 = time.perf_counter()
        if self.plan_mode == "frontier":
            waves = plan_frontier(tasks, self.window_size, self.max_group)
        else:
            waves = plan_waves(tasks, self.window_size)
        plan_time = time.perf_counter() - t0

        buffer_index = {b.name: i for i, b in enumerate(buffers)}
        n_rows = len(buffers)
        tables = compile_wave_plan(waves, self.registry, buffer_index, n_rows)

        d = int(buffers[0].shape[-1])
        key = (tables["opcode"].shape, d, len(self.registry))
        run = self._compiled.get(key)
        if run is None:
            run = jax.jit(self._interpreter())
            self._compiled[key] = run

        slab = jnp.stack([jnp.asarray(b.value) for b in buffers] + [jnp.zeros((d,), dtype=buffers[0].value.dtype)])
        plan = {k: jnp.asarray(v) for k, v in tables.items()}
        t1 = time.perf_counter()
        slab = run(slab, plan)
        slab.block_until_ready()
        exec_time = time.perf_counter() - t1
        for i, b in enumerate(buffers):
            b.value = slab[i]

        window = SchedulingWindow(self.window_size)  # stats container
        from .executors import ExecStats

        stats = ExecStats()
        stats.dispatches = 1  # the whole stream was one launch
        stats.tasks_run = len(tasks)
        stats.wave_widths = [len(w) for w in waves]
        stats.exec_seconds = exec_time
        report = SchedulerReport(window, stats, plan_time + exec_time, [[t.tid for t in w] for w in waves])
        report.plan_seconds = plan_time  # type: ignore[attr-defined]
        report.plan_mode = self.plan_mode  # type: ignore[attr-defined]
        report.plan_active_fraction = plan_active_fraction(waves)  # type: ignore[attr-defined]
        return report
