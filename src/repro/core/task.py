"""Task IR — the unit the ACS window schedules.

A ``Task`` is the TPU-side analogue of a CUDA kernel launch packet
(§II-A): an opcode, operand buffer references, the resolved read/write
``Segment``s (the paper's launch-time ``get_addresses`` output), and a
static cost estimate used by the wave packer and the roofline accounting.

Tasks with equal ``signature`` are *batchable*: the wave executor may run
them as one vmapped / grouped-GEMM launch — the TPU realization of
"concurrent execution of independent kernels".
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import numpy as np

from .buffers import Buffer, BufferView
from .segments import Segment, SegmentSet

__all__ = ["Task", "Operand", "operand_shape", "operand_dtype", "operand_base"]

Operand = Union[Buffer, BufferView]

_tid_counter = itertools.count()


def operand_shape(op: Operand) -> Tuple[int, ...]:
    if isinstance(op, BufferView):
        if op.row_count is None:
            raise ValueError("non-row views have no array shape")
        return (op.row_count,) + tuple(op.buffer.shape[1:])
    return tuple(op.shape)


def operand_dtype(op: Operand) -> np.dtype:
    buf = op.buffer if isinstance(op, BufferView) else op
    return np.dtype(buf.dtype)


def operand_base(op: Operand) -> Buffer:
    """The backing allocation: a view's parent buffer, or the buffer itself.
    This is the unit the slab arena assigns rows to."""
    return op.buffer if isinstance(op, BufferView) else op


@dataclasses.dataclass
class Task:
    """One schedulable kernel invocation."""

    opcode: str
    fn: Callable[..., Any]  # pure: (*input_values) -> output value | tuple
    inputs: Tuple[Operand, ...]
    outputs: Tuple[Operand, ...]
    read_segments: SegmentSet
    write_segments: SegmentSet
    cost_flops: float = 0.0
    cost_bytes: float = 0.0
    tid: int = dataclasses.field(default_factory=lambda: next(_tid_counter))
    # Extra python-scalar params baked into fn via the wrapper (kept for
    # signature identity so compiled wave programs can be reused).
    static_args: Tuple[Any, ...] = ()
    # Unique id of the defining AcsKernel — disambiguates distinct kernels
    # that share a display name (e.g. two lambdas): signature safety.
    kernel_uid: int = -1
    # Tag of the TaskStream that pushed this task (live sessions: per-tenant
    # / per-request accounting). Not part of the signature.
    stream_tag: Optional[str] = None
    # QoS class: lower = more urgent (0 = highest). Only a *scheduling
    # hint* — it buckets the window's READY index so urgent work launches
    # first among provably independent kernels; it never reorders
    # dependent work and is not part of the signature (a compiled wave
    # program serves every priority class).
    priority: int = 1

    @property
    def signature(self) -> Tuple:
        """Batching/caching key: same signature => same compiled program."""
        return (
            self.opcode,
            self.kernel_uid,
            tuple((operand_shape(x), str(operand_dtype(x))) for x in self.inputs),
            tuple((operand_shape(x), str(operand_dtype(x))) for x in self.outputs),
            self.static_args,
        )

    def input_values(self) -> Tuple[Any, ...]:
        return tuple(x.get_value() for x in self.inputs)

    def write_outputs(self, results: Any) -> None:
        if not isinstance(results, (tuple, list)):
            results = (results,)
        if len(results) != len(self.outputs):
            raise ValueError(
                f"task {self.opcode}#{self.tid}: fn returned {len(results)} "
                f"values for {len(self.outputs)} outputs"
            )
        for out, val in zip(self.outputs, results):
            out.set_value(val)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Task({self.opcode}#{self.tid}, in={len(self.inputs)}, out={len(self.outputs)})"


def default_segments(
    inputs: Sequence[Operand], outputs: Sequence[Operand]
) -> Tuple[SegmentSet, SegmentSet]:
    """Fig 17 default: every input read in full, every output written in full."""
    return (
        SegmentSet([x.segment for x in inputs]),
        SegmentSet([x.segment for x in outputs]),
    )
