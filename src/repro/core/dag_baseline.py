"""Full-DAG baseline — the CUDA Graph / ATMI comparison point (§II-D, Fig 9).

CUDA Graph requires the *entire* dependency DAG to be constructed before
execution, for every input. That is an all-pairs dependency check over the
whole stream (O(n^2) in stream length vs ACS's O(n·W) windowed checks),
plus a whole-graph schedule. The paper measures this construction at ~47%
of total runtime for Brax — the benchmark `bench_dag_overhead.py`
reproduces that measurement against this implementation.

For *static* graphs the constructed schedule can be cached and replayed
(``DagGraph.execute`` with ``construct=False``), reproducing the paper's
Fig 27 observation that CUDA Graph matches ACS-HW when the graph never
changes.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from .executors import FusedWaveExecutor
from .scheduler import SchedulerReport
from .segments import depends_on
from .task import Task
from .window import SchedulingWindow

__all__ = ["build_full_dag", "level_schedule", "DagRunner"]


def build_full_dag(tasks: Sequence[Task]) -> Tuple[Dict[int, List[int]], int]:
    """All-pairs dependency construction. Returns (edges: tid -> upstream
    tids, number of dependency checks performed)."""
    edges: Dict[int, List[int]] = {t.tid: [] for t in tasks}
    checks = 0
    for j, newer in enumerate(tasks):
        for older in tasks[:j]:
            checks += 1
            if depends_on(
                newer.read_segments,
                newer.write_segments,
                older.read_segments,
                older.write_segments,
            ):
                edges[newer.tid].append(older.tid)
    return edges, checks


def level_schedule(tasks: Sequence[Task], edges: Dict[int, List[int]]) -> List[List[Task]]:
    """Topological level order: level(t) = 1 + max(level(upstream))."""
    by_tid = {t.tid: t for t in tasks}
    level: Dict[int, int] = {}
    for t in tasks:  # program order is a valid topological order
        ups = edges[t.tid]
        level[t.tid] = 1 + max((level[u] for u in ups), default=-1)
    n_levels = 1 + max(level.values(), default=0)
    out: List[List[Task]] = [[] for _ in range(n_levels)]
    for tid, lv in level.items():
        out[lv].append(by_tid[tid])
    return out


class DagRunner:
    """Construct-then-execute runner with optional schedule caching."""

    def __init__(self) -> None:
        self._cached: Optional[List[List[Task]]] = None
        self.construct_seconds = 0.0
        self.dep_checks = 0

    def construct(self, tasks: Sequence[Task]) -> None:
        t0 = time.perf_counter()
        edges, checks = build_full_dag(tasks)
        self._cached = level_schedule(tasks, edges)
        self.construct_seconds += time.perf_counter() - t0
        self.dep_checks += checks

    def execute(self, tasks: Sequence[Task], construct: bool = True) -> SchedulerReport:
        """If ``construct`` (the dynamic-graph case), the DAG is rebuilt for
        this input; otherwise the cached schedule is replayed (static case).
        """
        if construct or self._cached is None:
            self.construct(tasks)
        schedule = self._cached
        assert schedule is not None
        executor = FusedWaveExecutor()
        window = SchedulingWindow(size=max(1, len(tasks)))  # for stats shape only
        t0 = time.perf_counter()
        waves: List[List[int]] = []
        for wave in schedule:
            executor.execute_wave(wave)
            waves.append([t.tid for t in wave])
        executor.finalize()
        wall = time.perf_counter() - t0
        report = SchedulerReport(window, executor.stats, wall, waves)
        report.construct_seconds = self.construct_seconds  # type: ignore[attr-defined]
        report.dep_checks = self.dep_checks  # type: ignore[attr-defined]
        return report
