"""Shape-class slab arena — the device-resident buffer image (DESIGN §2 A3).

ACS-HW keeps the scheduling window *and* the kernels' operands next to the
command processor so dispatch never round-trips to the host. Our device
interpreter (`core/device_dispatch.py`) needs the same thing on TPU: every
operand a lowered stream touches must live in a device-resident slab that
dispatch tables can index with plain integers. The seed version supported
exactly one uniform ``(D,)`` shape; the arena generalizes it to the real
workloads:

* Operands are grouped into **shape classes** ``(padded_shape, dtype)``;
  the padded shape rounds the trailing dimension up to ``pad_multiple``
  (8 by default — one TPU sublane; use 128 to model full lane padding).
  Two buffers whose shapes pad to the same tuple share a class even when
  their true shapes differ — the per-operand true shape is static in the
  lowered program, so gathers slice the padding back off before compute.
* Each class owns one **slab** ``[rows, *padded_shape]``; every
  ``Buffer`` is assigned one row, and a row-``BufferView`` resolves to a
  leading-axis sub-interval of its parent's row, so view aliasing (a
  joint writing one row of a force buffer the integrator later reads in
  full) behaves exactly like the virtual-address-range checks in
  `core/buffers.py`. (The seed's dummy row is gone: arena steps are
  fully active — no inactive slots needing a write sink.)
* Padding is **accounted, not hidden**: ``padding_waste()`` reports, per
  class, the row count and the fraction of slab cells occupied by padding
  — the cost of running heterogeneous kernels through a uniform-indexed
  arena, which benchmarks surface next to dispatch counts.
* The arena may be **persistent** (the `DeviceSession` rolling window):
  ``pack_incremental`` keeps already-materialized slabs and appends only
  rows added since the last pack (new submissions referencing new
  buffers), and ``update_rows`` refreshes individual rows whose host
  values changed (host-fallback writes between device epochs). Row and
  class ids are stable *between compactions*, so lowered dispatch tables
  stay valid across epochs.
* Rows have a **lifecycle** (DESIGN §2 A3 — the unbounded-lifetime gap):
  ``free(buf)`` releases a buffer's row into its class's free-list, and
  ``add`` recycles free rows before growing the slab — a long-lived
  session fed per-request buffers reuses a bounded row set instead of
  leaking one row per request. Recycled rows inside the packed watermark
  are tracked and refreshed from host values at the next
  ``pack_incremental`` (the device row still holds the dead buffer's
  bits). When a class's dead-row fraction crosses ``compact_waste``
  (``needs_compaction``), ``compact`` rebuilds the class: live rows are
  renumbered densely (old order preserved), the device slab is gathered
  in place (no host round-trip for already-packed rows), and the class's
  **generation** counter bumps — the signal consumers holding static row
  addresses (the `DeviceSession` plan cache) use to invalidate exactly
  the affected entries.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .buffers import Buffer, BufferView
from .task import Operand, Task, operand_base

__all__ = ["ShapeClass", "ArenaAddress", "SlabArena", "ShardTransferTable",
           "pad_shape", "row_capacity"]


def _commit_like(val: Any, slab: Any) -> Any:
    """Place ``val`` on the device ``slab`` is committed to. A persistent
    slab pinned to a non-default device (mesh shards pin each shard's
    session) must not be updated with values committed elsewhere: a
    buffer written by one shard's dispatch holds an array committed to
    THAT shard's device, and scattering it into another shard's slab
    raises jax's incompatible-devices error. Uncommitted slabs (single
    device, plain host sessions) pass through untouched."""
    if getattr(slab, "committed", False):
        import jax

        (dev,) = slab.devices()
        return jax.device_put(val, dev)
    return val


def row_capacity(n_rows: int) -> int:
    """Physical slab rows for ``n_rows`` logical rows: the next power of
    two (floored at 8). Slab shapes are jit trace signatures — an
    exact-fit slab forces a retrace (and a full XLA compile) every time
    the resident peak moves by one row, which dominates wall time for
    small irregular kernels. Quantizing capacity bounds the distinct
    shapes per class at O(log peak); rows past the logical count hold
    zeros and are never addressed."""
    cap = 8
    while cap < n_rows:
        cap *= 2
    return cap


def pad_shape(shape: Tuple[int, ...], pad_multiple: int) -> Tuple[int, ...]:
    """Round the trailing dimension up to ``pad_multiple`` (scalars pass
    through)."""
    if not shape or pad_multiple <= 1:
        return tuple(shape)
    last = -(-shape[-1] // pad_multiple) * pad_multiple
    return tuple(shape[:-1]) + (last,)


@dataclasses.dataclass(frozen=True)
class ShapeClass:
    """One slab's identity: the padded shape every resident row shares."""

    padded_shape: Tuple[int, ...]
    dtype: str

    @property
    def row_elems(self) -> int:
        return int(np.prod(self.padded_shape, dtype=np.int64)) if self.padded_shape else 1

    @property
    def label(self) -> str:
        return f"{self.dtype}{list(self.padded_shape)}"


@dataclasses.dataclass(frozen=True)
class ArenaAddress:
    """Where one operand lives: ``slabs[class_id][row]``, optionally a
    leading-axis sub-interval ``[row_start : row_start + row_count]`` when
    the operand is a row view of its parent buffer."""

    class_id: int
    row: int
    row_start: int = 0
    row_count: int = 0  # 0 => the whole row (a full Buffer operand)

    @property
    def is_view(self) -> bool:
        return self.row_count > 0


class ShardTransferTable:
    """Cross-shard row-transfer ledger for a mesh-sharded window.

    Each shard owns its own :class:`SlabArena` — a shard-local address
    space: ``(class_id, row)`` coordinates are meaningful only against the
    owning shard's slabs, so a buffer consumed on a different shard than
    the one that produced it cannot be addressed remotely; its row is
    MOVED across at a sub-epoch boundary — either as a direct
    device-to-device peer copy of the slab row (``mode="d2d"``) or through
    the host-staged fallback (owner syncs the row to host, the destination
    refreshes it on its next dispatch; ``mode="staged"``). This table
    records every such copy — source shard, destination shard, shape-class
    label, row bytes, and transfer mode — so the mesh session can report
    cross-device traffic honestly (the paper's concurrency claims are only
    meaningful net of transfer cost).
    """

    def __init__(self) -> None:
        self.transfers = 0
        self.bytes = 0
        # (src_shard, dst_shard) -> count; class label -> count;
        # mode -> {transfers, bytes} (the d2d-vs-staged audit split).
        self.by_route: Dict[Tuple[int, int], int] = {}
        self.by_class: Dict[str, int] = {}
        self.by_mode: Dict[str, Dict[str, int]] = {}

    def record(self, src_shard: int, dst_shard: int, class_label: str,
               nbytes: int, mode: str = "staged") -> None:
        self.transfers += 1
        self.bytes += int(nbytes)
        route = (src_shard, dst_shard)
        self.by_route[route] = self.by_route.get(route, 0) + 1
        self.by_class[class_label] = self.by_class.get(class_label, 0) + 1
        slot = self.by_mode.setdefault(mode, {"transfers": 0, "bytes": 0})
        slot["transfers"] += 1
        slot["bytes"] += int(nbytes)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "transfers": self.transfers,
            "bytes": self.bytes,
            "by_route": {f"{s}->{d}": n
                         for (s, d), n in sorted(self.by_route.items())},
            "by_class": dict(sorted(self.by_class.items())),
            "by_mode": {m: dict(v)
                        for m, v in sorted(self.by_mode.items())},
        }


class SlabArena:
    """Assigns buffers to (class, row) slab coordinates and moves values
    host<->device around a lowered stream's single dispatch."""

    def __init__(self, pad_multiple: int = 8, *, compact_waste: float = 0.5,
                 compact_min_rows: int = 8):
        self.pad_multiple = pad_multiple
        # Compaction policy: rebuild a class once it holds at least
        # compact_min_rows rows and its dead fraction reaches compact_waste.
        self.compact_waste = compact_waste
        self.compact_min_rows = compact_min_rows
        self._class_ids: Dict[ShapeClass, int] = {}
        self._classes: List[ShapeClass] = []
        # per class, row -> Buffer (None = freed row awaiting reuse)
        self._rows: List[List[Optional[Buffer]]] = []
        # id(Buffer) -> (class, row); _rows holds the references, keeping
        # the ids stable between compactions.
        self._addr: Dict[int, Tuple[int, int]] = {}
        # Per-class count of rows already materialized into device slabs
        # (the pack_incremental watermark).
        self._packed_rows: List[int] = []
        # Per-class LIFO free-lists of recyclable row indices.
        self._free: List[List[int]] = []
        # Per-class rows below the packed watermark that were re-assigned to
        # a new buffer since the last pack: the device row still holds the
        # dead occupant's bits and must be refreshed at the next
        # pack_incremental.
        self._reused: List[set] = []
        # Per-class compaction counters; a cached plan built against a
        # class's addresses is valid iff the generation it recorded still
        # matches. `generation` is the global sum (cheap change detector).
        self._generation: List[int] = []
        self.generation = 0
        # Lifecycle counters (surfaced through session_stats / benchmarks).
        self.freed_rows = 0
        self.recycled_rows = 0
        self.compactions = 0
        self.unpack_rows_written = 0

    # -- classification ----------------------------------------------------
    def class_of(self, buf: Buffer) -> ShapeClass:
        return ShapeClass(
            padded_shape=pad_shape(tuple(buf.shape), self.pad_multiple),
            dtype=str(np.dtype(buf.dtype)),
        )

    def row_nbytes(self, buf: Buffer) -> int:
        """Padded slab-row bytes a transfer of this buffer moves — what a
        :class:`ShardTransferTable` records per staged cross-shard copy."""
        cls = self.class_of(buf)
        return cls.row_elems * np.dtype(cls.dtype).itemsize

    def add(self, buf: Buffer) -> Tuple[int, int]:
        """Assign ``buf`` a (class_id, row); idempotent per buffer object."""
        key = id(buf)
        if key in self._addr:
            return self._addr[key]
        cls = self.class_of(buf)
        cid = self._class_ids.get(cls)
        if cid is None:
            cid = len(self._classes)
            self._class_ids[cls] = cid
            self._classes.append(cls)
            self._rows.append([])
            self._packed_rows.append(0)
            self._free.append([])
            self._reused.append(set())
            self._generation.append(0)
        if self._free[cid]:
            row = self._free[cid].pop()
            self._rows[cid][row] = buf
            self.recycled_rows += 1
            if row < self._packed_rows[cid]:
                # The materialized slab row holds the previous occupant's
                # value; refresh it from host at the next incremental pack.
                self._reused[cid].add(row)
        else:
            row = len(self._rows[cid])
            self._rows[cid].append(buf)
        self._addr[key] = (cid, row)
        return cid, row

    def free(self, buf: Buffer) -> bool:
        """Release ``buf``'s row into its class free-list for recycling.

        Returns False (no-op) when the buffer is not arena-resident. The
        caller is responsible for ordering: a row must not be freed while a
        pending task still references its buffer.
        """
        addr = self._addr.pop(id(buf), None)
        if addr is None:
            return False
        cid, row = addr
        self._rows[cid][row] = None
        self._free[cid].append(row)
        self._reused[cid].discard(row)
        self.freed_rows += 1
        return True

    def add_tasks(self, tasks: Iterable[Task]) -> None:
        for t in tasks:
            for op in tuple(t.inputs) + tuple(t.outputs):
                self.add(operand_base(op))

    def address(self, op: Operand) -> ArenaAddress:
        """Resolve an operand to its arena coordinates (adding the parent
        buffer if unseen)."""
        if isinstance(op, BufferView):
            if op.row_start is None:
                raise ValueError(
                    f"arena operands must be Buffers or row views; {op.name!r} "
                    "is a raw byte view (no row_start)"
                )
            cid, row = self.add(op.buffer)
            return ArenaAddress(cid, row, op.row_start, op.row_count)
        cid, row = self.add(op)
        return ArenaAddress(cid, row)

    # -- introspection -----------------------------------------------------
    def __contains__(self, buf: Buffer) -> bool:
        """True iff ``buf`` already holds a (class, row) assignment."""
        return id(buf) in self._addr

    def addr_of(self, buf: Buffer) -> Optional[Tuple[int, int]]:
        """``(class_id, row)`` for a resident buffer, ``None`` otherwise —
        the read-only lookup transfer layers use (unlike :meth:`add`, it
        never assigns a row as a side effect)."""
        return self._addr.get(id(buf))

    # -- row-granular device transfer (mesh d2d edges) ----------------------
    def export_row(self, slabs: Sequence[Any], buf: Buffer, *,
                   expected_generation: Optional[int] = None) -> Any:
        """The materialized device row holding ``buf``'s padded value —
        the unit a :class:`ShardLink` peer-copies to another shard without
        a host round-trip. Raises if the buffer is not resident, its row
        was never packed, or the class generation moved under the caller
        (a compaction renumbered rows between address capture and export)."""
        addr = self._addr.get(id(buf))
        if addr is None:
            raise KeyError(f"export_row: {buf.name!r} is not arena-resident")
        cid, row = addr
        if expected_generation is not None and \
                self._generation[cid] != expected_generation:
            raise RuntimeError(
                f"export_row: class {cid} generation moved "
                f"{expected_generation} -> {self._generation[cid]} "
                f"(compaction invalidated the captured row address)")
        if row >= self._packed_rows[cid] or row in self._reused[cid]:
            raise RuntimeError(
                f"export_row: {buf.name!r} row {row} is not materialized "
                "device-side (unpacked or pending host refresh)")
        return slabs[cid][row]

    def import_row(self, slabs: Sequence[Any], buf: Buffer, value: Any, *,
                   expected_generation: Optional[int] = None) -> List[Any]:
        """Functionally set ``buf``'s slab row to ``value`` (a padded row
        exported from a peer shard), committing the value onto this slab's
        device — the receiving half of a d2d edge. Requires the row to be
        materialized already (inside the packed watermark); the same
        generation check as :meth:`export_row` applies."""
        addr = self._addr.get(id(buf))
        if addr is None:
            raise KeyError(f"import_row: {buf.name!r} is not arena-resident")
        cid, row = addr
        if expected_generation is not None and \
                self._generation[cid] != expected_generation:
            raise RuntimeError(
                f"import_row: class {cid} generation moved "
                f"{expected_generation} -> {self._generation[cid]} "
                f"(compaction invalidated the captured row address)")
        if row >= self._packed_rows[cid]:
            raise RuntimeError(
                f"import_row: {buf.name!r} row {row} is not materialized "
                "device-side yet (pack before importing)")
        cls = self._classes[cid]
        if tuple(value.shape) != cls.padded_shape:
            raise ValueError(
                f"import_row: {buf.name!r} expects a padded row of shape "
                f"{cls.padded_shape}, got {tuple(value.shape)}")
        out = list(slabs)
        out[cid] = out[cid].at[row].set(
            _commit_like(value.astype(out[cid].dtype), out[cid]))
        # The device row now holds the peer's bits; a pending host-refresh
        # mark would clobber them at the next pack.
        self._reused[cid].discard(row)
        return out

    @property
    def classes(self) -> List[ShapeClass]:
        return list(self._classes)

    def n_classes(self) -> int:
        return len(self._classes)

    def rows(self, class_id: int) -> List[Optional[Buffer]]:
        return list(self._rows[class_id])

    def class_generation(self, class_id: int) -> int:
        return self._generation[class_id]

    def device_address_table(self, operands: Sequence[Operand]
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Resolve operands to dense per-slot address arrays — the form the
        device-resident ready-queue program indexes with plain integers
        (DESIGN §2 A3): ``(rows, starts)``, both ``[len(operands)] int32``.
        ``rows`` is each operand's slab row; ``starts`` the leading-axis
        offset for row views (0 for full-buffer operands). Class ids and
        view extents stay static in the lowered program (they select the
        slab and the slice width), so only the row/start integers need to
        travel as device operands."""
        rows = np.zeros(len(operands), np.int32)
        starts = np.zeros(len(operands), np.int32)
        for i, op in enumerate(operands):
            addr = self.address(op)
            rows[i] = addr.row
            starts[i] = addr.row_start
        return rows, starts

    def live_rows(self, class_id: Optional[int] = None) -> int:
        if class_id is not None:
            return len(self._rows[class_id]) - len(self._free[class_id])
        return sum(len(r) for r in self._rows) - sum(len(f) for f in self._free)

    def free_rows(self, class_id: Optional[int] = None) -> int:
        if class_id is not None:
            return len(self._free[class_id])
        return sum(len(f) for f in self._free)

    def slab_bytes(self) -> int:
        """Device footprint of the slabs the next pack materializes: total
        rows (live + dead-but-unreclaimed) x padded row bytes per class."""
        total = 0
        for cid, cls in enumerate(self._classes):
            total += len(self._rows[cid]) * cls.row_elems * np.dtype(cls.dtype).itemsize
        return total

    def padding_waste(self) -> Dict[str, Dict[str, Any]]:
        """Per-class occupancy: how many slab cells hold real values vs
        trailing-dimension padding and dead (freed, not yet compacted)
        rows."""
        out: Dict[str, Dict[str, Any]] = {}
        for cid, cls in enumerate(self._classes):
            bufs = self._rows[cid]
            padded = cls.row_elems
            used = sum(
                int(np.prod(b.shape, dtype=np.int64)) if b.shape else 1
                for b in bufs if b is not None
            )
            total = padded * len(bufs)
            out[cls.label] = {
                "rows": len(bufs),
                "dead_rows": len(self._free[cid]),
                "padded_elems_per_row": padded,
                "used_elems": used,
                "waste_frac": round(1.0 - used / total, 4) if total else 0.0,
            }
        return out

    def total_waste_frac(self) -> float:
        padded = used = 0
        for cid, cls in enumerate(self._classes):
            padded += cls.row_elems * len(self._rows[cid])
            used += sum(
                int(np.prod(b.shape, dtype=np.int64)) if b.shape else 1
                for b in self._rows[cid] if b is not None
            )
        return 1.0 - used / padded if padded else 0.0

    # -- compaction ---------------------------------------------------------
    def needs_compaction(self) -> List[int]:
        """Class ids whose dead-row fraction crossed the policy threshold."""
        out = []
        for cid in range(len(self._classes)):
            total = len(self._rows[cid])
            if total >= self.compact_min_rows and \
                    len(self._free[cid]) / total >= self.compact_waste:
                out.append(cid)
        return out

    def compact(self, slabs: Optional[Sequence[Any]] = None,
                class_ids: Optional[Iterable[int]] = None,
                ) -> Tuple[Optional[List[Any]], Dict[int, Dict[int, int]]]:
        """Rebuild the given classes' slabs with dead rows squeezed out.

        Live rows keep their relative order, so already-packed rows form a
        dense prefix and the new slab is a pure device-side gather of the
        old one — freed rows' values are dropped, never round-tripped
        through the host. Rows beyond the old watermark were never
        materialized; the watermark resets to the packed-live count and the
        next :meth:`pack_incremental` appends them as usual.

        Returns ``(new_slabs, moved)`` where ``moved[cid]`` maps old row ->
        new row for every surviving row of a compacted class. Each
        compacted class's generation (and the global ``generation``) bumps,
        invalidating any consumer-cached addressing built against it.
        ``slabs=None`` skips the device gather (un-materialized arena).
        """
        if class_ids is None:
            class_ids = self.needs_compaction()
        out = None if slabs is None else list(slabs)
        moved: Dict[int, Dict[int, int]] = {}
        for cid in class_ids:
            if not self._free[cid]:
                continue
            rows = self._rows[cid]
            packed = self._packed_rows[cid]
            live_old = [r for r, b in enumerate(rows) if b is not None]
            remap = {old: new for new, old in enumerate(live_old)}
            # ascending order => packed live rows are exactly the prefix
            n_packed_live = sum(1 for r in live_old if r < packed)
            for old in live_old:
                self._addr[id(rows[old])] = (cid, remap[old])
            self._rows[cid] = [rows[r] for r in live_old]
            self._free[cid] = []
            self._reused[cid] = {remap[r] for r in self._reused[cid]}
            self._packed_rows[cid] = n_packed_live
            if out is not None and cid < len(out):
                keep = live_old[:n_packed_live]
                slab = out[cid][jnp.asarray(keep, dtype=jnp.int32)] \
                    if keep else out[cid][:0]
                # Re-pad to quantized capacity over the squeezed logical
                # rows, so the follow-up pack_incremental appends within
                # capacity instead of changing the slab shape again.
                cap = row_capacity(len(self._rows[cid]))
                if cap > slab.shape[0]:
                    cls = self._classes[cid]
                    slab = jnp.concatenate(
                        [slab,
                         jnp.zeros((cap - slab.shape[0],) + cls.padded_shape,
                                   slab.dtype)])
                out[cid] = slab
            moved[cid] = remap
            self._generation[cid] += 1
            self.generation += 1
            self.compactions += 1
        return out, moved

    # -- host <-> device movement ------------------------------------------
    @staticmethod
    def _place(val: Any, device: Optional[Any]) -> Any:
        """Commit a row value onto ``device`` before it is stacked with
        sibling rows. Host values are not guaranteed co-located: after a
        cross-shard unpack, ``buf.value`` is a slice of the OWNING shard's
        slab, committed to that shard's device — stacking two such rows
        from different shards raises jax's incompatible-devices error
        unless the consumer pins them onto its own device first."""
        if device is None:
            return val
        import jax

        return jax.device_put(val, device)

    def _row_value(self, buf: Optional[Buffer], cls: ShapeClass):
        if buf is None:
            # Dead row (freed, not yet recycled/compacted): placeholder.
            return jnp.zeros(cls.padded_shape, dtype=np.dtype(cls.dtype))
        return self._padded_value(buf, cls)

    def _padded_value(self, buf: Buffer, cls: ShapeClass):
        val = buf.value
        if val is None:
            # Not-yet-produced output: program order guarantees the
            # producing step scatters before any consumer gathers.
            return jnp.zeros(cls.padded_shape, dtype=np.dtype(cls.dtype))
        val = jnp.asarray(val)
        if tuple(val.shape) != tuple(buf.shape):
            raise ValueError(
                f"buffer {buf.name!r} declares shape {tuple(buf.shape)} but "
                f"holds a value of shape {tuple(val.shape)}"
            )
        if tuple(val.shape) == cls.padded_shape:
            return val
        pads = [(0, p - s) for s, p in zip(val.shape, cls.padded_shape)]
        return jnp.pad(val, pads)

    def pack(self, device: Optional[Any] = None) -> List[Any]:
        """One device array per class: ``[rows, *padded_shape]``. Every
        row is addressable by some operand — no scratch row (all lowered
        steps are fully active). ``device`` pins each row value before
        stacking (see :meth:`_place`)."""
        slabs = []
        for cid, cls in enumerate(self._classes):
            dtype = np.dtype(cls.dtype)
            rows = [self._place(self._row_value(b, cls), device)
                    for b in self._rows[cid]]
            slab = jnp.stack(rows).astype(dtype)
            cap = row_capacity(len(rows))
            if cap > len(rows):
                slab = jnp.concatenate(
                    [slab, jnp.zeros((cap - len(rows),) + cls.padded_shape,
                                     dtype)])
            slabs.append(slab)
            self._packed_rows[cid] = len(self._rows[cid])
            self._reused[cid].clear()  # every row just re-read from host
        return slabs

    def pack_incremental(self, slabs: Optional[Sequence[Any]],
                         device: Optional[Any] = None) -> List[Any]:
        """Persistent-arena pack: keep already-materialized slab rows (they
        hold the latest device-side values) and append only rows added
        since the last pack. ``slabs=None`` degenerates to a full
        :meth:`pack`. New classes get fresh slabs; existing slabs are never
        re-read from host values — host-side changes to already-packed
        buffers go through :meth:`update_rows`. ``device`` pins appended
        and refreshed row values before stacking (see :meth:`_place`)."""
        if slabs is None:
            return self.pack(device=device)
        out: List[Any] = list(slabs)
        for cid, cls in enumerate(self._classes):
            dtype = np.dtype(cls.dtype)
            total = len(self._rows[cid])
            packed = self._packed_rows[cid] if cid < len(slabs) else 0
            if packed < total:
                fresh = jnp.stack(
                    [self._place(self._row_value(b, cls), device)
                     for b in self._rows[cid][packed:]]
                ).astype(dtype)
                if cid < len(out):
                    cap = out[cid].shape[0]
                    if total > cap:
                        new_cap = row_capacity(total)
                        out[cid] = jnp.concatenate(
                            [out[cid],
                             jnp.zeros((new_cap - cap,) + cls.padded_shape,
                                       dtype)])
                    out[cid] = out[cid].at[packed:total].set(
                        _commit_like(fresh, out[cid]))
                else:
                    cap = row_capacity(total)
                    slab = jnp.zeros((cap,) + cls.padded_shape, dtype)
                    out.append(slab.at[:total].set(fresh))
                self._packed_rows[cid] = total
            if self._reused[cid]:
                # Recycled rows inside the watermark: the slab still holds
                # the dead occupant's bits — refresh from host values.
                rows = sorted(self._reused[cid])
                vals = jnp.stack(
                    [self._place(self._row_value(self._rows[cid][r], cls),
                                 device) for r in rows]
                ).astype(dtype)
                out[cid] = out[cid].at[jnp.asarray(rows, dtype=jnp.int32)].set(
                    _commit_like(vals, out[cid]))
                self._reused[cid].clear()
        return out

    def update_rows(self, slabs: Sequence[Any],
                    buffers: Iterable[Buffer]) -> List[Any]:
        """Refresh the given buffers' slab rows from their current host
        values (functional update): the re-sync path for buffers written
        host-side between device epochs."""
        out = list(slabs)
        for buf in buffers:
            cid, row = self._addr[id(buf)]
            val = self._padded_value(buf, self._classes[cid])
            out[cid] = out[cid].at[row].set(
                _commit_like(val.astype(out[cid].dtype), out[cid]))
        return out

    def unpack(self, slabs: Sequence[Any],
               only: Optional[Iterable[Buffer]] = None) -> None:
        """Write slab rows back into buffer values, slicing padding off.

        ``only`` restricts writeback to the given buffers (e.g. the ones
        some task actually wrote) and resolves each through the address map
        — O(|only|), not O(total resident rows); default writes every live
        resident row. Buffers already released are skipped: their rows may
        have been recycled and no host value is owed.
        """
        if only is not None:
            for buf in only:
                addr = self._addr.get(id(buf))
                if addr is None:
                    continue
                cid, row = addr
                self._write_back(buf, slabs[cid], row, self._classes[cid])
            return
        for cid, cls in enumerate(self._classes):
            slab = slabs[cid]
            for row, buf in enumerate(self._rows[cid]):
                if buf is None:
                    continue
                self._write_back(buf, slab, row, cls)

    def _write_back(self, buf: Buffer, slab: Any, row: int,
                    cls: ShapeClass) -> None:
        val = slab[row]
        if tuple(buf.shape) != cls.padded_shape:
            val = val[tuple(slice(0, s) for s in buf.shape)]
        buf.value = val
        self.unpack_rows_written += 1
