"""``@acs_kernel`` — the ACS_wrapper analogue (paper Fig 16/17).

The paper wraps every CUDA kernel in an ``ACE_wrapper`` struct holding a
``get_addresses`` callback that, given the launch arguments, populates
``__read_segments__`` / ``__write_segments__`` just before launch. Here the
wrapper is a decorator producing an :class:`AcsKernel`; launching it onto a
:class:`TaskStream` resolves the segments (default: full operand ranges,
exactly Fig 17's matmul example) and enqueues a :class:`Task`.

If segment ranges cannot be determined (the paper's indirect-access case),
``conservative=True`` marks the task as touching the *entire* address
space, serializing it against everything — the paper's stated fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .buffers import Buffer, BufferView
from .segments import Segment, SegmentSet
from .task import Operand, Task, default_segments, operand_dtype, operand_shape

__all__ = ["acs_kernel", "AcsKernel", "TaskStream", "KERNEL_REGISTRY"]

KERNEL_REGISTRY: Dict[str, "AcsKernel"] = {}

# A segment covering the whole virtual address space (conservative fallback).
_WHOLE_SPACE = Segment(0, 2**62)


GetAddresses = Callable[..., Tuple[List[Segment], List[Segment]]]


_kernel_uid_counter = 0


@dataclasses.dataclass
class AcsKernel:
    """A kernel definition: pure fn + address resolver + cost model."""

    name: str
    fn: Callable[..., Any]
    get_addresses: Optional[GetAddresses] = None
    flops: Optional[Callable[..., float]] = None
    conservative: bool = False
    uid: int = -1

    def __post_init__(self) -> None:
        global _kernel_uid_counter
        if self.uid < 0:
            self.uid = _kernel_uid_counter
            _kernel_uid_counter += 1

    def launch(
        self,
        stream: "TaskStream",
        inputs: Sequence[Operand],
        outputs: Sequence[Operand],
        static_args: Tuple[Any, ...] = (),
    ) -> Task:
        """Resolve segments ("just before kernel launch", §IV-A) and enqueue."""
        inputs = tuple(inputs)
        outputs = tuple(outputs)
        if self.conservative:
            reads = SegmentSet([_WHOLE_SPACE])
            writes = SegmentSet([_WHOLE_SPACE])
        elif self.get_addresses is not None:
            r, w = self.get_addresses(inputs, outputs, *static_args)
            reads, writes = SegmentSet(list(r)), SegmentSet(list(w))
        else:
            reads, writes = default_segments(inputs, outputs)

        flops = float(self.flops(inputs, outputs, *static_args)) if self.flops else _default_flops(inputs, outputs)
        bytes_moved = sum(x.segment.size for x in inputs) + sum(x.segment.size for x in outputs)

        fn = self.fn
        if static_args:
            base = self.fn
            fn = lambda *vals, _b=base, _s=static_args: _b(*vals, *_s)

        task = Task(
            opcode=self.name,
            fn=fn,
            inputs=inputs,
            outputs=outputs,
            read_segments=reads,
            write_segments=writes,
            cost_flops=flops,
            cost_bytes=float(bytes_moved),
            static_args=tuple(static_args),
            kernel_uid=self.uid,
        )
        stream.push(task)
        return task


def _default_flops(inputs: Sequence[Operand], outputs: Sequence[Operand]) -> float:
    # Elementwise default: one flop per output element.
    total = 0.0
    for o in outputs:
        total += float(np.prod(operand_shape(o), dtype=np.float64))
    return total


def acs_kernel(
    name: Optional[str] = None,
    get_addresses: Optional[GetAddresses] = None,
    flops: Optional[Callable[..., float]] = None,
    conservative: bool = False,
) -> Callable[[Callable], AcsKernel]:
    """Decorator: ``@acs_kernel()`` turns a pure jnp function into an
    :class:`AcsKernel` registered under its name."""

    def deco(fn: Callable) -> AcsKernel:
        kname = name or fn.__name__
        kern = AcsKernel(
            name=kname,
            fn=fn,
            get_addresses=get_addresses,
            flops=flops,
            conservative=conservative,
        )
        KERNEL_REGISTRY[kname] = kern
        return kern

    return deco


class TaskStream:
    """The application-visible launch stream (single in-order queue).

    The paper's applications launch kernels into one stream; ACS re-extracts
    the parallelism downstream. ``TaskStream`` records launches in program
    order — batch schedulers consume the recorded list.

    A stream may also be **live**: constructed with a ``sink`` (a
    :class:`~.session.SchedulerSession`, or any callable / object with
    ``submit``), every ``push`` — i.e. every ``AcsKernel.launch`` — feeds
    the consumer immediately, which is exactly the paper's §III-D picture
    of the input FIFO being refilled while kernels execute. ``tag`` stamps
    each pushed task's ``stream_tag`` (per-request / per-tenant accounting
    in the serving runtime).

    ``record=False`` stops the stream from retaining pushed tasks in
    ``self.tasks`` — required for a *long-lived* live stream (a server's
    persistent decode stream would otherwise hold every Task it ever
    pushed, with its buffer references and closures, for the process
    lifetime). The sink is then the only consumer.

    ``priority`` stamps each pushed task's QoS class (lower = more
    urgent; DESIGN §13). Like ``tag`` it is pure metadata: it buckets the
    window's READY index but never enters the task signature.
    """

    def __init__(self, sink: Optional[Any] = None, tag: Optional[str] = None,
                 record: bool = True, priority: Optional[int] = None) -> None:
        self.tasks: List[Task] = []
        self.tag = tag
        self.priority = priority
        self._record = record
        self._subscribers: List[Callable[[Task], Any]] = []
        if sink is not None:
            self.subscribe(sink)

    def subscribe(self, sink: Any) -> None:
        """Attach a live consumer: each subsequent ``push`` is forwarded to
        ``sink.submit(task)`` (sessions) or ``sink(task)`` (callables)."""
        fn = getattr(sink, "submit", sink)
        if not callable(fn):
            raise TypeError(f"stream sink {sink!r} is neither callable nor has .submit")
        self._subscribers.append(fn)

    def push(self, task: Task) -> None:
        if self.tag is not None:
            task.stream_tag = self.tag
        if self.priority is not None:
            task.priority = self.priority
        if self._record:
            self.tasks.append(task)
        for fn in self._subscribers:
            fn(task)

    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)
