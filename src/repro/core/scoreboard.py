"""Interval scoreboard — the window's incremental dependency authority.

The seed window reproduced Algorithm 1 literally: every insertion checked
the incoming kernel's read/write segments against *every* resident's
segments (``segments.window_upstreams``, a stacked O(window x segments^2)
interval pass). The paper budgets 0.41-1.64us per check (Table II) and
picks N=32 largely because that scan grows linearly with the window — the
check cost caps how much concurrency the scheduler can even *see*.

Out-of-order CPUs solved the same problem decades ago by replacing
all-pairs comparison with renaming/scoreboard structures keyed on the
*resource*, not the instruction pair; Atos tracks dynamic dependencies
through shared frontier state, and Jangda et al. key fine-grained kernel
waits on producer tiles rather than scanning consumers. This module makes
the same move for address intervals:

* the scoreboard maintains, per virtual-address interval, the set of
  resident **writer** tids and the set of resident **reader** tids, in a
  sorted half-open boundary structure (an interval map: each boundary
  starts a cell that extends to the next boundary);
* **inserting** a task probes only the cells its own segments touch and
  returns the exact RAW/WAR/WAW upstream set — O(segments x log
  boundaries + cells touched), independent of window size;
* **retiring** a task removes only its own interval claims (recorded at
  insert), coalescing cells that became identical so the structure stays
  O(live claims) for arbitrarily long sessions.

Exactness note — writer *sets*, not a single last-writer: a classic
renaming scoreboard keeps only the last writer per resource, which is
enough for *schedule* correctness (a WAW chain serializes transitively).
The refactor gate here is stronger — bit-identical upstream sets against
the pairwise oracle (``window_upstreams``) — and under WAW an address
interval legitimately has several resident writers (A wrote, B wrote
after; both still resident), all of which the pairwise scan reports. So
each cell carries the full writer set and probe unions match the oracle
exactly (property-tested in ``tests/test_scoreboard.py``).

The boundary structure is a two-level (blocked) sorted list: positions
live in blocks of ~``_BLOCK`` entries, so a split/merge memmoves one
small block (C-speed) instead of one flat window-sized list — the flat
``list.insert`` variant measurably degrades to O(window) per insertion.

Segments are registered **coalesced** (``SegmentSet.coalesced()``):
adjacent/overlapping intervals — e.g. a task reading many contiguous row
views of one buffer — merge into one claim, cutting probe counts and
boundary churn without changing the claimed address set.
"""

from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .segments import SegmentSet

__all__ = ["IntervalScoreboard", "dependency_arrays"]

_BLOCK = 256  # target block width; blocks split at 2x, merge below 1/8x


class _Cell:
    """Claims over one half-open interval [boundary, next boundary)."""

    __slots__ = ("readers", "writers")

    def __init__(self, readers=(), writers=()):
        self.readers: Set[int] = set(readers)
        self.writers: Set[int] = set(writers)

    def empty(self) -> bool:
        return not self.readers and not self.writers

    def same(self, other: "_Cell") -> bool:
        return self.readers == other.readers and self.writers == other.writers


class _BoundMap:
    """Blocked sorted map: boundary position -> cell covering the interval
    from that boundary to the next. Two-level so mutation memmoves stay
    block-sized; lookups are a bisect over block minima + one in-block."""

    __slots__ = ("pos", "cells", "mins", "n")

    def __init__(self):
        self.pos: List[List[int]] = [[]]
        self.cells: List[List[_Cell]] = [[]]
        self.mins: List[int] = []  # first position per non-empty block
        self.n = 0

    def __len__(self) -> int:
        return self.n

    # -- cursors -----------------------------------------------------------
    def locate(self, p: int) -> Tuple[int, int]:
        """(block, index) of the rightmost boundary <= p; (0, -1) if none."""
        if not self.n:
            return 0, -1
        bi = bisect.bisect_right(self.mins, p) - 1
        if bi < 0:
            return 0, -1
        ii = bisect.bisect_right(self.pos[bi], p) - 1
        return bi, ii

    def nxt(self, bi: int, ii: int) -> Optional[Tuple[int, int]]:
        ii += 1
        pos = self.pos
        while bi < len(pos) and ii >= len(pos[bi]):
            bi += 1
            ii = 0
        return (bi, ii) if bi < len(pos) else None

    def first(self) -> Optional[Tuple[int, int]]:
        return self.nxt(0, -1) if self.n else None

    # -- mutation ----------------------------------------------------------
    def _insert_at(self, bi: int, ii: int, p: int, cell: _Cell) -> None:
        ps, cs = self.pos[bi], self.cells[bi]
        ps.insert(ii, p)
        cs.insert(ii, cell)
        self.n += 1
        if self.n == 1:
            self.mins.append(p)
        elif ii == 0:
            self.mins[bi] = p
        if len(ps) > 2 * _BLOCK:
            half = len(ps) // 2
            self.pos.insert(bi + 1, ps[half:])
            del ps[half:]
            self.cells.insert(bi + 1, cs[half:])
            del cs[half:]
            self.mins.insert(bi + 1, self.pos[bi + 1][0])

    def ensure(self, p: int) -> None:
        """Ensure a boundary at ``p``. A fresh boundary splits its covering
        cell (the new cell inherits copies of the claims); a boundary ahead
        of every existing one starts an unclaimed cell."""
        bi, ii = self.locate(p)
        if ii >= 0 and self.pos[bi][ii] == p:
            return
        if ii < 0:
            self._insert_at(0, 0, p, _Cell())
        else:
            c = self.cells[bi][ii]
            self._insert_at(bi, ii + 1, p, _Cell(c.readers, c.writers))

    def delete(self, bi: int, ii: int) -> None:
        ps, cs = self.pos[bi], self.cells[bi]
        del ps[ii]
        del cs[ii]
        self.n -= 1
        if not ps:
            if len(self.pos) > 1:
                del self.pos[bi]
                del self.cells[bi]
                del self.mins[bi]
            else:
                self.mins.clear()
            return
        self.mins[bi] = ps[0]
        # Fold a dwindled block into its successor so deletions cannot
        # fragment the structure into thousands of near-empty blocks.
        if len(ps) < _BLOCK // 8 and bi + 1 < len(self.pos) \
                and len(ps) + len(self.pos[bi + 1]) <= 2 * _BLOCK:
            self.pos[bi + 1][:0] = ps
            self.cells[bi + 1][:0] = cs
            self.mins[bi + 1] = self.pos[bi + 1][0]
            del self.pos[bi]
            del self.cells[bi]
            del self.mins[bi]

    def prev_cell(self, bi: int, ii: int) -> Optional[_Cell]:
        if ii > 0:
            return self.cells[bi][ii - 1]
        if bi > 0:
            return self.cells[bi - 1][-1]
        return None


class IntervalScoreboard:
    """Per-interval last-writers/active-readers tracking (module docstring).

    ``insert(tid, reads, writes)`` probes the claims its segments touch and
    returns the exact RAW/WAR/WAW upstream tid set, then registers the
    task's own claims; ``retire(tid)`` removes exactly those claims. The
    address universe is the virtual space of ``core.buffers`` — any int
    half-open intervals work.
    """

    __slots__ = ("_map", "_claims", "probe_cells", "inserted", "retired")

    def __init__(self) -> None:
        self._map = _BoundMap()
        # tid -> (read pairs, write pairs) as registered (coalesced).
        self._claims: Dict[int, Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]] = {}
        self.probe_cells = 0  # cells inspected by probes (the Table II unit)
        self.inserted = 0
        self.retired = 0

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._claims)

    def __contains__(self, tid: int) -> bool:
        return tid in self._claims

    @property
    def boundaries(self) -> int:
        """Live boundary count — O(live claims), the structure-size bound
        long sessions rely on (retire coalesces its own claims away)."""
        return len(self._map)

    # -- probe / insert ----------------------------------------------------
    def _pairs(self, segs: SegmentSet) -> List[Tuple[int, int]]:
        return [(int(s), int(e))
                for s, e in zip(segs.starts, segs.ends) if s < e]

    def probe(self, reads: SegmentSet, writes: SegmentSet) -> Set[int]:
        """Exact upstream set for a task with these segments, without
        registering any claims: RAW (reads vs writers) | WAR (writes vs
        readers) | WAW (writes vs writers)."""
        return self._probe(self._pairs(reads.coalesced()),
                           self._pairs(writes.coalesced()))

    def probe_writers(self, reads: SegmentSet) -> Set[int]:
        """RAW-only probe: active tasks whose WRITE claims overlap the given
        read segments, without registering anything. The mesh admission
        plane uses this to find the true data-flow upstreams of an incoming
        task (the producers whose outputs it consumes) — the placement
        signal — separately from the full RAW/WAR/WAW hazard set that
        decides sub-epoch barriers."""
        return self._probe(self._pairs(reads.coalesced()), [])

    def _probe(self, reads, writes) -> Set[int]:
        m = self._map
        up: Set[int] = set()
        if not m.n:
            return up
        probes = 0
        for pairs, include_readers in ((writes, True), (reads, False)):
            for ss, ee in pairs:
                bi, ii = m.locate(ss)
                if ii >= 0:
                    # the cell containing ss overlaps iff it extends past ss
                    cur = m.nxt(bi, ii)
                    if cur is None or m.pos[cur[0]][cur[1]] > ss:
                        c = m.cells[bi][ii]
                        probes += 1
                        up |= c.writers
                        if include_readers:
                            up |= c.readers
                else:
                    cur = m.first()
                # every further cell starts inside (ss, ee): all overlap
                while cur is not None:
                    b, i = cur
                    if m.pos[b][i] >= ee:
                        break
                    c = m.cells[b][i]
                    probes += 1
                    up |= c.writers
                    if include_readers:
                        up |= c.readers
                    cur = m.nxt(b, i)
        self.probe_cells += probes
        return up

    def insert(self, tid: int, reads: SegmentSet, writes: SegmentSet) -> Set[int]:
        """Probe + claim: returns the exact upstream tid set among active
        (inserted, not yet retired) tasks, then registers ``tid``'s own
        read/write interval claims (coalesced)."""
        if tid in self._claims:
            raise ValueError(f"task {tid} is already on the scoreboard")
        rp = self._pairs(reads.coalesced())
        wp = self._pairs(writes.coalesced())
        upstream = self._probe(rp, wp)
        m = self._map
        for pairs, attr in ((rp, "readers"), (wp, "writers")):
            for ss, ee in pairs:
                m.ensure(ss)
                m.ensure(ee)
                cur = m.locate(ss)  # exact boundary at ss
                while cur is not None:
                    b, i = cur
                    if m.pos[b][i] >= ee:
                        break
                    getattr(m.cells[b][i], attr).add(tid)
                    cur = m.nxt(b, i)
        self._claims[tid] = (rp, wp)
        self.inserted += 1
        return upstream

    # -- retire ------------------------------------------------------------
    def retire(self, tid: int) -> None:
        """Remove exactly ``tid``'s interval claims and coalesce cells that
        became indistinguishable from their neighbour."""
        claims = self._claims.pop(tid, None)
        if claims is None:
            raise KeyError(f"task {tid} is not on the scoreboard")
        rp, wp = claims
        m = self._map
        for pairs, attr in ((rp, "readers"), (wp, "writers")):
            for ss, ee in pairs:
                cur = m.locate(ss)
                while cur is not None:
                    b, i = cur
                    if m.pos[b][i] >= ee:
                        break
                    getattr(m.cells[b][i], attr).discard(tid)
                    cur = m.nxt(b, i)
        for ss, ee in rp + wp:
            self._coalesce(ss, ee)
        self.retired += 1

    def _coalesce(self, ss: int, ee: int) -> None:
        """Drop boundaries in [ss, ee] whose cell equals its predecessor
        (or is an unclaimed leading cell). Positions, not cursors: each
        candidate is re-located so deletions cannot invalidate the walk."""
        m = self._map
        candidates: List[int] = []
        cur = m.locate(ss)
        if cur[1] < 0:
            cur = m.first()
        while cur is not None:
            p = m.pos[cur[0]][cur[1]]
            if p > ee:
                break
            if p >= ss:
                candidates.append(p)
            cur = m.nxt(*cur)
        for p in candidates:
            bi, ii = m.locate(p)
            if ii < 0 or m.pos[bi][ii] != p:
                continue  # already merged away
            cell = m.cells[bi][ii]
            prev = m.prev_cell(bi, ii)
            if prev is None:
                if cell.empty():
                    m.delete(bi, ii)
            elif cell.same(prev):
                m.delete(bi, ii)


def dependency_arrays(tasks: Sequence) -> Tuple[np.ndarray, np.ndarray]:
    """Exact intra-batch dependency structure as dense device operands.

    Inserting ``tasks`` in the given (program) order into a fresh
    scoreboard yields, for each task, the exact RAW/WAR/WAW upstream set
    among its predecessors in the batch — the same edges the live window
    tracks, restricted to this batch. Returned in the layout the
    ready-queue lowering consumes (DESIGN §2 A3):

    * ``indeg`` — ``[n] int32``, the per-task remaining-dependency counter
      initial values (number of in-batch upstreams);
    * ``dep_tbl`` — ``[n, max_out] int32`` forward edges: row *i* lists
      the batch positions that depend on task *i*, padded with the
      sentinel ``n`` (``max_out`` >= 1 so the table is never 0-wide).

    Positions index into ``tasks``; retiring position *i* on device
    decrements ``remaining[dep_tbl[i]]`` (the sentinel lands in a trash
    slot) and zero-crossings join the ready ring.
    """
    n = len(tasks)
    board = IntervalScoreboard()
    pos = {t.tid: i for i, t in enumerate(tasks)}
    out_edges: List[List[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, np.int32)
    for i, t in enumerate(tasks):
        ups = board.insert(t.tid, t.read_segments, t.write_segments)
        indeg[i] = len(ups)
        for up in ups:
            out_edges[pos[up]].append(i)
    max_out = max((len(e) for e in out_edges), default=0)
    dep_tbl = np.full((n, max(max_out, 1)), n, np.int32)
    for i, edges in enumerate(out_edges):
        for j, d in enumerate(sorted(edges)):
            dep_tbl[i, j] = d
    return indeg, dep_tbl
