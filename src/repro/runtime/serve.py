"""Serving through the ACS window: a live session server + batch baseline.

Each request owns a KV-cache slot and emits kernels exactly like the
paper's applications:

* ``prefill(slot)``  — one task per newly admitted request; reads the
  token buffer, writes that slot's cache buffer.
* ``decode(slots)``  — one task over the currently decodable slot set;
  reads and writes those slots' caches.

Because slots are disjoint buffers, the ACS window discovers that a new
request's prefill is independent of the in-flight decode and co-schedules
them — continuous batching *emerges from dependency scheduling* rather
than being hand-coded. A slot's prefill -> decode -> decode chain stays
serialized by its RAW hazards on the slot buffer.

Two servers share the slot/admission machinery (:class:`_ServingCore`):

* :class:`SessionServer` — the open-loop runtime (DESIGN.md §10). It owns
  a persistent :class:`~..core.session.SchedulerSession`; admission emits
  a request's *whole program* (prefill + its count-bounded per-slot decode
  chain) through a live per-request ``TaskStream`` (``sink=`` the session,
  ``tag=req{rid}``) *into the live window while other requests' chains are
  still in flight*; per-task retirement callbacks harvest tokens and free
  prompt buffers without ever draining the world.
* :class:`ContinuousBatchingServer` — the per-step batch-drain baseline
  (``step()`` rebuilds a stream and blocks the host each iteration). Kept
  for its API stability and as the latency baseline ``bench_serving.py``
  measures the session server against.

Both apply multi-tenant QoS admission (DESIGN.md §13): requests carry a
priority class (lower = more urgent) and an optional relative deadline;
tenants may have hard slot quotas and weighted shares. ``_pick_next``
orders the queue by (aged effective priority, weighted tenant load,
deadline, arrival) — with the defaults (one priority class, unit weights,
no quotas/deadlines) this reduces exactly to the original fairness rule
(fewest active slots, oldest-first tie-break). Aging promotes a waiting
request one bucket per ``aging_s`` seconds, so a low-priority tenant's
wait behind a flood is bounded by ``priority * aging_s`` plus one
admission cycle. Backpressure is unchanged (bounded admission FIFO;
``submit`` raises :class:`AdmissionQueueFull` at capacity and stamps the
observed queue depth on the request), and both servers free each
request's prompt buffer once its prefill has retired — a long-running
server cannot leak one ``req{rid}_prompt`` allocation per request.

:class:`SessionServer` can additionally preempt long decode chains
cooperatively (``preempt_rounds``): chains are emitted in bounded
segments, and at each segment boundary — an epoch boundary under the
device/mesh schedulers — a chain yields its slot to a strictly more
urgent queued request, parking its opaque ``(cache, token, pos)`` slot
state and resuming later from exactly where it left off (the stale-slot
reset machinery makes the handoff safe; no recompute).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Deque, Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from ..core import BufferPool, TaskStream, WaveScheduler
from ..core.executors import SerialExecutor
from ..core.wrapper import AcsKernel
from ..models import decode_step, init_cache, prefill
from ..models.config import ArchConfig

__all__ = ["Request", "AdmissionQueueFull", "DrainTimeout",
           "ContinuousBatchingServer", "SessionServer",
           "PRIORITY_HIGH", "PRIORITY_NORMAL", "PRIORITY_LOW"]

_rid = itertools.count()

# QoS priority classes (lower = more urgent). Any non-negative int is a
# valid class; these three are the conventional named tiers.
PRIORITY_HIGH = 0
PRIORITY_NORMAL = 1
PRIORITY_LOW = 2


class AdmissionQueueFull(RuntimeError):
    """submit() refused: the bounded admission FIFO is at capacity — the
    server's backpressure signal to producers."""


class DrainTimeout(RuntimeError):
    """``run_until_drained`` exhausted ``max_iters`` with work still
    queued or active. Carries the stuck state so operators see *what*
    stalled instead of a silently truncated result list."""

    def __init__(self, message: str, *, queue_depth: int, active_slots: int,
                 finished: Optional[List["Request"]] = None):
        super().__init__(message)
        self.queue_depth = queue_depth
        self.active_slots = active_slots
        # requests that DID finish before the stall — not lost with the raise
        self.finished = finished or []


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # [S] int32
    max_new: int = 8
    tenant: str = "default"
    priority: int = PRIORITY_NORMAL     # QoS class, lower = more urgent
    deadline: Optional[float] = None    # SLO: seconds after arrival, or None
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_arrival: float = 0.0              # perf_counter at submit
    t_admit: float = 0.0                # perf_counter when a slot was granted
    t_finish: float = 0.0               # perf_counter when the last token retired
    queue_depth: int = 0                # admission FIFO depth observed at submit
    preemptions: int = 0                # times this request's chain was parked
    rounds_left: int = 0                # decode rounds not yet emitted/retired
    parked_state: Optional[tuple] = None  # opaque (cache, tok, pos) while parked

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def finished(self) -> bool:
        """True once the request's last token has retired (``t_finish``
        is stamped exactly once, at finish)."""
        return self.t_finish > 0.0

    @property
    def latency(self) -> Optional[float]:
        """End-to-end request latency, or None until finished. (It used
        to return ``-t_arrival`` — a large negative number — when read
        before finish, silently poisoning percentile aggregations.)"""
        if not self.finished:
            return None
        return self.t_finish - self.t_arrival


class _ServingCore:
    """Slots, kernels, and QoS bounded admission — shared by both servers.

    QoS knobs (all default to the pre-QoS behavior):

    * ``tenant_weights`` — weighted shares: a tenant's load for admission
      purposes is ``active_slots / weight``, so weight 2.0 holds twice
      the slots of weight 1.0 at equal queue pressure.
    * ``tenant_quota`` — hard cap on a tenant's concurrently active
      slots; an int applies to every tenant, a dict caps only the listed
      tenants. Quota'd-out requests stay queued (never dropped).
    * ``aging_s`` — starvation bound: a queued request's *effective*
      priority improves one bucket per ``aging_s`` seconds waited
      (clamped at ``PRIORITY_HIGH``), so any request reaches the top
      bucket within ``priority * aging_s`` seconds. ``None`` disables.
    """

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, max_queue: int = 256,
                 history_limit: Optional[int] = 1024,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 tenant_quota: Optional[Union[int, Dict[str, int]]] = None,
                 aging_s: Optional[float] = 5.0):
        assert cfg.frontend is None, "serving driver uses token models"
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_queue = max_queue
        self.history_limit = history_limit
        self.tenant_weights = dict(tenant_weights or {})
        for t, w in self.tenant_weights.items():
            if not w > 0:
                raise ValueError(f"tenant weight must be > 0: {t!r} -> {w}")
        self.tenant_quota = tenant_quota
        if aging_s is not None and not aging_s > 0:
            raise ValueError(f"aging_s must be > 0 or None, got {aging_s}")
        self.aging_s = aging_s
        self.preemptions = 0  # chains parked at a segment boundary (server-wide)
        self.pool = BufferPool()
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}
        # Incremental per-tenant active-slot counts, maintained at
        # _grant_slot / _release_slot — _pick_next used to rebuild this
        # dict from self.active on EVERY admission (O(active x queue)
        # per grant).
        self._tenant_active: Dict[str, int] = {}
        # Rolling report trace: a long-lived server's host memory must be
        # flat, so monitoring state rotates instead of accumulating
        # (asserted by benchmarks/bench_soak.py).
        self.report_log: Deque[Dict] = collections.deque(maxlen=history_limit)

        # one opaque buffer per slot: value = (cache pytree, last_token, pos)
        self.slots = []
        for i in range(max_slots):
            cache = init_cache(cfg, 1, max_len)
            buf = self.pool.alloc((1,), np.float32, name=f"slot{i}",
                                  value=(cache, None, 0))
            self.slots.append(buf)
        self.free = list(range(max_slots))

        cfg_ = cfg

        def _prefill_fn(slot_val, tokens):
            cache, _, _ = slot_val
            logits, cache = prefill(self.params, cfg_, tokens, cache)
            tok = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
            # list-of-one: each element maps to one output buffer
            return [(cache, tok, jnp.asarray(tokens.shape[1], jnp.int32))]

        def _decode_fn(*slot_vals):
            outs = []
            for cache, tok, pos in slot_vals:
                pos = jnp.asarray(pos, jnp.int32)
                logits, cache = decode_step(
                    self.params, cfg_, tok[:, None], cache, pos,
                )
                nxt = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
                outs.append((cache, nxt, pos + 1))
            return outs

        self._prefill_kernel = AcsKernel(name="req_prefill", fn=_prefill_fn)
        self._decode_kernel = AcsKernel(name="req_decode", fn=_decode_fn)

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 8,
               tenant: str = "default", priority: int = PRIORITY_NORMAL,
               deadline: Optional[float] = None) -> Request:
        """Enqueue a request. Raises :class:`AdmissionQueueFull` when the
        bounded FIFO is at capacity and :class:`ValueError` for requests
        that can never be served (over-long prompt, negative ``max_new``,
        negative ``priority``, non-positive ``deadline``); otherwise
        stamps the observed queue depth on the request (the
        producer-visible backpressure signal). ``max_new=0`` is valid and
        means zero decode rounds: the request finishes with no generated
        tokens once its prefill retires. ``priority`` is the QoS class
        (lower = more urgent, default :data:`PRIORITY_NORMAL`);
        ``deadline`` is a relative SLO in seconds — once half the budget
        is gone the request is promoted to the top bucket."""
        if len(self.queue) >= self.max_queue:
            raise AdmissionQueueFull(
                f"admission queue at capacity ({self.max_queue}); retry later")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds the cache capacity "
                f"(max_len - 1 = {self.max_len - 1}); truncate the prompt "
                "or raise max_len")
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        if priority < 0:
            raise ValueError(f"priority must be >= 0, got {priority}")
        if deadline is not None and not deadline > 0:
            raise ValueError(f"deadline must be > 0 seconds, got {deadline}")
        req = Request(prompt=prompt, max_new=max_new, tenant=tenant,
                      priority=priority, deadline=deadline)
        req.t_arrival = time.perf_counter()
        self.queue.append(req)
        req.queue_depth = len(self.queue)
        return req

    def queue_depth(self) -> int:
        return len(self.queue)

    # -- admission ----------------------------------------------------------
    def _quota_of(self, tenant: str) -> Optional[int]:
        if self.tenant_quota is None:
            return None
        if isinstance(self.tenant_quota, dict):
            return self.tenant_quota.get(tenant)
        return self.tenant_quota

    def _weight_of(self, tenant: str) -> float:
        return self.tenant_weights.get(tenant, 1.0)

    def effective_priority(self, req: Request,
                           now: Optional[float] = None) -> int:
        """The request's priority bucket *as scheduled*: the submitted
        class, improved one bucket per ``aging_s`` seconds waited
        (starvation bound), promoted to the top bucket once half its
        deadline budget is spent, clamped at :data:`PRIORITY_HIGH` —
        an aged request ties the top class but never outranks it."""
        if now is None:
            now = time.perf_counter()
        bucket = req.priority
        if self.aging_s is not None:
            bucket -= int((now - req.t_arrival) / self.aging_s)
        if req.deadline is not None:
            slack = (req.t_arrival + req.deadline) - now
            if slack <= 0.5 * req.deadline:
                bucket = PRIORITY_HIGH
        return max(bucket, PRIORITY_HIGH)

    def _admission_key(self, req: Request, now: float):
        """Total admission order: most urgent effective bucket, then
        least weighted tenant load, then earliest absolute deadline,
        then arrival order (rid is monotone in submit order and survives
        preemption re-queues, so a parked request keeps its age)."""
        deadline_at = (req.t_arrival + req.deadline
                       if req.deadline is not None else float("inf"))
        load = self._tenant_active.get(req.tenant, 0) / self._weight_of(req.tenant)
        return (self.effective_priority(req, now), load, deadline_at, req.rid)

    def _pick_next(self) -> Optional[Request]:
        """QoS admission: pop the queued request minimizing
        :meth:`_admission_key`, skipping tenants at their quota. Returns
        None when every queued request is quota-blocked (callers stop
        admitting; the requests stay queued). With the default knobs —
        one priority class, unit weights, no quotas/deadlines — the key
        degenerates to (tenant active count, arrival), i.e. exactly the
        original fewest-active-slots / oldest-first scan, but against
        incremental counts: O(queue) per grant instead of
        O(active x queue).

        Under cooperative preemption (``preempt_rounds`` set) admission
        additionally holds back requests strictly less urgent than the
        most urgent ACTIVE class: a chain that just yielded at a segment
        boundary must not be re-admitted into the slot it freed while
        the urgent work it yielded to is still running (priority
        isolation — aging re-levels parked chains, so the hold-back is
        starvation-bounded like every other ordering here)."""
        now = time.perf_counter()
        floor = None
        if getattr(self, "preempt_rounds", None) is not None and self.active:
            floor = min(self.effective_priority(r, now)
                        for r in self.active.values())
        best_i: Optional[int] = None
        best_key = None
        for i, r in enumerate(self.queue):
            quota = self._quota_of(r.tenant)
            if quota is not None and self._tenant_active.get(r.tenant, 0) >= quota:
                continue
            if floor is not None and self.effective_priority(r, now) > floor:
                continue
            key = self._admission_key(r, now)
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        if best_i is None:
            return None
        if best_i == 0:
            return self.queue.popleft()
        req = self.queue[best_i]
        del self.queue[best_i]
        return req

    def _grant_slot(self, req: Request):
        """Bind the request to a free slot; returns its prompt buffer
        (freed again when the prefill retires), or None when resuming a
        preempted chain — the parked ``(cache, tok, pos)`` is restored
        verbatim and no prefill is needed. For fresh admissions the slot
        value resets to ``(cache, None, 0)`` so the previous occupant's
        leftover token/pos can never be mistaken for this request's state
        (a stale token made the batch server schedule a decode before the
        new prefill retired)."""
        req.slot = self.free.pop(0)
        if req.t_admit == 0.0:  # first grant only: resume keeps the original
            req.t_admit = time.perf_counter()
        self.active[req.slot] = req
        self._tenant_active[req.tenant] = \
            self._tenant_active.get(req.tenant, 0) + 1
        if req.parked_state is not None:
            self.slots[req.slot].value = req.parked_state
            req.parked_state = None
            return None
        cache = self.slots[req.slot].value[0]
        self.slots[req.slot].value = (cache, None, 0)
        tok_buf = self.pool.alloc(
            (1, len(req.prompt)), np.int32, name=f"req{req.rid}_prompt",
            value=jnp.asarray(req.prompt[None]),
        )
        return tok_buf

    def _release_slot(self, s: int) -> Request:
        """Unbind slot ``s``: drop it from the active set, decrement the
        tenant's incremental count, return the slot to the free list.
        Every slot-freeing path (finish, harvest, zero-round finish,
        preemption park) funnels through here so the counts _pick_next
        reads can never drift from ``self.active``."""
        req = self.active.pop(s)
        n = self._tenant_active.get(req.tenant, 0) - 1
        if n > 0:
            self._tenant_active[req.tenant] = n
        else:
            self._tenant_active.pop(req.tenant, None)
        self.free.append(s)
        return req

    def _harvest_slot(self, s: int) -> Optional[Request]:
        """Read the slot's freshly decoded token; return the request if it
        finished (slot freed), else None."""
        req = self.active[s]
        _, tok, pos = self.slots[s].value
        req.generated.append(int(np.asarray(tok)[0]))
        if req.done or int(pos) >= self.max_len - 1:
            req.t_finish = time.perf_counter()
            self._release_slot(s)
            return req
        return None


class ContinuousBatchingServer(_ServingCore):
    """Per-step batch-drain serving (the seed design, and the baseline the
    session server is benchmarked against): every iteration rebuilds a
    ``TaskStream``, runs it to empty through a closed-batch scheduler, and
    blocks the host — iteration *i*'s decode can never overlap iteration
    *i+1*'s prefill."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, window: int = 32, max_queue: int = 256,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 tenant_quota: Optional[Union[int, Dict[str, int]]] = None,
                 aging_s: Optional[float] = 5.0):
        super().__init__(cfg, params, max_slots=max_slots, max_len=max_len,
                         max_queue=max_queue, tenant_weights=tenant_weights,
                         tenant_quota=tenant_quota, aging_s=aging_s)
        # slot values are opaque pytrees (cache trees): the fused vmap
        # batcher needs array operands, so waves execute via the serial
        # executor — the window still builds multi-task waves, which is
        # the dependency-schedule evidence the benchmarks read.
        self.scheduler = WaveScheduler(window_size=window,
                                       executor=SerialExecutor())

    def step(self) -> List[Request]:
        """One server iteration: admit + prefill new requests, decode the
        active set — all through the ACS window. Returns finished requests."""
        stream = TaskStream()

        # admit as many queued requests as there are free slots (stop
        # early if everything still queued is quota-blocked)
        prompt_bufs: List[str] = []
        while self.queue and self.free:
            req = self._pick_next()
            if req is None:
                break
            tok_buf = self._grant_slot(req)
            prompt_bufs.append(tok_buf.name)
            self._prefill_kernel.launch(
                stream, inputs=(self.slots[req.slot], tok_buf),
                outputs=(self.slots[req.slot],),
            )

        # decode wave over slots that hold a token AND can still take a
        # round (not done — max_new=0 finishes on prefill alone — and not
        # at cache capacity)
        decoding = [s for s, r in self.active.items()
                    if self.slots[s].value[1] is not None and not r.done
                    and int(self.slots[s].value[2]) < self.max_len - 1]
        if decoding:
            bufs = tuple(self.slots[s] for s in decoding)
            self._decode_kernel.launch(stream, inputs=bufs, outputs=bufs)

        if not stream.tasks:
            return []
        # executors jit/cache by signature; opaque pytree values need the
        # plain (uncompiled) path — dispatch counting still applies.
        report = self.scheduler.run(stream.tasks)
        # prefills completed inside the drain: release the prompt buffers
        for name in prompt_bufs:
            self.pool.free(name)
        entry = report.as_dict()
        entry["tasks_this_run"] = sum(len(w) for w in report.waves)
        entry["waves_this_run"] = len(report.waves)
        self.report_log.append(entry)

        finished = []
        for s in list(decoding):
            req = self._harvest_slot(s)
            if req is not None:
                finished.append(req)
        # zero-round finish: active slots whose prefill retired but which
        # can never decode (max_new=0, or the prompt fills the cache) —
        # finish with what they have instead of spinning forever
        for s in list(self.active):
            req = self.active[s]
            _, tok, pos = self.slots[s].value
            if tok is not None and (
                    req.done or int(pos) >= self.max_len - 1):
                req.t_finish = time.perf_counter()
                self._release_slot(s)
                finished.append(req)
        return finished

    def run_until_drained(self, max_iters: int = 200) -> List[Request]:
        """Step until queue and slots are empty. Raises
        :class:`DrainTimeout` (carrying the stuck queue/active counts and
        the requests that DID finish) if ``max_iters`` steps don't drain
        the server — it used to return the partial list silently."""
        out: List[Request] = []
        for _ in range(max_iters):
            out.extend(self.step())
            if not self.queue and not self.active:
                return out
        raise DrainTimeout(
            f"run_until_drained: {max_iters} steps left "
            f"{len(self.queue)} queued / {len(self.active)} active requests",
            queue_depth=len(self.queue), active_slots=len(self.active),
            finished=out)


class SessionServer(_ServingCore):
    """Open-loop serving on a persistent scheduler session (DESIGN.md §10).

    Admission emits a request's *entire* kernel program — prefill plus its
    count-bounded decode chain — into the live window while other
    requests' chains are still in flight; the window's RAW hazards
    serialize each chain on its own slot buffer and co-schedule
    independent chains. ``pump()`` is the non-blocking service iteration:
    poll the session (retirement callbacks harvest tokens, free prompt
    buffers, finish requests), then admit queued requests into freed
    slots. Admission latency is bounded by the pump cadence, not by a full
    window drain, and no mid-request host round-trip ever gates a decode
    chain.

    ``scheduler="frontier"`` (default) runs width-1 groups through the
    async frontier — slot values are opaque pytrees, which vmap cannot
    stack, so concurrency comes from overlapped in-flight groups rather
    than batching. ``scheduler="wave"`` reproduces the seed's fused-wave
    evidence (one slot's decode co-resident with another's prefill in a
    single wave) with a serial executor. ``scheduler="device"`` serves
    through the persistent :class:`~..core.device_dispatch.DeviceSession`:
    admitted chains drain in whole-window epochs (slot values are opaque
    cache pytrees, so every serving kernel takes the session's in-epoch
    host path — the evidence here is the epoch/admission structure and the
    per-epoch stats, not arena residency). ``pool.free`` is wired into the
    device session's row lifecycle: any array buffer a producer routes
    through the arena (e.g. auxiliary device-lowerable streams submitted
    alongside requests) has its row recycled when the buffer is freed.
    The device session defaults to ``plan_mode="loop"`` — the ready-queue
    epoch executor that advances each dependency frontier in one dispatch
    (DESIGN §2 A3); pass ``plan_mode="wave"``/``"frontier"`` to serve
    through the fixed-step table lowering instead.

    ``scheduler="mesh"`` serves through the mesh-sharded window
    (:class:`~..core.mesh_session.MeshDeviceSession`): the global
    admission plane places each request's chain on one shard (its slot
    buffer's RAW chain pins it there) while independent requests spread
    across shards/devices; ``n_shards`` defaults to the visible device
    count. Per-device slot accounting rides the pump: every iteration
    samples which shard owns each active slot (``shard_occupancy``), and
    the rolling ``shard_slot_samples`` trace plus the session's
    cross-shard/transfer counters land in the close report.

    **Cooperative preemption** (``preempt_rounds``, DESIGN §13): with
    the default ``None``, a request's whole decode chain is emitted at
    admission (the pre-QoS behavior). With ``preempt_rounds=k``, chains
    are emitted in segments of at most ``k`` decode rounds; at each
    segment boundary — an epoch boundary under the device/mesh
    schedulers, since a segment's tasks drain within one epoch — the
    chain either continues (next segment emitted from the retirement
    callback), finishes, or *yields its slot*: if a strictly more
    urgent admissible request is queued and no slot is free, the
    chain's opaque ``(cache, token, pos)`` state is parked on the
    Request, the slot is freed (stale-slot reset makes the handoff
    safe), and the request re-queues at its original age. Resume
    restores the parked state verbatim — no recompute, and the token
    stream is bit-identical to an unpreempted run. Each park increments
    ``Request.preemptions`` and the server-wide ``preemptions`` counter.
    """

    SCHEDULERS = ("frontier", "wave", "device", "mesh")

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, window: int = 32, max_queue: int = 256,
                 scheduler: str = "frontier", max_inflight: int = 8,
                 history_limit: Optional[int] = 1024,
                 plan_mode: str = "loop", n_shards: Optional[int] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 tenant_quota: Optional[Union[int, Dict[str, int]]] = None,
                 aging_s: Optional[float] = 5.0,
                 preempt_rounds: Optional[int] = None,
                 transfer_mode: str = "auto",
                 overlap_drains: bool = True):
        super().__init__(cfg, params, max_slots=max_slots, max_len=max_len,
                         max_queue=max_queue, history_limit=history_limit,
                         tenant_weights=tenant_weights,
                         tenant_quota=tenant_quota, aging_s=aging_s)
        if preempt_rounds is not None and preempt_rounds < 1:
            raise ValueError(
                f"preempt_rounds must be >= 1 or None, got {preempt_rounds}")
        self.preempt_rounds = preempt_rounds
        if scheduler == "frontier":
            from ..core.frontier import FrontierSession

            self.session = FrontierSession(window_size=window,
                                           max_inflight=max_inflight,
                                           max_group=1,
                                           history_limit=history_limit)
        elif scheduler == "wave":
            from ..core.session import WaveSession

            self.session = WaveSession(window_size=window,
                                       executor=SerialExecutor(),
                                       history_limit=history_limit)
        elif scheduler == "device":
            from ..core.device_dispatch import DeviceSession

            self.session = DeviceSession(window_size=window,
                                         plan_mode=plan_mode,
                                         history_limit=history_limit)
            # Row lifecycle wiring: freeing any pool buffer (per-request
            # prompts, auxiliary workload buffers) releases its arena row
            # for recycling — the device session's slabs stay bounded under
            # unbounded request streams.
            self.pool.add_free_hook(self.session.release_buffer)
        elif scheduler == "mesh":
            from ..core.mesh_session import MeshDeviceSession

            self.session = MeshDeviceSession(window_size=window,
                                             n_shards=n_shards,
                                             history_limit=history_limit,
                                             transfer_mode=transfer_mode,
                                             overlap_drains=overlap_drains)
            # Same row-lifecycle wiring as "device", fanned out to every
            # shard's arena (a freed buffer may hold rows on several).
            self.pool.add_free_hook(self.session.release_buffer)
        else:
            raise ValueError(
                f"session server scheduler must be one of {self.SCHEDULERS}, "
                f"got {scheduler!r}")
        self.scheduler_name = scheduler
        self._finished: List[Request] = []
        # set during close(): the flush retires chains (firing _finish_slot),
        # but a closing window must not receive fresh admissions
        self._closing = False
        # tid -> prefill | decode for tasks currently IN FLIGHT; entries
        # drop at retirement, so a long-lived server holds at most one
        # window's worth (schedule-kind traces for finished work live in
        # the rolling report_log, not here).
        self.task_kinds: Dict[int, str] = {}
        self.occupancy_samples: Deque[int] = collections.deque(
            maxlen=history_limit)
        # mesh only: rolling per-device slot-occupancy trace — one
        # {shard: active slot count} sample per pump plus one per request
        # retirement (bounded like every other monitoring surface —
        # soak-safe).
        self.shard_slot_samples: Deque[Dict[int, int]] = collections.deque(
            maxlen=history_limit)

    # -- retirement callbacks (fire inside session.poll/drive) --------------
    def _finish_slot(self, slot: int) -> None:
        if self.scheduler_name == "mesh":
            # sample while the finishing slot is still active: its chain
            # just executed, so shard attribution is known — the per-pump
            # sample can land when callback-admitted successors haven't
            # run yet (unattributed) or everything already drained
            self.shard_slot_samples.append(self.shard_occupancy())
        req = self._release_slot(slot)
        req.t_finish = time.perf_counter()
        self._finished.append(req)
        self._admit_ready()

    def _on_prefill_retired(self, task, buf_name: str, slot: int,
                            finish: bool) -> None:
        self.pool.free(buf_name)  # no leak
        self.task_kinds.pop(task.tid, None)
        if finish:  # zero decode rounds: the prefill IS the whole program
            self._finish_slot(slot)

    def _on_decode_retired(self, task, slot: int, boundary: bool) -> None:
        self.task_kinds.pop(task.tid, None)
        req = self.active[slot]
        _, tok, _ = self.slots[slot].value
        req.generated.append(int(np.asarray(tok)[0]))
        req.rounds_left -= 1
        if not boundary:
            return
        # Segment boundary: finish, yield the slot, or emit the next
        # segment (the continuation submits from inside the retirement
        # callback — the session RLock permits it, and the tasks land in
        # the window for the next epoch/group).
        if req.rounds_left <= 0:
            self._finish_slot(slot)
        elif self._should_yield(req):
            self._park(slot)
        else:
            self._emit_decode_segment(req)

    def _should_yield(self, req: Request) -> bool:
        """Cooperative-preemption test at a segment boundary: yield iff
        strictly more urgent work exists — RUNNING in another slot (the
        urgent class takes every host round-trip until it drains:
        priority isolation, not just a slot), or admissible in the queue
        with no free slot to serve it. Equal urgency never preempts (no
        thrash between peers, and aging re-levels a parked chain so
        isolation is starvation-bounded), and quota-blocked waiters don't
        trigger a park they couldn't use."""
        if self.preempt_rounds is None:
            return False
        now = time.perf_counter()
        mine = self.effective_priority(req, now)
        for r in self.active.values():
            if r is not req and self.effective_priority(r, now) < mine:
                return True
        if self.free or not self.queue:
            return False
        for r in self.queue:
            quota = self._quota_of(r.tenant)
            if quota is not None and self._tenant_active.get(r.tenant, 0) >= quota:
                continue
            if self.effective_priority(r, now) < mine:
                return True
        return False

    def _park(self, slot: int) -> None:
        """Preempt: capture the chain's opaque slot state (fresh — its
        segment's last decode just retired), free the slot, and re-queue
        the request at its original age (rid order; the internal
        re-queue is exempt from the admission bound — the request was
        already admitted once). Resume happens through the normal
        admission path via ``parked_state``."""
        req = self._release_slot(slot)
        req.parked_state = self.slots[slot].value
        req.slot = None
        req.preemptions += 1
        self.preemptions += 1
        self.queue.append(req)
        self._admit_ready()

    # -- service loop --------------------------------------------------------
    def _admit_ready(self) -> None:
        """Admission sweep: grant free slots to queued requests in QoS
        order. Runs between pumps AND from the slot-freeing retirement
        callbacks (finish, park). The callback path matters: the
        session's poll/drive pumps staged work to quiescence, and under
        lazy segment emission a long chain's rounds cascade entirely
        inside one drive — a slot freed mid-cascade would sit idle until
        the cascade drains, so an urgent arrival that parked a flood
        chain would still wait behind the rest of the epoch. Admitting
        from inside the callback lets the successor's program join the
        same cascade (submission from retirement callbacks is the same
        contract the decode continuations rely on)."""
        if self._closing or self.session.closed:
            return
        while self.queue and self.free:
            req = self._pick_next()
            if req is None:  # everything queued is quota-blocked/held back
                break
            self._admit(req)

    def _admit(self, req: Request) -> None:
        """Emit the request's kernel program into the live window at
        admission: the prefill plus its decode chain — whole
        (``preempt_rounds=None``: termination is count-based, so the full
        chain is known up front and no mid-request host round-trip ever
        gates it, §III-D) or in preemptible segments. The window
        serializes the chain via the slot buffer's RAW hazards and
        co-schedules it against other slots' chains (disjoint buffers);
        the per-request stream stamps each task with the request's
        effective priority bucket so urgent chains launch first among
        independent READY kernels. A resumed request (parked state
        restored by ``_grant_slot``) skips the prefill and emits only its
        remaining rounds."""
        tok_buf = self._grant_slot(req)
        s = req.slot
        if tok_buf is None:  # resuming a preempted chain
            self._emit_decode_segment(req)
            return
        stream = self._stream_for(req)
        task = self._prefill_kernel.launch(
            stream, inputs=(self.slots[s], tok_buf), outputs=(self.slots[s],))
        self.task_kinds[task.tid] = "prefill"
        # Decode rounds the cache can actually hold: zero when max_new=0 or
        # the prompt already fills it — never force a phantom round that
        # would advance pos past max_len (the old max(1, ...) clamp).
        req.rounds_left = min(req.max_new, self.max_len - 1 - len(req.prompt))
        self.session.on_task_retired(
            task, lambda t, n=tok_buf.name, s=s, fin=(req.rounds_left == 0):
            self._on_prefill_retired(t, n, s, fin))
        self._emit_decode_segment(req, stream)

    def _stream_for(self, req: Request) -> TaskStream:
        """Live per-request stream: AcsKernel.launch feeds the session's
        window directly, tagged for per-request accounting and stamped
        with the request's current effective priority bucket."""
        return TaskStream(sink=self.session, tag=f"req{req.rid}",
                          record=False,
                          priority=self.effective_priority(req))

    def _emit_decode_segment(self, req: Request,
                             stream: Optional[TaskStream] = None) -> None:
        """Emit the next run of decode rounds for the request's chain:
        everything left when ``preempt_rounds`` is None, else at most
        ``preempt_rounds`` rounds — the boundary round's retirement
        callback then decides finish / yield / continue."""
        if req.rounds_left <= 0:
            return
        s = req.slot
        if stream is None:
            stream = self._stream_for(req)
        seg = (req.rounds_left if self.preempt_rounds is None
               else min(req.rounds_left, self.preempt_rounds))
        bufs = (self.slots[s],)
        for k in range(seg):
            dtask = self._decode_kernel.launch(stream, inputs=bufs, outputs=bufs)
            self.task_kinds[dtask.tid] = "decode"
            self.session.on_task_retired(
                dtask,
                lambda t, s=s, boundary=(k == seg - 1):
                self._on_decode_retired(t, s, boundary))

    def pump(self) -> List[Request]:
        """One non-blocking service iteration; returns newly finished
        requests. Producers may call ``submit`` at any time between pumps
        (or from another thread with a threaded session). Safe after
        ``close()``: it then only drains requests that finished during the
        closing flush."""
        if not self.session.closed:
            self.session.poll()
            self._admit_ready()
            self.occupancy_samples.append(self.session.window.resident())
            if self.scheduler_name == "mesh":
                self.shard_slot_samples.append(self.shard_occupancy())
        out, self._finished = self._finished, []
        return out

    def shard_occupancy(self) -> Dict[int, int]:
        """Per-device slot accounting (mesh scheduler): how many ACTIVE
        request slots each shard currently owns — a slot is attributed to
        the shard that last wrote its buffer, i.e. where its chain runs.
        Slots whose chain has not executed yet are not attributed."""
        counts: Dict[int, int] = {}
        shard_of = getattr(self.session, "shard_of", None)
        if shard_of is None:
            return counts
        for s in self.active:
            shard = shard_of(self.slots[s])
            if shard is not None:
                counts[shard] = counts.get(shard, 0) + 1
        return counts

    def run_until_drained(self, max_iters: int = 10_000) -> List[Request]:
        """Serve until queue and slots empty (blocking between pumps only
        when nothing retired — the session's oldest-group sync). Raises
        :class:`DrainTimeout` (with the stuck queue/active counts and the
        requests that DID finish) when ``max_iters`` pumps don't drain
        the server — it used to return the partial list silently."""
        out: List[Request] = []
        for _ in range(max_iters):
            done = self.pump()
            out.extend(done)
            if not self.queue and not self.active:
                return out
            if not done:
                self.session.drive()
        raise DrainTimeout(
            f"run_until_drained: {max_iters} pumps left "
            f"{len(self.queue)} queued / {len(self.active)} active requests",
            queue_depth=len(self.queue), active_slots=len(self.active),
            finished=out)

    def close(self):
        """Close the underlying session and log its final report. Chains
        still in flight retire during the closing flush — collect those
        requests with one more ``pump()`` after close. Under
        ``preempt_rounds`` the continuation segments of in-flight chains
        are emitted lazily from retirement callbacks, which cannot feed a
        closing window — so drain first (finished requests stay
        collectable via ``pump()``)."""
        if self.preempt_rounds is not None and (self.queue or self.active):
            # two statements on purpose: pump() REBINDS self._finished, so
            # the attribute must be read after run_until_drained returns
            drained = self.run_until_drained()
            self._finished.extend(drained)
        self._closing = True
        report = self.session.close()
        entry = report.as_dict()
        entry["preemptions"] = self.preemptions
        entry["occupancy_mean"] = (
            float(np.mean(self.occupancy_samples)) if self.occupancy_samples else 0.0)
        if hasattr(report, "session_stats"):  # device session epoch counters
            entry["device_session"] = dict(report.session_stats)
        if self.shard_slot_samples:  # mesh per-device slot accounting
            shards: Dict[int, List[int]] = {}
            for sample in self.shard_slot_samples:
                for shard, n in sample.items():
                    shards.setdefault(shard, []).append(n)
            entry["shard_slots_mean"] = {
                str(shard): float(np.mean(v)) for shard, v in sorted(shards.items())}
        if self.scheduler_name == "mesh":
            # Transfer-plane summary at top level (the full per-shard audit
            # stays under device_session): which link mode the session
            # selected, how traffic split d2d vs staged, and the max
            # concurrent in-flight shards the overlapped drain reached.
            stats = self.session.session_stats()
            for key in ("transfer_mode", "d2d_moves", "staged_moves",
                        "d2d_fallbacks", "drain_overlap", "overlap_drains"):
                entry[key] = stats[key]
        self.report_log.append(entry)
        return report
