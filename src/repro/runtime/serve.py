"""Serving through the ACS window: a live session server + batch baseline.

Each request owns a KV-cache slot and emits kernels exactly like the
paper's applications:

* ``prefill(slot)``  — one task per newly admitted request; reads the
  token buffer, writes that slot's cache buffer.
* ``decode(slots)``  — one task over the currently decodable slot set;
  reads and writes those slots' caches.

Because slots are disjoint buffers, the ACS window discovers that a new
request's prefill is independent of the in-flight decode and co-schedules
them — continuous batching *emerges from dependency scheduling* rather
than being hand-coded. A slot's prefill -> decode -> decode chain stays
serialized by its RAW hazards on the slot buffer.

Two servers share the slot/admission machinery (:class:`_ServingCore`):

* :class:`SessionServer` — the open-loop runtime (DESIGN.md §10). It owns
  a persistent :class:`~..core.session.SchedulerSession`; admission emits
  a request's *whole program* (prefill + its count-bounded per-slot decode
  chain) through a live per-request ``TaskStream`` (``sink=`` the session,
  ``tag=req{rid}``) *into the live window while other requests' chains are
  still in flight*; per-task retirement callbacks harvest tokens and free
  prompt buffers without ever draining the world.
* :class:`ContinuousBatchingServer` — the per-step batch-drain baseline
  (``step()`` rebuilds a stream and blocks the host each iteration). Kept
  for its API stability and as the latency baseline ``bench_serving.py``
  measures the session server against.

Both apply multi-tenant fairness (admit for the tenant with the fewest
active slots, oldest-first tie-break) and backpressure (bounded admission
FIFO; ``submit`` raises :class:`AdmissionQueueFull` at capacity and stamps
the observed queue depth on the request), and both free each request's
prompt buffer once its prefill has retired — a long-running server cannot
leak one ``req{rid}_prompt`` allocation per request.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import BufferPool, TaskStream, WaveScheduler
from ..core.executors import SerialExecutor
from ..core.wrapper import AcsKernel
from ..models import decode_step, init_cache, prefill
from ..models.config import ArchConfig

__all__ = ["Request", "AdmissionQueueFull", "ContinuousBatchingServer",
           "SessionServer"]

_rid = itertools.count()


class AdmissionQueueFull(RuntimeError):
    """submit() refused: the bounded admission FIFO is at capacity — the
    server's backpressure signal to producers."""


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # [S] int32
    max_new: int = 8
    tenant: str = "default"
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_arrival: float = 0.0              # perf_counter at submit
    t_admit: float = 0.0                # perf_counter when a slot was granted
    t_finish: float = 0.0               # perf_counter when the last token retired
    queue_depth: int = 0                # admission FIFO depth observed at submit

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def latency(self) -> float:
        """End-to-end request latency (valid once finished)."""
        return self.t_finish - self.t_arrival


class _ServingCore:
    """Slots, kernels, and fair bounded admission — shared by both servers."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, max_queue: int = 256,
                 history_limit: Optional[int] = 1024):
        assert cfg.frontend is None, "serving driver uses token models"
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_queue = max_queue
        self.history_limit = history_limit
        self.pool = BufferPool()
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}
        # Rolling report trace: a long-lived server's host memory must be
        # flat, so monitoring state rotates instead of accumulating
        # (asserted by benchmarks/bench_soak.py).
        self.report_log: Deque[Dict] = collections.deque(maxlen=history_limit)

        # one opaque buffer per slot: value = (cache pytree, last_token, pos)
        self.slots = []
        for i in range(max_slots):
            cache = init_cache(cfg, 1, max_len)
            buf = self.pool.alloc((1,), np.float32, name=f"slot{i}",
                                  value=(cache, None, 0))
            self.slots.append(buf)
        self.free = list(range(max_slots))

        cfg_ = cfg

        def _prefill_fn(slot_val, tokens):
            cache, _, _ = slot_val
            logits, cache = prefill(self.params, cfg_, tokens, cache)
            tok = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
            # list-of-one: each element maps to one output buffer
            return [(cache, tok, jnp.asarray(tokens.shape[1], jnp.int32))]

        def _decode_fn(*slot_vals):
            outs = []
            for cache, tok, pos in slot_vals:
                pos = jnp.asarray(pos, jnp.int32)
                logits, cache = decode_step(
                    self.params, cfg_, tok[:, None], cache, pos,
                )
                nxt = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
                outs.append((cache, nxt, pos + 1))
            return outs

        self._prefill_kernel = AcsKernel(name="req_prefill", fn=_prefill_fn)
        self._decode_kernel = AcsKernel(name="req_decode", fn=_decode_fn)

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 8,
               tenant: str = "default") -> Request:
        """Enqueue a request. Raises :class:`AdmissionQueueFull` when the
        bounded FIFO is at capacity and :class:`ValueError` for requests
        that can never be served (over-long prompt, negative ``max_new``);
        otherwise stamps the observed queue depth on the request (the
        producer-visible backpressure signal). ``max_new=0`` is valid and
        means zero decode rounds: the request finishes with no generated
        tokens once its prefill retires."""
        if len(self.queue) >= self.max_queue:
            raise AdmissionQueueFull(
                f"admission queue at capacity ({self.max_queue}); retry later")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds the cache capacity "
                f"(max_len - 1 = {self.max_len - 1}); truncate the prompt "
                "or raise max_len")
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        req = Request(prompt=prompt, max_new=max_new, tenant=tenant)
        req.t_arrival = time.perf_counter()
        self.queue.append(req)
        req.queue_depth = len(self.queue)
        return req

    def queue_depth(self) -> int:
        return len(self.queue)

    # -- admission ----------------------------------------------------------
    def _pick_next(self) -> Request:
        """Multi-tenant fairness: admit for the tenant holding the fewest
        active slots; oldest-first tie-break (deque order is arrival
        order, so index order IS age order)."""
        counts: Dict[str, int] = {}
        for r in self.active.values():
            counts[r.tenant] = counts.get(r.tenant, 0) + 1
        best, best_load = 0, counts.get(self.queue[0].tenant, 0)
        for i in range(1, len(self.queue)):
            load = counts.get(self.queue[i].tenant, 0)
            if load < best_load:
                best, best_load = i, load
        if best == 0:
            return self.queue.popleft()
        req = self.queue[best]
        del self.queue[best]
        return req

    def _grant_slot(self, req: Request):
        """Bind the request to a free slot and allocate its prompt buffer
        (freed again when the prefill retires). The slot value resets to
        ``(cache, None, 0)`` so the previous occupant's leftover token/pos
        can never be mistaken for this request's state (a stale token made
        the batch server schedule a decode before the new prefill retired)."""
        req.slot = self.free.pop(0)
        req.t_admit = time.perf_counter()
        self.active[req.slot] = req
        cache = self.slots[req.slot].value[0]
        self.slots[req.slot].value = (cache, None, 0)
        tok_buf = self.pool.alloc(
            (1, len(req.prompt)), np.int32, name=f"req{req.rid}_prompt",
            value=jnp.asarray(req.prompt[None]),
        )
        return tok_buf

    def _harvest_slot(self, s: int) -> Optional[Request]:
        """Read the slot's freshly decoded token; return the request if it
        finished (slot freed), else None."""
        req = self.active[s]
        _, tok, pos = self.slots[s].value
        req.generated.append(int(np.asarray(tok)[0]))
        if req.done or int(pos) >= self.max_len - 1:
            req.t_finish = time.perf_counter()
            del self.active[s]
            self.free.append(s)
            return req
        return None


class ContinuousBatchingServer(_ServingCore):
    """Per-step batch-drain serving (the seed design, and the baseline the
    session server is benchmarked against): every iteration rebuilds a
    ``TaskStream``, runs it to empty through a closed-batch scheduler, and
    blocks the host — iteration *i*'s decode can never overlap iteration
    *i+1*'s prefill."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, window: int = 32, max_queue: int = 256):
        super().__init__(cfg, params, max_slots=max_slots, max_len=max_len,
                         max_queue=max_queue)
        # slot values are opaque pytrees (cache trees): the fused vmap
        # batcher needs array operands, so waves execute via the serial
        # executor — the window still builds multi-task waves, which is
        # the dependency-schedule evidence the benchmarks read.
        self.scheduler = WaveScheduler(window_size=window,
                                       executor=SerialExecutor())

    def step(self) -> List[Request]:
        """One server iteration: admit + prefill new requests, decode the
        active set — all through the ACS window. Returns finished requests."""
        stream = TaskStream()

        # admit as many queued requests as there are free slots
        prompt_bufs: List[str] = []
        while self.queue and self.free:
            req = self._pick_next()
            tok_buf = self._grant_slot(req)
            prompt_bufs.append(tok_buf.name)
            self._prefill_kernel.launch(
                stream, inputs=(self.slots[req.slot], tok_buf),
                outputs=(self.slots[req.slot],),
            )

        # decode wave over slots that hold a token AND can still take a
        # round (not done — max_new=0 finishes on prefill alone — and not
        # at cache capacity)
        decoding = [s for s, r in self.active.items()
                    if self.slots[s].value[1] is not None and not r.done
                    and int(self.slots[s].value[2]) < self.max_len - 1]
        if decoding:
            bufs = tuple(self.slots[s] for s in decoding)
            self._decode_kernel.launch(stream, inputs=bufs, outputs=bufs)

        if not stream.tasks:
            return []
        # executors jit/cache by signature; opaque pytree values need the
        # plain (uncompiled) path — dispatch counting still applies.
        report = self.scheduler.run(stream.tasks)
        # prefills completed inside the drain: release the prompt buffers
        for name in prompt_bufs:
            self.pool.free(name)
        entry = report.as_dict()
        entry["tasks_this_run"] = sum(len(w) for w in report.waves)
        entry["waves_this_run"] = len(report.waves)
        self.report_log.append(entry)

        finished = []
        for s in list(decoding):
            req = self._harvest_slot(s)
            if req is not None:
                finished.append(req)
        # zero-round finish: active slots whose prefill retired but which
        # can never decode (max_new=0, or the prompt fills the cache) —
        # finish with what they have instead of spinning forever
        for s in list(self.active):
            req = self.active[s]
            _, tok, pos = self.slots[s].value
            if tok is not None and (
                    req.done or int(pos) >= self.max_len - 1):
                req.t_finish = time.perf_counter()
                del self.active[s]
                self.free.append(s)
                finished.append(req)
        return finished

    def run_until_drained(self, max_iters: int = 200) -> List[Request]:
        out = []
        for _ in range(max_iters):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out


class SessionServer(_ServingCore):
    """Open-loop serving on a persistent scheduler session (DESIGN.md §10).

    Admission emits a request's *entire* kernel program — prefill plus its
    count-bounded decode chain — into the live window while other
    requests' chains are still in flight; the window's RAW hazards
    serialize each chain on its own slot buffer and co-schedule
    independent chains. ``pump()`` is the non-blocking service iteration:
    poll the session (retirement callbacks harvest tokens, free prompt
    buffers, finish requests), then admit queued requests into freed
    slots. Admission latency is bounded by the pump cadence, not by a full
    window drain, and no mid-request host round-trip ever gates a decode
    chain.

    ``scheduler="frontier"`` (default) runs width-1 groups through the
    async frontier — slot values are opaque pytrees, which vmap cannot
    stack, so concurrency comes from overlapped in-flight groups rather
    than batching. ``scheduler="wave"`` reproduces the seed's fused-wave
    evidence (one slot's decode co-resident with another's prefill in a
    single wave) with a serial executor. ``scheduler="device"`` serves
    through the persistent :class:`~..core.device_dispatch.DeviceSession`:
    admitted chains drain in whole-window epochs (slot values are opaque
    cache pytrees, so every serving kernel takes the session's in-epoch
    host path — the evidence here is the epoch/admission structure and the
    per-epoch stats, not arena residency). ``pool.free`` is wired into the
    device session's row lifecycle: any array buffer a producer routes
    through the arena (e.g. auxiliary device-lowerable streams submitted
    alongside requests) has its row recycled when the buffer is freed.
    The device session defaults to ``plan_mode="loop"`` — the ready-queue
    epoch executor that advances each dependency frontier in one dispatch
    (DESIGN §2 A3); pass ``plan_mode="wave"``/``"frontier"`` to serve
    through the fixed-step table lowering instead.

    ``scheduler="mesh"`` serves through the mesh-sharded window
    (:class:`~..core.mesh_session.MeshDeviceSession`): the global
    admission plane places each request's chain on one shard (its slot
    buffer's RAW chain pins it there) while independent requests spread
    across shards/devices; ``n_shards`` defaults to the visible device
    count. Per-device slot accounting rides the pump: every iteration
    samples which shard owns each active slot (``shard_occupancy``), and
    the rolling ``shard_slot_samples`` trace plus the session's
    cross-shard/transfer counters land in the close report.
    """

    SCHEDULERS = ("frontier", "wave", "device", "mesh")

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, window: int = 32, max_queue: int = 256,
                 scheduler: str = "frontier", max_inflight: int = 8,
                 history_limit: Optional[int] = 1024,
                 plan_mode: str = "loop", n_shards: Optional[int] = None):
        super().__init__(cfg, params, max_slots=max_slots, max_len=max_len,
                         max_queue=max_queue, history_limit=history_limit)
        if scheduler == "frontier":
            from ..core.frontier import FrontierSession

            self.session = FrontierSession(window_size=window,
                                           max_inflight=max_inflight,
                                           max_group=1,
                                           history_limit=history_limit)
        elif scheduler == "wave":
            from ..core.session import WaveSession

            self.session = WaveSession(window_size=window,
                                       executor=SerialExecutor(),
                                       history_limit=history_limit)
        elif scheduler == "device":
            from ..core.device_dispatch import DeviceSession

            self.session = DeviceSession(window_size=window,
                                         plan_mode=plan_mode,
                                         history_limit=history_limit)
            # Row lifecycle wiring: freeing any pool buffer (per-request
            # prompts, auxiliary workload buffers) releases its arena row
            # for recycling — the device session's slabs stay bounded under
            # unbounded request streams.
            self.pool.add_free_hook(self.session.release_buffer)
        elif scheduler == "mesh":
            from ..core.mesh_session import MeshDeviceSession

            self.session = MeshDeviceSession(window_size=window,
                                             n_shards=n_shards,
                                             history_limit=history_limit)
            # Same row-lifecycle wiring as "device", fanned out to every
            # shard's arena (a freed buffer may hold rows on several).
            self.pool.add_free_hook(self.session.release_buffer)
        else:
            raise ValueError(
                f"session server scheduler must be one of {self.SCHEDULERS}, "
                f"got {scheduler!r}")
        self.scheduler_name = scheduler
        self._finished: List[Request] = []
        # tid -> prefill | decode for tasks currently IN FLIGHT; entries
        # drop at retirement, so a long-lived server holds at most one
        # window's worth (schedule-kind traces for finished work live in
        # the rolling report_log, not here).
        self.task_kinds: Dict[int, str] = {}
        self.occupancy_samples: Deque[int] = collections.deque(
            maxlen=history_limit)
        # mesh only: rolling per-device slot-occupancy trace, one
        # {shard: active slot count} sample per pump (bounded like every
        # other monitoring surface — soak-safe).
        self.shard_slot_samples: Deque[Dict[int, int]] = collections.deque(
            maxlen=history_limit)

    # -- retirement callbacks (fire inside session.poll/drive) --------------
    def _finish_slot(self, slot: int) -> None:
        req = self.active.pop(slot)
        req.t_finish = time.perf_counter()
        self.free.append(slot)
        self._finished.append(req)

    def _on_prefill_retired(self, task, buf_name: str, slot: int,
                            finish: bool) -> None:
        self.pool.free(buf_name)  # no leak
        self.task_kinds.pop(task.tid, None)
        if finish:  # zero decode rounds: the prefill IS the whole program
            self._finish_slot(slot)

    def _on_decode_retired(self, task, slot: int, last: bool) -> None:
        self.task_kinds.pop(task.tid, None)
        req = self.active[slot]
        _, tok, _ = self.slots[slot].value
        req.generated.append(int(np.asarray(tok)[0]))
        if last:
            self._finish_slot(slot)

    # -- service loop --------------------------------------------------------
    def _admit(self, req: Request) -> None:
        """Emit the request's ENTIRE kernel program — prefill plus every
        decode round — into the live window at admission. Termination is
        count-based (``max_new`` bounded by ``max_len``), so the full
        chain is known up front: the window serializes it via the slot
        buffer's RAW hazards, co-schedules it against other slots' chains
        (disjoint buffers), and the host only trails behind retirements
        harvesting tokens — no mid-request host round-trip ever gates the
        decode chain (§III-D)."""
        tok_buf = self._grant_slot(req)
        s = req.slot
        # live per-request stream: AcsKernel.launch feeds the session's
        # window directly, tagged for per-request accounting
        stream = TaskStream(sink=self.session, tag=f"req{req.rid}", record=False)
        task = self._prefill_kernel.launch(
            stream, inputs=(self.slots[s], tok_buf), outputs=(self.slots[s],))
        self.task_kinds[task.tid] = "prefill"
        # Decode rounds the cache can actually hold: zero when max_new=0 or
        # the prompt already fills it — never force a phantom round that
        # would advance pos past max_len (the old max(1, ...) clamp).
        rounds = min(req.max_new, self.max_len - 1 - len(req.prompt))
        self.session.on_task_retired(
            task, lambda t, n=tok_buf.name, s=s, fin=(rounds == 0):
            self._on_prefill_retired(t, n, s, fin))
        bufs = (self.slots[s],)
        for k in range(rounds):
            dtask = self._decode_kernel.launch(stream, inputs=bufs, outputs=bufs)
            self.task_kinds[dtask.tid] = "decode"
            self.session.on_task_retired(
                dtask,
                lambda t, s=s, last=(k == rounds - 1):
                self._on_decode_retired(t, s, last))

    def pump(self) -> List[Request]:
        """One non-blocking service iteration; returns newly finished
        requests. Producers may call ``submit`` at any time between pumps
        (or from another thread with a threaded session). Safe after
        ``close()``: it then only drains requests that finished during the
        closing flush."""
        if not self.session.closed:
            self.session.poll()
            while self.queue and self.free:
                self._admit(self._pick_next())
            self.occupancy_samples.append(self.session.window.resident())
            if self.scheduler_name == "mesh":
                self.shard_slot_samples.append(self.shard_occupancy())
        out, self._finished = self._finished, []
        return out

    def shard_occupancy(self) -> Dict[int, int]:
        """Per-device slot accounting (mesh scheduler): how many ACTIVE
        request slots each shard currently owns — a slot is attributed to
        the shard that last wrote its buffer, i.e. where its chain runs.
        Slots whose chain has not executed yet are not attributed."""
        counts: Dict[int, int] = {}
        shard_of = getattr(self.session, "shard_of", None)
        if shard_of is None:
            return counts
        for s in self.active:
            shard = shard_of(self.slots[s])
            if shard is not None:
                counts[shard] = counts.get(shard, 0) + 1
        return counts

    def run_until_drained(self, max_iters: int = 10_000) -> List[Request]:
        """Serve until queue and slots empty (blocking between pumps only
        when nothing retired — the session's oldest-group sync)."""
        out: List[Request] = []
        for _ in range(max_iters):
            done = self.pump()
            out.extend(done)
            if not self.queue and not self.active:
                break
            if not done:
                self.session.drive()
        return out

    def close(self):
        """Close the underlying session and log its final report. Chains
        still in flight retire during the closing flush — collect those
        requests with one more ``pump()`` after close."""
        report = self.session.close()
        entry = report.as_dict()
        entry["occupancy_mean"] = (
            float(np.mean(self.occupancy_samples)) if self.occupancy_samples else 0.0)
        if hasattr(report, "session_stats"):  # device session epoch counters
            entry["device_session"] = dict(report.session_stats)
        if self.shard_slot_samples:  # mesh per-device slot accounting
            shards: Dict[int, List[int]] = {}
            for sample in self.shard_slot_samples:
                for shard, n in sample.items():
                    shards.setdefault(shard, []).append(n)
            entry["shard_slots_mean"] = {
                str(shard): float(np.mean(v)) for shard, v in sorted(shards.items())}
        self.report_log.append(entry)
        return report
