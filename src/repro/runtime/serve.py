"""Serving with continuous batching scheduled through the ACS window.

Each request owns a KV-cache slot. Every server iteration emits kernels
into a single TaskStream, exactly like the paper's applications:

* ``prefill(slot)``  — one task per newly admitted request; reads the
  token buffer, writes that slot's cache buffer.
* ``decode(slots)``  — one task over the currently active slot set; reads
  and writes those slots' caches.

Because slots are disjoint buffers, the ACS window discovers that a new
request's prefill is independent of the in-flight decode wave and runs
them in the same wave — continuous batching *emerges from dependency
scheduling* rather than being hand-coded. A slot's prefill -> decode ->
decode chain stays serialized by its RAW hazards on the slot buffer.

This is deliverable-(b)'s serving driver at reduced scale; at production
scale the same stream semantics run per-host with the fused decode wave
mapped onto the pjit decode_step (launch/steps.py).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import BufferPool, TaskStream, WaveScheduler
from ..core.wrapper import AcsKernel
from ..models import decode_step, init_cache, prefill
from ..models.config import ArchConfig

__all__ = ["Request", "ContinuousBatchingServer"]

_rid = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # [S] int32
    max_new: int = 8
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class ContinuousBatchingServer:
    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 64, window: int = 32):
        assert cfg.frontend is None, "serving driver uses token models"
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.pool = BufferPool()
        # slot values are opaque pytrees (cache trees): the fused vmap
        # batcher needs array operands, so waves execute via the serial
        # executor — the window still builds multi-task waves, which is
        # the dependency-schedule evidence the benchmarks read.
        from ..core.executors import SerialExecutor

        self.scheduler = WaveScheduler(window_size=window,
                                       executor=SerialExecutor())
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.report_log: List[Dict] = []

        # one opaque buffer per slot: value = (cache pytree, last_token, pos)
        self.slots = []
        for i in range(max_slots):
            cache = init_cache(cfg, 1, max_len)
            buf = self.pool.alloc((1,), np.float32, name=f"slot{i}",
                                  value=(cache, None, 0))
            self.slots.append(buf)
        self.free = list(range(max_slots))

        cfg_ = cfg

        def _prefill_fn(slot_val, tokens):
            cache, _, _ = slot_val
            logits, cache = prefill(self.params, cfg_, tokens, cache)
            tok = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
            # list-of-one: each element maps to one output buffer
            return [(cache, tok, jnp.asarray(tokens.shape[1], jnp.int32))]

        def _decode_fn(*slot_vals):
            outs = []
            for cache, tok, pos in slot_vals:
                pos = jnp.asarray(pos, jnp.int32)
                logits, cache = decode_step(
                    self.params, cfg_, tok[:, None], cache, pos,
                )
                nxt = jnp.argmax(logits[:, -1, : cfg_.vocab], axis=-1)
                outs.append((cache, nxt, pos + 1))
            return outs

        self._prefill_kernel = AcsKernel(name="req_prefill", fn=_prefill_fn)
        self._decode_kernel = AcsKernel(name="req_decode", fn=_decode_fn)

    # -- client API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 8) -> Request:
        req = Request(prompt=np.asarray(prompt, np.int32), max_new=max_new)
        self.queue.append(req)
        return req

    def step(self) -> List[Request]:
        """One server iteration: admit + prefill new requests, decode the
        active set — all through the ACS window. Returns finished requests."""
        stream = TaskStream()

        # admit as many queued requests as there are free slots
        while self.queue and self.free:
            req = self.queue.pop(0)
            req.slot = self.free.pop(0)
            self.active[req.slot] = req
            tok_buf = self.pool.alloc(
                (1, len(req.prompt)), np.int32, name=f"req{req.rid}_prompt",
                value=jnp.asarray(req.prompt[None]),
            )
            self._prefill_kernel.launch(
                stream, inputs=(self.slots[req.slot], tok_buf),
                outputs=(self.slots[req.slot],),
            )

        # decode wave over slots that already hold a token
        decoding = [s for s, r in self.active.items()
                    if self.slots[s].value[1] is not None]
        if decoding:
            bufs = tuple(self.slots[s] for s in decoding)
            self._decode_kernel.launch(stream, inputs=bufs, outputs=bufs)

        if not stream.tasks:
            return []
        # executors jit/cache by signature; opaque pytree values need the
        # plain (uncompiled) path — dispatch counting still applies.
        report = self.scheduler.run(stream.tasks)
        entry = report.as_dict()
        entry["tasks_this_run"] = sum(len(w) for w in report.waves)
        entry["waves_this_run"] = len(report.waves)
        self.report_log.append(entry)

        finished = []
        for s in list(decoding):
            req = self.active[s]
            cache, tok, pos = self.slots[s].value
            req.generated.append(int(tok[0]))
            if req.done or pos >= self.max_len - 1:
                finished.append(req)
                del self.active[s]
                self.free.append(s)
        return finished

    def run_until_drained(self, max_iters: int = 200) -> List[Request]:
        out = []
        for _ in range(max_iters):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out
