"""Fault-tolerant training loop.

Production behaviors implemented and CPU-tested:

* **Checkpoint/restart** — params/opt-state/data-cursor checkpointed every
  ``checkpoint_every`` steps (atomic manifests); a fresh ``Trainer`` on the
  same directory resumes exactly (tested: loss trajectory continues).
* **Straggler mitigation** — per-step wall time watchdog: a step slower
  than ``straggler_factor`` x running median is recorded and the
  ``on_straggler`` hook fires (at scale: re-dispatch the step's wave /
  evict the slow host; here: observable metrics + hook).
* **Failure injection** — ``fail_at_step`` raises mid-run (tests restart
  and verify bit-exact resumption).
* **Elastic remesh** — checkpoints are mesh-agnostic (gathered arrays);
  restoring under a different mesh/policy re-shards on load.
* **Gradient compression** — optional error-feedback int8 on the gradient
  stream (cross-pod DP reduction path; optim/compression.py).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import DataCursor, TokenPipeline
from ..models import init_params, loss_fn
from ..models.config import ArchConfig
from ..optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    ef_int8_compress,
    ef_int8_decompress,
)

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 32
    batch: int = 4
    lr: float = 3e-3
    warmup: int = 20
    total_steps: int = 400
    clip: float = 1.0
    checkpoint_every: int = 20
    keep: int = 3
    straggler_factor: float = 3.0
    grad_compression: bool = False
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        tcfg: TrainerConfig,
        ckpt_dir: Path,
        *,
        fail_at_step: Optional[int] = None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=tcfg.keep)
        self.fail_at_step = fail_at_step
        self.on_straggler = on_straggler
        self.schedule = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
        self.pipeline = TokenPipeline(
            cfg.vocab, tcfg.seq_len, tcfg.batch, seed=tcfg.seed
        )
        self.metrics: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []

        params = init_params(cfg, jax.random.PRNGKey(tcfg.seed), tp_size=1)
        opt = adamw_init(params)
        err = (
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if tcfg.grad_compression else None
        )
        self.state = {"params": params, "opt": opt, "err": err}

        restored = self.ckpt.restore_latest(self.state)
        if restored is not None:
            self.state, extras = restored
            self.pipeline.seek(DataCursor.from_dict(extras["cursor"]))
            self.start_step = int(extras["step"]) + 1
        else:
            self.start_step = 0

        tcfg_local = tcfg
        cfg_local = cfg
        compress = tcfg.grad_compression

        def step_fn(state, inputs, labels, lr):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg_local, inputs, labels)
            )(state["params"])
            grads, gnorm = clip_by_global_norm(grads, tcfg_local.clip)
            err = state["err"]
            if compress:
                # error-feedback int8 round-trip (the cross-pod wire format)
                q, scales, err = ef_int8_compress(grads, err)
                grads = ef_int8_decompress(q, scales)
            params, opt = adamw_update(state["params"], grads, state["opt"], lr)
            return {"params": params, "opt": opt, "err": err}, {
                "loss": loss, "gnorm": gnorm,
            }

        self._step = jax.jit(step_fn)

    def run(self, n_steps: Optional[int] = None) -> List[Dict[str, float]]:
        end = self.tcfg.total_steps if n_steps is None else self.start_step + n_steps
        times: List[float] = []
        for step in range(self.start_step, end):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            # The watchdog times the WHOLE step, batch fetch included: an
            # input-pipeline stall delays the step exactly like a slow
            # device and must register as straggler signal.
            t0 = time.perf_counter()
            inputs, labels = self.pipeline.next_batch()
            self.state, m = self._step(
                self.state, jnp.asarray(inputs), jnp.asarray(labels),
                jnp.asarray(self.schedule(step), jnp.float32),
            )
            m = {k: float(v) for k, v in m.items()}
            dt = time.perf_counter() - t0
            times.append(dt)
            med = statistics.median(times[-25:])
            if len(times) > 5 and dt > self.tcfg.straggler_factor * med:
                self.straggler_steps.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt / med)
            m.update(step=step, dt=dt)
            self.metrics.append(m)
            if (step + 1) % self.tcfg.checkpoint_every == 0 or step + 1 == end:
                self.ckpt.save(
                    step, self.state,
                    extras={"cursor": self.pipeline.cursor.as_dict()},
                )
        self.start_step = end
        return self.metrics
