"""Runtime loops: fault-tolerant training, ACS-scheduled serving."""

from .serve import (
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    AdmissionQueueFull,
    ContinuousBatchingServer,
    DrainTimeout,
    Request,
    SessionServer,
)
from .train import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "ContinuousBatchingServer",
           "SessionServer", "AdmissionQueueFull", "DrainTimeout", "Request",
           "PRIORITY_HIGH", "PRIORITY_NORMAL", "PRIORITY_LOW"]
