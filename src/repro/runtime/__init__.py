"""Runtime loops: fault-tolerant training, ACS-scheduled serving."""

from .serve import ContinuousBatchingServer, Request
from .train import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "ContinuousBatchingServer", "Request"]
