"""Runtime loops: fault-tolerant training, ACS-scheduled serving."""

from .serve import (
    AdmissionQueueFull,
    ContinuousBatchingServer,
    Request,
    SessionServer,
)
from .train import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "ContinuousBatchingServer",
           "SessionServer", "AdmissionQueueFull", "Request"]
