"""Distribution layer: mesh axes, per-arch sharding policies, constraint
helpers. See DESIGN.md §6."""

from .axes import ShardingPolicy, current_policy, shard, use_policy
from .sharding import batch_specs, cache_specs, param_specs, policy_for

__all__ = [
    "ShardingPolicy", "current_policy", "shard", "use_policy",
    "param_specs", "batch_specs", "cache_specs", "policy_for",
]
