"""Per-arch sharding rules (DESIGN.md §6).

``policy_for(cfg, mesh)`` resolves the per-(arch, mesh) decisions:
heads/kv-heads/experts shard over 'model' when divisible; otherwise
attention falls back to sequence sharding and the (small) attention
weights are replicated. ``param_specs`` / ``batch_specs`` / ``cache_specs``
produce PartitionSpec pytrees for jit in_shardings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..models.config import ArchConfig
from .axes import ShardingPolicy

__all__ = ["policy_for", "param_specs", "batch_specs", "cache_specs"]


def policy_for(cfg: ArchConfig, mesh: jax.sharding.Mesh,
               batch: Optional[int] = None) -> ShardingPolicy:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axes.get("model", 1)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    return ShardingPolicy(
        dp=dp,
        tp="model",
        tp_size=tp,
        dp_size=dp_size,
        batch_shardable=batch is None or batch % dp_size == 0,
        shard_heads=cfg.eff_heads % tp == 0,
        shard_kv_heads=cfg.eff_kv_heads % tp == 0,
        shard_experts=cfg.moe is not None,  # experts are padded to E % tp == 0
        seq_shard_attn=cfg.eff_heads % tp != 0,
        mesh=mesh,
    )


# -- parameter tree ----------------------------------------------------------

def _leaf_spec(name: str, ndim: int, pol: ShardingPolicy) -> P:
    """Sharding rule for one (unstacked) parameter leaf by name + rank."""
    tp = pol.tp
    h = tp if pol.shard_heads else None
    rules: Dict[Tuple[str, int], P] = {
        ("embed", 2): P(tp, None),        # vocab-sharded embedding
        ("head", 2): P(None, tp),
        ("frontend_proj", 2): P(None, tp),
        ("norm", 1): P(None),
        ("ffn_norm", 1): P(None),
        ("final_norm", 1): P(None),
        # attention
        ("wq", 3): P(None, h, None),
        ("wk", 3): P(None, tp if pol.shard_kv_heads else None, None),
        ("wv", 3): P(None, tp if pol.shard_kv_heads else None, None),
        ("wo", 2): P(h, None),
        # MLA
        ("wq_a", 2): P(None, None),
        ("wq_b", 3): P(None, h, None),
        ("wkv_a", 2): P(None, None),
        ("wkv_b", 3): P(None, h, None),
        # dense FFN
        ("w_gate", 2): P(None, tp),
        ("w_up", 2): P(None, tp),
        ("w_down", 2): P(tp, None),
        # MoE experts (E axis)
        ("router", 2): P(None, None),
        ("w_gate", 3): P(tp, None, None),
        ("w_up", 3): P(tp, None, None),
        ("w_down", 3): P(tp, None, None),
        # RG-LRU
        ("w_in", 2): P(None, tp),
        ("w_gate_in", 2): P(None, tp),
        ("conv_w", 2): P(None, tp),
        ("wr", 2): P(None, tp),
        ("wi", 2): P(None, tp),
        ("a_log", 1): P(tp),
        ("w_out", 2): P(tp, None),
        # Mamba
        ("x_proj", 2): P(tp, None),
        ("dt_proj", 2): P(None, tp),
        ("dt_bias", 1): P(tp),
        ("A_log", 2): P(tp, None),
        ("D", 1): P(tp),
    }
    return rules.get((name, ndim), P(*([None] * ndim)))


def param_specs(params: Any, pol: ShardingPolicy) -> Any:
    """PartitionSpec pytree matching ``params`` (stage-stacked leaves get a
    leading None for the scan axis)."""

    def spec_for(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", None)) or str(getattr(k, "idx", "")) for k in path]
        name = next((k for k in reversed(keys) if isinstance(k, str) and not k.isdigit()), "")
        stacked = "stages" in keys
        ndim = leaf.ndim - (1 if stacked else 0)
        base = _leaf_spec(name, ndim, pol)
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(spec_for, params)


# -- step inputs ---------------------------------------------------------------

def batch_specs(cfg: ArchConfig, pol: ShardingPolicy, kind: str) -> Any:
    """Specs for (inputs, labels) or serving inputs."""
    dp = pol.dp if pol.batch_shardable else ()
    if cfg.frontend:
        inputs = P(dp, None, None)  # [B, S, F] embeddings
    else:
        inputs = P(dp, None)        # [B, S] tokens
    if kind == "train":
        return inputs, P(dp, None)
    return inputs


def cache_specs(cfg: ArchConfig, pol: ShardingPolicy) -> Any:
    """Spec tree mirroring transformer.init_cache's structure.

    These are strict jit *argument* shardings, so every sharded dimension
    must divide exactly — ``wide`` picks the largest divisible option:
    folded (dp+tp) axes when the batch is unshardable, else tp, else
    replicated.
    """
    from ..models.transformer import split_pattern

    tp = pol.tp
    dp = pol.dp if pol.batch_shardable else ()
    tp_total = pol.tp_size * (1 if pol.batch_shardable else pol.dp_size)

    def wide(dim: int):
        if not pol.batch_shardable and dim % tp_total == 0:
            return pol.dp + (tp,)
        if dim % max(pol.tp_size, 1) == 0:
            return tp
        return None

    def entry(kind: str, stacked: bool, max_len: int):
        lead = (None,) if stacked else ()
        if kind in ("attn_global", "attn_local"):
            rows = max_len
            if kind == "attn_local" and cfg.window is not None:
                rows = min(cfg.window, max_len)
            if pol.shard_kv_heads:
                kv = P(*lead, dp, tp, None, None)
            else:
                kv = P(*lead, dp, None, wide(rows), None)
            return (kv, kv)
        if kind == "mla":
            c = P(*lead, dp, None, None)
            return (c, c)
        if kind == "rglru":
            w = cfg.rglru_width or cfg.d_model
            return (P(*lead, dp, wide(w)), P(*lead, dp, None, wide(w)))
        if kind == "mamba":
            di = cfg.expand * cfg.d_model
            return (P(*lead, dp, wide(di), None), P(*lead, dp, None, wide(di)))
        raise ValueError(kind)

    # max_len is only needed for the local-window row count; the callers
    # always size local caches at min(window, seq) == window for the
    # assigned shapes, so window is the effective row count.
    max_len = cfg.window or 0

    prefix, n_stages = split_pattern(cfg)
    return {
        "prefix": [entry(k, False, max_len or 1 << 30) for k in prefix],
        "stages": tuple(entry(k, True, max_len or 1 << 30) for k in cfg.pattern_unit)
        if n_stages > 0 else None,
    }
