"""Sharding context: model code expresses *semantic* constraints
(``shard(x, "act_btd")``); the active :class:`ShardingPolicy` maps them to
``PartitionSpec``s for the production mesh — or to no-ops when unset (CPU
smoke tests run the exact same model code with no mesh at all).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingPolicy", "use_policy", "current_policy", "shard"]

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Resolved per-(arch, mesh) sharding decisions (DESIGN.md §6)."""

    dp: Tuple[str, ...]          # data-parallel mesh axes, e.g. ("pod", "data")
    tp: str = "model"            # tensor-parallel axis
    shard_heads: bool = True     # H % tp_size == 0
    shard_kv_heads: bool = True  # Hkv % tp_size == 0
    shard_experts: bool = True   # (padded) E % tp_size == 0
    seq_shard_attn: bool = False # fallback: shard attention over sequence
    tp_size: int = 1
    dp_size: int = 1
    # False for cells whose global batch does not divide the dp axes
    # (long_500k: batch=1): batch dims replicate and the dp axes are folded
    # into the channel/sequence sharding instead.
    batch_shardable: bool = True
    mesh: Optional[jax.sharding.Mesh] = None  # required for constraints

    # -- semantic specs -------------------------------------------------------
    def spec(self, kind: str) -> Optional[P]:
        tp = self.tp
        dp = self.dp if self.batch_shardable else ()
        # wide axis: fold the idle dp axes into tp when batch is unshardable
        tpw = tp if self.batch_shardable else self.dp + (tp,)
        table = {
            # activations [B, S, D]
            "act_btd": P(dp, None, None),
            # ffn hidden [B, S, F] — F sharded over tp
            "ffn_hidden": P(dp, None, tpw),
            # logits [B, S, V] — vocab sharded
            "logits": P(dp, None, tpw),
            # attention tensors [B, H, S, hd]
            "heads": P(dp, tp, None, None) if self.shard_heads
                     else (P(dp, None, tp, None) if self.seq_shard_attn else P(dp, None, None, None)),
            "kv_heads": P(dp, tp, None, None) if self.shard_kv_heads
                        else (P(dp, None, tp, None) if self.seq_shard_attn else P(dp, None, None, None)),
            # kv cache [B, Hkv, S, hd]
            "kv_cache": P(dp, tp, None, None) if self.shard_kv_heads
                        else P(dp, None, tpw, None),
            # MoE dispatch [G, E, C, D] (G = batch-aligned dispatch groups)
            "experts_gecd": P(dp, tp, None, None) if self.shard_experts else P(dp, None, None, None),
            "experts_gec": P(dp, tp, None) if self.shard_experts else P(dp, None, None),
            # recurrent channel tensors [B, S, W] — W sharded
            "channels": P(dp, None, tpw),
            # recurrent state [B, W]
            "state_bw": P(dp, tpw),
            # tokens [B, S]
            "tokens": P(dp, None),
        }
        return table[kind]


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = getattr(_TLS, "policy", None)
    _TLS.policy = policy
    try:
        yield
    finally:
        _TLS.policy = prev


def current_policy() -> Optional[ShardingPolicy]:
    return getattr(_TLS, "policy", None)


def shard(x, kind: str):
    """Apply the active policy's constraint for ``kind`` (no-op without one)."""
    policy = current_policy()
    if policy is None or policy.mesh is None:
        return x
    spec = policy.spec(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(policy.mesh, spec)
    )
