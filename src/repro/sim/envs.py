"""Environment specs mirroring the paper's Brax/MuJoCo evaluation set
(§V: ant, grasp, humanoid, cheetah, walker2d).

Each spec is an articulated rigid-body tree: bodies are point masses with a
collision radius; joints are stiff spring-damper constraints between parent
and child (penalty formulation — standard for differentiable engines like
Brax's spring dynamics). Actuators inject per-joint control torques
(as forces along the joint axis) from the RL policy.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["EnvSpec", "ENVIRONMENTS", "make_env"]


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    name: str
    n_bodies: int
    joints: Tuple[Tuple[int, int], ...]  # (parent, child) body indices
    actuated: Tuple[int, ...]  # joint indices with actuators
    radius: float = 0.12  # collision radius (uniform; spheres)
    mass: float = 1.0

    @property
    def n_joints(self) -> int:
        return len(self.joints)

    def contact_candidates(self) -> List[Tuple[int, int]]:
        """All body pairs not directly connected by a joint (broad set);
        the runtime broadphase narrows this per-state (input-dependence)."""
        connected = {tuple(sorted(j)) for j in self.joints}
        out = []
        for a in range(self.n_bodies):
            for b in range(a + 1, self.n_bodies):
                if (a, b) not in connected:
                    out.append((a, b))
        return out


def _chain(n: int) -> Tuple[Tuple[int, int], ...]:
    return tuple((i, i + 1) for i in range(n - 1))


def _star_legs(n_legs: int, per_leg: int) -> Tuple[Tuple[int, int], ...]:
    """Torso = body 0; each leg is a chain hanging off the torso."""
    joints = []
    body = 1
    for _ in range(n_legs):
        parent = 0
        for _ in range(per_leg):
            joints.append((parent, body))
            parent = body
            body += 1
    return tuple(joints)


def _ant() -> EnvSpec:
    # torso + 4 legs x 2 segments = 9 bodies, 8 joints (paper's ant: 4 legs
    # each with a knee joint).
    joints = _star_legs(4, 2)
    return EnvSpec("ant", 9, joints, actuated=tuple(range(8)))


def _cheetah() -> EnvSpec:
    # planar half-cheetah: torso + back thigh/shin/foot + front thigh/shin/foot.
    joints = _star_legs(2, 3)
    return EnvSpec("cheetah", 7, joints, actuated=tuple(range(6)))


def _walker2d() -> EnvSpec:
    joints = _star_legs(2, 3)
    return EnvSpec("walker2d", 7, joints, actuated=tuple(range(6)))


def _humanoid() -> EnvSpec:
    # torso(0), head(1), two arms x 2, two legs x 3, pelvis(..) ~ 13 bodies.
    joints = [(0, 1)]  # neck
    body = 2
    for _ in range(2):  # arms: upper, lower
        parent = 0
        for _ in range(2):
            joints.append((parent, body))
            parent = body
            body += 1
    for _ in range(2):  # legs: thigh, shin, foot
        parent = 0
        for _ in range(3):
            joints.append((parent, body))
            parent = body
            body += 1
    return EnvSpec("humanoid", body, tuple(joints), actuated=tuple(range(len(joints))))


def _grasp() -> EnvSpec:
    # palm(0) + 4 fingers x 3 segments + free object = 14 bodies; the object
    # (body 13) is unjointed -> its interactions are pure contacts, making
    # the active-contact set strongly state-dependent (the paper's point).
    joints = _star_legs(4, 3)
    return EnvSpec("grasp", 14, joints, actuated=tuple(range(12)))


ENVIRONMENTS = {
    "ant": _ant(),
    "grasp": _grasp(),
    "humanoid": _humanoid(),
    "cheetah": _cheetah(),
    "walker2d": _walker2d(),
}


def make_env(name: str) -> EnvSpec:
    return ENVIRONMENTS[name]


def initial_state(spec: EnvSpec, n_envs: int, seed: int = 0) -> np.ndarray:
    """[n_envs, n_bodies, 6] (pos xyz, vel xyz). Bodies start in a loose
    cluster above the ground plane with per-env jitter — each instance is a
    different scenario (paper §II-B: 'each thread simulates a different
    scenario')."""
    rng = np.random.RandomState(seed)
    pos = rng.uniform(-0.5, 0.5, size=(n_envs, spec.n_bodies, 3)).astype(np.float32)
    pos[..., 2] += 1.0  # above ground
    vel = 0.1 * rng.randn(n_envs, spec.n_bodies, 3).astype(np.float32)
    return np.concatenate([pos, vel], axis=-1)
