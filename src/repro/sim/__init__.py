"""Brax-like GPU physics simulation engine — the paper's workload 1 (§II-B).

Deep-RL data generation: many parallel environment instances, each stepped
by a stream of *small kernels* (per-joint constraint solves, per-contact
penalty forces, per-group integration) whose dependency graph is
input-dependent — the set of active contacts changes with the simulation
state every step, exactly the irregularity ACS targets.
"""

from .engine import PhysicsEngine, SimKernelStats, SIM_KERNELS, register_device_kernels
from .envs import ENVIRONMENTS, EnvSpec, make_env

__all__ = ["PhysicsEngine", "SimKernelStats", "SIM_KERNELS",
           "register_device_kernels", "ENVIRONMENTS", "EnvSpec", "make_env"]
