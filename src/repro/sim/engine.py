"""The simulation engine: physics as a stream of small ACS kernels.

Faithful workload structure (paper §II-B): each step of each environment
group emits
  * one ``joint_solve`` kernel per joint           (spring-damper + actuation)
  * one ``contact_pair`` kernel per *active* pair  (INPUT-DEPENDENT: the
    active set comes from a host-side broadphase over the current state —
    this is what makes the computational graph vary per input/state)
  * one ``ground_contact`` kernel per group
  * one ``integrate`` kernel per group             (gather forces, Euler)
  * one ``observe`` kernel per group               (policy features)

Kernels are deliberately small (a group is ``group_size`` envs × ≤14
bodies ≈ hundreds of floats) — the paper's small-kernel property. Groups
use disjoint buffers, so ACS's window recovers cross-group and intra-step
parallelism that the serial stream hides.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.task import Task
from ..core.wrapper import AcsKernel, TaskStream
from .envs import EnvSpec, initial_state

__all__ = ["PhysicsEngine", "SimKernelStats", "SIM_KERNELS", "register_device_kernels"]

_DT = 0.01
_GRAVITY = -9.81
_KP, _KD = 80.0, 4.0  # joint spring-damper
_KC = 200.0  # contact penalty stiffness
_KG = 400.0  # ground stiffness


# --------------------------------------------------------------------------
# Kernel bodies (pure jnp; statics appended by the wrapper)
# --------------------------------------------------------------------------

def _joint_fn(state, ctrl, j, parent, child, rest, kp, kd):
    """Spring-damper + actuation along the joint axis. [g,B,6] -> [1,g,6]
    (force-on-parent ++ force-on-child)."""
    pos, vel = state[..., :3], state[..., 3:]
    d = pos[:, child] - pos[:, parent]  # [g, 3]
    dist = jnp.linalg.norm(d, axis=-1, keepdims=True) + 1e-6
    u = d / dist
    rel_v = vel[:, child] - vel[:, parent]
    f = (kp * (dist - rest) + kd * jnp.sum(rel_v * u, axis=-1, keepdims=True)) * u
    f = f + ctrl[:, j : j + 1] * u  # actuation torque proxy along the axis
    return jnp.concatenate([f, -f], axis=-1)[None]  # [1, g, 6]


def _contact_fn(state, a, b, radius, kc):
    """Sphere-sphere penalty. [g,B,6] -> [1,g,6] (force-on-a ++ force-on-b)."""
    pos, vel = state[..., :3], state[..., 3:]
    d = pos[:, b] - pos[:, a]
    dist = jnp.linalg.norm(d, axis=-1, keepdims=True) + 1e-6
    u = d / dist
    pen = jnp.maximum(2.0 * radius - dist, 0.0)
    rel_v = jnp.sum((vel[:, b] - vel[:, a]) * u, axis=-1, keepdims=True)
    f = -(kc * pen - 0.1 * kc * pen * rel_v) * u  # push a away from b
    return jnp.concatenate([f, -f], axis=-1)[None]


def _ground_fn(state, radius, kg):
    """Ground-plane penalty + tangential damping. [g,B,6] -> [g,B,3]."""
    pos, vel = state[..., :3], state[..., 3:]
    pen = jnp.maximum(radius - pos[..., 2:3], 0.0)
    fz = kg * pen - 2.0 * jnp.minimum(vel[..., 2:3], 0.0) * kg * pen
    in_contact = (pen > 0).astype(state.dtype)
    ft = -5.0 * vel[..., :2] * in_contact  # friction proxy
    return jnp.concatenate([ft, fz], axis=-1)


def _integrate_fn(state, jf, gf, *cf_rows_and_statics):
    """Gather all force contributions, semi-implicit Euler step."""
    (parents, children, pairs_a, pairs_b, n_cf, mass, dt) = cf_rows_and_statics[-7:]
    cf_rows = cf_rows_and_statics[:-7]
    assert len(cf_rows) == n_cf
    g, b = state.shape[0], state.shape[1]
    force = jnp.zeros((g, b, 3), state.dtype)
    force = force + gf
    parents = np.asarray(parents, np.int32)
    children = np.asarray(children, np.int32)
    # jf: [J, g, 6] -> per-body scatter-add
    jf_t = jnp.swapaxes(jf, 0, 1)  # [g, J, 6]
    force = force.at[:, parents].add(jf_t[..., :3])
    force = force.at[:, children].add(jf_t[..., 3:])
    if cf_rows:
        cf = jnp.concatenate(cf_rows, axis=0)  # [C, g, 6]
        cf_t = jnp.swapaxes(cf, 0, 1)  # [g, C, 6]
        force = force.at[:, np.asarray(pairs_a, np.int32)].add(cf_t[..., :3])
        force = force.at[:, np.asarray(pairs_b, np.int32)].add(cf_t[..., 3:])
    acc = force / mass + jnp.array([0.0, 0.0, _GRAVITY], state.dtype)
    vel = state[..., 3:] + dt * acc
    pos = state[..., :3] + dt * vel
    return jnp.concatenate([pos, vel], axis=-1)


def _observe_fn(state):
    """Policy features: per-env flatten of (pos - torso, vel). [g,B,6] -> [g,B*6]."""
    torso = state[:, :1, :3]
    rel = jnp.concatenate([state[..., :3] - torso, state[..., 3:]], axis=-1)
    return rel.reshape(state.shape[0], -1)


def _joint_flops(inputs, outputs, *s):
    g = inputs[0].shape[0] if hasattr(inputs[0], "shape") else 1
    return 60.0 * g


_JOINT = AcsKernel(name="joint_solve", fn=_joint_fn)
_CONTACT = AcsKernel(name="contact_pair", fn=_contact_fn)
_GROUND = AcsKernel(name="ground_contact", fn=_ground_fn)
_INTEGRATE = AcsKernel(name="integrate", fn=_integrate_fn)
_OBSERVE = AcsKernel(name="observe", fn=_observe_fn)

#: Every kernel a PhysicsEngine stream can emit — the fixed opcode set the
#: device-resident window (DESIGN §2 A3) needs registered ahead of time.
SIM_KERNELS = (_JOINT, _CONTACT, _GROUND, _INTEGRATE, _OBSERVE)

#: Switch-branch table for the device ready-queue fast path: empty on
#: purpose. Every sim kernel either changes the row geometry (observe
#: flattens [g,B,6] -> [g,B*6]) or spans multiple shape classes per
#: stream (joint/contact/ground group sizes differ), so none satisfies
#: the single-class, shape-preserving eligibility of
#: ``kernels/ready_queue.py``. Sim epochs run through the structurally
#: identical ``lax.while_loop`` interpreter — still one dispatch.
SWITCH_BRANCHES: Dict[str, object] = {}


def register_device_kernels(registry) -> Dict[str, int]:
    """Register the simulation kernel set with a
    :class:`~repro.core.DeviceOpRegistry` (fn-less: the arena path executes
    each task's wrapper-resolved callable, with static args baked; the
    registry entry is the opcode-table slot that gates lowering). Returns
    name -> opcode. Shape classes per opcode are recorded by the lowering
    pass in ``registry.classes_seen``."""
    for name, fn in SWITCH_BRANCHES.items():
        registry.register_switch_branch(name, fn)
    return {k.name: registry.register(k.name) for k in SIM_KERNELS}


class SimKernelStats:
    """Per-stream kernel census (reproduces the paper's Figs 3-5 metrics)."""

    def __init__(self) -> None:
        self.kernels = 0
        self.steps = 0
        self.elements: List[int] = []  # per-kernel output element counts
        self.active_contacts: List[int] = []
        self.candidate_contacts = 0

    @property
    def kernels_per_step(self) -> float:
        return self.kernels / max(self.steps, 1)

    def cta_histogram(self, threads_per_cta: int = 256) -> Dict[int, int]:
        """Kernel-size distribution in CTAs (elements/threads ceil) — Fig 5."""
        hist: Dict[int, int] = {}
        for e in self.elements:
            ctas = max(1, -(-e // threads_per_cta))
            hist[ctas] = hist.get(ctas, 0) + 1
        return hist

    def as_dict(self) -> Dict[str, float]:
        return {
            "kernels": self.kernels,
            "steps": self.steps,
            "kernels_per_step": self.kernels_per_step,
            "mean_kernel_elems": float(np.mean(self.elements)) if self.elements else 0.0,
            "mean_active_contacts": float(np.mean(self.active_contacts))
            if self.active_contacts
            else 0.0,
        }


@dataclasses.dataclass
class _Group:
    state: Buffer
    jf: Buffer
    gf: Buffer
    cf: Buffer
    obs: Buffer


class PhysicsEngine:
    """One environment family, ``n_envs`` instances in groups of
    ``group_size`` (disjoint buffer sets => schedulable in parallel)."""

    def __init__(
        self,
        spec: EnvSpec,
        n_envs: int = 64,
        group_size: int = 8,
        seed: int = 0,
        dt: float = _DT,
        broadphase_margin: float = 0.25,
    ):
        assert n_envs % group_size == 0
        self.spec = spec
        self.n_envs = n_envs
        self.group_size = group_size
        self.dt = dt
        self.margin = broadphase_margin
        self.pool = BufferPool()
        self.rng = np.random.RandomState(seed)
        self.candidates = spec.contact_candidates()
        self.stats = SimKernelStats()
        self._step_index = 0

        g, b, j, c = group_size, spec.n_bodies, spec.n_joints, len(self.candidates)
        full = initial_state(spec, n_envs, seed)
        self.groups: List[_Group] = []
        for gi in range(n_envs // group_size):
            sl = full[gi * g : (gi + 1) * g]
            self.groups.append(
                _Group(
                    state=self.pool.alloc((g, b, 6), np.float32, f"state{gi}", jnp.asarray(sl)),
                    jf=self.pool.alloc((max(j, 1), g, 6), np.float32, f"jf{gi}",
                                       jnp.zeros((max(j, 1), g, 6), jnp.float32)),
                    gf=self.pool.alloc((g, b, 3), np.float32, f"gf{gi}",
                                       jnp.zeros((g, b, 3), jnp.float32)),
                    cf=self.pool.alloc((max(c, 1), g, 6), np.float32, f"cf{gi}",
                                       jnp.zeros((max(c, 1), g, 6), jnp.float32)),
                    obs=self.pool.alloc((g, b * 6), np.float32, f"obs{gi}",
                                        jnp.zeros((g, b * 6), jnp.float32)),
                )
            )

    # -- broadphase (host side; the source of input-dependence) ------------
    def _active_pairs(self, group: _Group) -> List[int]:
        pos = np.asarray(group.state.value)[..., :3]  # [g, B, 3]
        thresh = 2.0 * self.spec.radius + self.margin
        act = []
        for ci, (a, b) in enumerate(self.candidates):
            d = np.linalg.norm(pos[:, b] - pos[:, a], axis=-1)
            if np.any(d < thresh):
                act.append(ci)
        return act

    # -- emission -----------------------------------------------------------
    def emit_step(self, stream: TaskStream, policy: Optional[Callable] = None) -> None:
        """Launch one simulation step's kernels for every group, exactly as
        an application would: per-group, program order, single stream."""
        spec, g = self.spec, self.group_size
        for gi, grp in enumerate(self.groups):
            # fresh ctrl buffer per (group, step): host-produced actions
            if policy is not None:
                actions = np.asarray(policy(np.asarray(grp.obs.value)), np.float32)
            else:
                actions = self.rng.uniform(-1, 1, size=(g, spec.n_joints)).astype(np.float32)
            ctrl = self.pool.alloc(
                (g, spec.n_joints), np.float32,
                f"ctrl{gi}_s{self._step_index}", jnp.asarray(actions),
            )

            for j, (p, c) in enumerate(spec.joints):
                # reads full state + this joint's control column;
                # writes its OWN jf row -> joints are mutually independent.
                _JOINT.launch(
                    stream,
                    inputs=(grp.state, ctrl),
                    outputs=(grp.jf.row_view(j, 1),),
                    static_args=(j, p, c, 0.35, _KP, _KD),
                )

            active = self._active_pairs(grp)
            self.stats.active_contacts.append(len(active))
            for ci in active:
                a, b = self.candidates[ci]
                _CONTACT.launch(
                    stream,
                    inputs=(grp.state,),
                    outputs=(grp.cf.row_view(ci, 1),),
                    static_args=(a, b, spec.radius, _KC),
                )

            _GROUND.launch(
                stream, inputs=(grp.state,), outputs=(grp.gf,),
                static_args=(spec.radius, _KG),
            )

            parents = tuple(p for p, _ in spec.joints)
            children = tuple(c for _, c in spec.joints)
            pa = tuple(self.candidates[ci][0] for ci in active)
            pb = tuple(self.candidates[ci][1] for ci in active)
            _INTEGRATE.launch(
                stream,
                inputs=(grp.state, grp.jf, grp.gf) + tuple(grp.cf.row_view(ci, 1) for ci in active),
                outputs=(grp.state,),
                static_args=(parents, children, pa, pb, len(active), spec.mass, self.dt),
            )
            _OBSERVE.launch(stream, inputs=(grp.state,), outputs=(grp.obs,))

        self.stats.kernels = len(stream.tasks)
        self.stats.steps += 1
        self.stats.candidate_contacts = len(self.candidates)
        self._step_index += 1

    def emit_batch(self, stream: TaskStream, n_steps: int,
                   policy: Optional[Callable] = None) -> None:
        for _ in range(n_steps):
            self.emit_step(stream, policy)

    def record_kernel_sizes(self, stream: TaskStream) -> None:
        from ..core.task import operand_shape

        for t in stream.tasks:
            elems = sum(int(np.prod(operand_shape(o))) for o in t.outputs)
            self.stats.elements.append(elems)

    def buffers(self) -> Tuple[Buffer, ...]:
        """All live allocations (states, force accumulators, controls) in
        allocation order — what the device runner's slab arena packs."""
        return self.pool.buffers()

    def state_snapshot(self) -> np.ndarray:
        return np.concatenate([np.asarray(g.state.value) for g in self.groups], axis=0)
