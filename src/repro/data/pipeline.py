"""Synthetic LM data pipeline with exactly-resumable state.

Production properties the trainer relies on:
* **Determinism** — batch ``i`` of shard ``s`` is a pure function of
  (seed, s, i): restart-safe, and every DP replica can derive its own
  shard without coordination.
* **Resumability** — a :class:`DataCursor` (step, shard) is stored inside
  every checkpoint; ``seek`` is O(1) (counter-based PRNG, no state replay).
* **Shardability** — ``n_shards`` mirrors the DP group count; elastic
  restarts with a different DP degree re-shard by reassigning shard ids.

Tokens follow a Zipfian marginal with a Markov twist so the loss signal is
learnable (cross-entropy drops measurably within a few hundred steps on
the ~100M example run — examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

__all__ = ["DataCursor", "TokenPipeline"]


@dataclasses.dataclass
class DataCursor:
    step: int = 0
    shard: int = 0

    def as_dict(self):
        return {"step": self.step, "shard": self.shard}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]), shard=int(d["shard"]))


class TokenPipeline:
    def __init__(self, vocab: int, seq_len: int, batch: int, *,
                 seed: int = 0, n_shards: int = 1, shard: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.n_shards = n_shards
        self.cursor = DataCursor(step=0, shard=shard)
        # Zipf-ish unigram + shift-mix transition (learnable structure)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._p = (1.0 / ranks ** 1.1)
        self._p /= self._p.sum()

    def seek(self, cursor: DataCursor) -> None:
        self.cursor = DataCursor(cursor.step, cursor.shard)

    def _rng_for(self, step: int) -> np.random.Generator:
        # counter-based: (seed, shard, step) -> independent stream
        return np.random.Generator(
            np.random.Philox(key=self.seed, counter=[self.cursor.shard, step, 0, 0])
        )

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (inputs, labels) int32 [batch, seq_len]."""
        rng = self._rng_for(self.cursor.step)
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len + 1), p=self._p)
        # Markov structure: token depends on predecessor half the time
        mix = rng.random((self.batch, self.seq_len)) < 0.5
        shifted = (toks[:, :-1] * 31 + 7) % self.vocab
        toks[:, 1:][mix] = shifted[mix]
        toks = toks.astype(np.int32)
        self.cursor.step += 1
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()
