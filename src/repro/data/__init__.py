"""Deterministic, resumable synthetic token pipeline."""

from .pipeline import DataCursor, TokenPipeline

__all__ = ["DataCursor", "TokenPipeline"]
