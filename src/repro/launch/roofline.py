"""Roofline analysis (deliverable g).

Three terms per (arch x shape) on the single-pod mesh, TPU v5e constants:

    compute    = HLO_FLOPs_per_device / 197e12          [s]
    memory     = HLO_bytes_per_device / 819e9           [s]
    collective = wire_bytes_per_device / 50e9            [s]

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes;
``compiled.as_text()`` (post-SPMD HLO) for collective ops. Two measured
caveats, both handled here:

1. XLA's cost analysis and the HLO text count a ``while`` body ONCE, not
   per trip (verified empirically) — so per-cell terms are derived from
   UNROLLED lowerings of 1-stage and 2-stage configs:
       per_stage = X(2 stages) - X(1 stage)
       total     = X(1 stage) + per_stage * (n_stages - 1)
   which is exact because body stages are identical.
2. Wire bytes per collective use ring-algorithm estimates:
   all-reduce 2x, all-gather/reduce-scatter/all-to-all/permute 1x the
   largest operand (the (n-1)/n factor is ~1 at n=16..512).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import jax

PEAK_FLOPS = 197e12   # bf16 / chip (TPU v5e)
HBM_BW = 819e9        # B/s / chip
LINK_BW = 50e9        # B/s / chip ICI

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _line_max_bytes(line: str) -> int:
    best = 0
    for dt, dims in _SHAPE_RE.findall(line):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best


def collective_bytes_from_text(text: str) -> Dict[str, float]:
    """Per-collective-kind wire-byte estimate from post-SPMD HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match op invocations (e.g. "all-reduce(", "all-gather-start(")
            if f"{kind}(" in stripped or f"{kind}-start(" in stripped:
                size = _line_max_bytes(stripped)
                mult = 2.0 if kind == "all-reduce" else 1.0
                out[kind] += mult * size
                counts[kind] += 1
                break
    out["total_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        if self.model_flops and self.flops_per_device:
            return self.model_flops / self.flops_per_device
        return None

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_per_device": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
        }


def roofline_terms(flops: float, bytes_: float, wire_bytes: float,
                   model_flops: float = 0.0) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=wire_bytes / LINK_BW,
        flops_per_device=flops,
        bytes_per_device=bytes_,
        wire_bytes_per_device=wire_bytes,
        model_flops=model_flops,
    )


def analyze_unrolled(cfg, mesh, shape_name, shapes, bundle_cls):
    """Exact per-cell terms via the 1-stage/2-stage unrolled differencing."""
    import dataclasses as dc

    from ..models.transformer import split_pattern, unrolled_stages

    prefix, n_stages = split_pattern(cfg)
    unit = len(cfg.pattern_unit)

    def measure(n_layers_small: int) -> Dict[str, float]:
        small = dc.replace(cfg, name=cfg.name, n_layers=n_layers_small)
        bundle = bundle_cls(small, mesh)
        with unrolled_stages():
            compiled = bundle.lower(shape_name, shapes).compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes_from_text(compiled.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": float(coll["total_bytes"]),
        }

    n1 = len(prefix) + unit
    n2 = len(prefix) + 2 * unit
    m1 = measure(n1)
    m2 = measure(n2)
    total = {
        k: m1[k] + (m2[k] - m1[k]) * (n_stages - 1) for k in m1
    }
    return total, m1, m2
