"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
device use, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
