"""Mesh definitions — training pods AND the sharded scheduling window.

Every factory here is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state: dry-runs and the
mesh-window tests must set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first
device use, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_window_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """All local devices on a 1-D ``data`` axis (tests/examples).

    Previously ``(n, 1)`` over ``("data", "model")`` — the trailing
    unit ``model`` axis hid the actual device count from consumers that
    factorize the mesh by axis shape, and window sharding wants the flat
    device list. ``parallel.sharding`` treats a missing ``model`` axis as
    tensor-parallel degree 1, so training specs are unaffected.
    """
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_window_mesh(n: Optional[int] = None) -> jax.sharding.Mesh:
    """The scheduling-window mesh: ``n`` devices on a 1-D ``"window"``
    axis, each owning one slab-arena shard of a mesh-sharded
    :class:`~repro.core.mesh_session.MeshDeviceSession`. ``n=None`` takes
    every visible device (under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` that is the
    forced host-device count — the dev/CI path)."""
    devs = jax.devices()
    if n is None:
        n = len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"window mesh wants {n} devices but {len(devs)} are visible")
    return jax.sharding.Mesh(devs[:n], ("window",))
