import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Roofline driver (deliverable g): per (arch x shape) on the single-pod
mesh, derive the three roofline terms from UNROLLED 1-stage/2-stage
lowerings (launch/roofline.py) plus MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) per device, and write results/roofline.json.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline_run --arch X --shape Y
  PYTHONPATH=src python -m repro.launch.roofline_run --all [--skip-done]
"""

import argparse
import json
import time
from pathlib import Path

from ..configs import ARCHS, SHAPES, cells, get_config
from .mesh import make_production_mesh
from .roofline import analyze_unrolled, roofline_terms
from .steps import StepBundle

RESULTS = Path(__file__).resolve().parents[3] / "results" / "roofline.json"


def model_flops_per_device(cfg, shape_name, n_devices: int) -> float:
    """6*N*D useful-FLOPs accounting (N_active for MoE), per device."""
    seq, batch, kind = SHAPES[shape_name]
    n = cfg.n_active_params if cfg.moe is not None else cfg.n_params
    if kind == "train":
        tokens = seq * batch          # fwd+bwd: 6 N D
        factor = 6.0
    elif kind == "prefill":
        tokens = seq * batch          # fwd only: 2 N D
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = batch
        factor = 2.0
    return factor * n * tokens / n_devices


def run_cell(arch: str, shape: str, verbose=True):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    total, m1, m2 = analyze_unrolled(cfg, mesh, shape, SHAPES, StepBundle)
    mf = model_flops_per_device(cfg, shape, mesh.devices.size)
    terms = roofline_terms(total["flops"], total["bytes"], total["wire"],
                           model_flops=mf)
    record = {
        "arch": arch,
        "shape": shape,
        "analysis_s": round(time.time() - t0, 1),
        **terms.as_dict(),
        "one_stage": m1,
        "two_stage": m2,
    }
    if verbose:
        print(json.dumps(record, indent=2))
    return record


def save(record):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}
    data[f'{record["arch"]}|{record["shape"]}'] = record
    RESULTS.write_text(json.dumps(data, indent=1, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    done = set(json.loads(RESULTS.read_text())) if (
        args.skip_done and RESULTS.exists()) else set()

    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    failures = []
    for arch in archs:
        shapes = cells(get_config(arch)) if args.all or not args.shape else [args.shape]
        for shape in shapes:
            if f"{arch}|{shape}" in done:
                continue
            print(f"=== {arch} x {shape}", flush=True)
            try:
                rec = run_cell(arch, shape, verbose=False)
                save(rec)
                print(f"    dominant={rec['dominant']} "
                      f"compute={rec['compute_s']:.4f}s "
                      f"memory={rec['memory_s']:.4f}s "
                      f"collective={rec['collective_s']:.4f}s "
                      f"useful={rec['useful_flops_fraction']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"    FAIL {e!r}", flush=True)
    if failures:
        print(f"{len(failures)} failures")
        for f in failures:
            print(" ", f[0], f[1], f[2][:160])


if __name__ == "__main__":
    main()
