import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimbing driver: apply named optimization steps to the three
chosen cells, re-derive the roofline terms after each, and append the
hypothesis -> change -> before -> after record to
results/perf_iterations.json (the §Perf log in EXPERIMENTS.md).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell minicpm-2b/train_4k
    PYTHONPATH=src python -m repro.launch.hillclimb --all
"""

import argparse
import contextlib
import dataclasses
import json
import time
from pathlib import Path

from ..configs import SHAPES, get_config
from ..models.transformer import remat_policy
from .mesh import make_production_mesh
from .roofline import analyze_unrolled, roofline_terms
from .roofline_run import model_flops_per_device
from .steps import StepBundle

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf_iterations.json"


def _pad_heads(cfg, n):
    return dataclasses.replace(cfg, pad_heads_to=n)


def _bf16_combine(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, combine_dtype="bfloat16")
    )


def _capacity(cfg, f):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=f)
    )


def _grouped_dispatch(cfg, g):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=g)
    )


# Each step: (name, hypothesis, config transform, remat policy)
PLANS = {
    "minicpm-2b/train_4k": [
        ("pad_heads_48",
         "36 heads don't divide TP=16, so attention is sequence-sharded: "
         "k/v all-gathers across 'model' every layer (fwd+bwd+remat) "
         "dominate the 9.3s collective term. Padding heads to 48 (zero "
         "heads, numerics-exact) shards attention 16-way: predicted "
         "collective -> ~1/2 (the gathers go away, Megatron psums remain), "
         "useful-FLOPs up from 0.42 (replicated attention eliminated).",
         lambda c: _pad_heads(c, 48), "nothing"),
        ("remat_dots",
         "nothing_saveable recomputes every stage fwd in bwd INCLUDING its "
         "collectives (~1.5x collective traffic). Saving dot outputs skips "
         "recompute of GEMMs + their psums: predicted collective -1/3, "
         "compute term -~25%, at higher activation memory.",
         lambda c: c, "dots"),
    ],
    "granite-moe-3b-a800m/train_4k": [
        ("pad_heads_32",
         "24 heads vs TP=16: same sequence-shard fallback as minicpm; "
         "attention replication also poisons useful-FLOPs (0.295). Pad to "
         "32: predicted collective down ~30%, useful up ~1.5x.",
         lambda c: _pad_heads(c, 32), "nothing"),
        ("bf16_combine",
         "The MoE output combine (scatter-add over the TP-sharded expert "
         "axis) is the layer's psum and currently rides f32: [T,d] x 59 "
         "layers x fwd/bwd. bf16 wire format halves those bytes: predicted "
         "collective -~40% of the MoE share.",
         _bf16_combine, "nothing"),
        ("remat_dots",
         "As for minicpm: skip bwd recompute of expert GEMMs and their "
         "combines; predicted collective -~1/3.",
         lambda c: c, "dots"),
        ("grouped_dispatch_16",
         "PROFILE FINDING (refutes the two hypotheses above): the dominant "
         "collective is a 4.8GB f32 all-reduce of [E_loc, C, d] with "
         "C = 262144 — expert dispatch runs over the GLOBAL token axis, so "
         "every device carries 16x more dispatch rows than its own tokens "
         "and GSPMD reduces them across the mesh. Routing within 16 "
         "batch-aligned groups (= DP degree) makes gather/compute/combine "
         "shard-local; predicted collective -> ~1/4.",
         lambda c: _grouped_dispatch(c, 16), "dots"),
    ],
    "deepseek-v2-236b/train_4k": [
        ("bf16_combine",
         "deepseek train is the most collective-bound cell (151.9s vs "
         "6.9s compute). The dominant stream is the expert-combine psum "
         "([32k, 5120] f32 x 59 MoE layers x fwd+bwd+remat). bf16 combine "
         "halves it: predicted collective -> ~90-110s.",
         _bf16_combine, "nothing"),
        ("remat_dots",
         "Remat recompute doubles fwd-side collectives in bwd. Saving dot "
         "outputs removes the recomputed gathers/psums: predicted "
         "collective -~30%, memory term rises (acceptable: HBM has slack "
         "in memory_analysis).",
         lambda c: c, "dots"),
        ("capacity_1.0",
         "Capacity factor 1.25 inflates every expert GEMM and its gather/"
         "combine rows by 25%. cf=1.0 trades marginal router-overflow "
         "drops for a uniform 20% cut of MoE compute AND combine bytes.",
         lambda c: _capacity(c, 1.0), "dots"),
        ("grouped_dispatch_16",
         "Same profile finding as granite: dispatch over the global token "
         "axis carries DPx redundant rows through every device. Group-"
         "local dispatch (16 batch-aligned groups) shards the whole MoE "
         "block over (dp, tp); predicted collective -> well under half.",
         lambda c: _grouped_dispatch(c, 16), "dots"),
    ],
}


def measure(cfg, shape, policy_name):
    mesh = make_production_mesh(multi_pod=False)
    ctx = remat_policy(policy_name)
    with ctx:
        total, _, _ = analyze_unrolled(cfg, mesh, shape, SHAPES, StepBundle)
    mf = model_flops_per_device(cfg, shape, mesh.devices.size)
    return roofline_terms(total["flops"], total["bytes"], total["wire"],
                          model_flops=mf)


def run_cell(cell: str):
    arch, shape = cell.split("/")
    base_cfg = get_config(arch)

    data = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}
    log = data.get(cell, [])
    done = {e["step"] for e in log}

    if "baseline" not in done:
        t = measure(base_cfg, shape, "nothing")
        log.append({"step": "baseline", "hypothesis": "(paper-faithful baseline)",
                    **t.as_dict()})
        print(f"[{cell}] baseline: {t.as_dict()}", flush=True)

    cfg = base_cfg
    policy = "nothing"
    for name, hypothesis, transform, pol in PLANS[cell]:
        cfg = transform(cfg)
        policy = pol
        if name in done:
            continue
        t0 = time.time()
        t = measure(cfg, shape, policy)
        rec = {"step": name, "hypothesis": hypothesis, "analysis_s":
               round(time.time() - t0, 1), **t.as_dict()}
        log.append(rec)
        print(f"[{cell}] {name}: dominant={t.dominant} "
              f"c={t.compute_s:.3f} m={t.memory_s:.3f} x={t.collective_s:.3f} "
              f"useful={t.useful_flops_fraction:.3f}", flush=True)
        data[cell] = log
        RESULTS.parent.mkdir(parents=True, exist_ok=True)
        RESULTS.write_text(json.dumps(data, indent=1))
    data[cell] = log
    RESULTS.write_text(json.dumps(data, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    cells = list(PLANS) if args.all or not args.cell else [args.cell]
    for cell in cells:
        run_cell(cell)


if __name__ == "__main__":
    main()
