import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e): for every (arch x shape x mesh) cell,
``jit(step).lower(**input_specs).compile()`` must succeed on the production
meshes — (16, 16) single pod and (2, 16, 16) = 512 chips multi-pod. Records
memory_analysis / cost_analysis / per-collective byte counts to a JSON
results file consumed by EXPERIMENTS.md and launch/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

The XLA_FLAGS line above must execute before ANY other jax import — jax
locks the device count at first init (and smoke tests must keep seeing one
device, so this is NOT in conftest/pyproject).
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax

from ..configs import ARCHS, SHAPES, cells, get_config
from .mesh import make_production_mesh
from .roofline import collective_bytes_from_text, roofline_terms
from .steps import StepBundle

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun.json"


def run_cell(arch: str, shape: str, multi_pod: bool, *, verbose: bool = True):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = StepBundle(cfg, mesh)
    t0 = time.time()
    lowered = bundle.lower(shape, SHAPES)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes_from_text(text)

    def _get(obj, name):
        v = getattr(obj, name, None)
        return int(v) if v is not None else None

    record = {
        "arch": arch,
        "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": _get(mem, "argument_size_in_bytes"),
            "output_bytes": _get(mem, "output_size_in_bytes"),
            "temp_bytes": _get(mem, "temp_size_in_bytes"),
            "generated_code_bytes": _get(mem, "generated_code_size_in_bytes"),
        },
    }
    if verbose:
        print(json.dumps(record, indent=2))
        print(compiled.memory_analysis())
    return record


def save(record):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if RESULTS.exists():
        data = json.loads(RESULTS.read_text())
    key = f'{record["arch"]}|{record["shape"]}|{record["mesh"]}'
    data[key] = record
    RESULTS.write_text(json.dumps(data, indent=1, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.skip_done and RESULTS.exists():
        done = set(json.loads(RESULTS.read_text()))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    todo = []
    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    for arch in archs:
        shapes = cells(get_config(arch)) if args.all or not args.shape else [args.shape]
        for shape in shapes:
            for mp in meshes:
                key = f'{arch}|{shape}|{"pod2x16x16" if mp else "16x16"}'
                if key in done:
                    continue
                todo.append((arch, shape, mp))

    failures = []
    for arch, shape, mp in todo:
        tag = f'{arch} x {shape} x {"multi" if mp else "single"}'
        print(f"=== {tag}", flush=True)
        try:
            record = run_cell(arch, shape, mp, verbose=False)
            save(record)
            print(f"    ok: compile {record['compile_s']}s, "
                  f"flops/dev {record['flops_per_device']:.3e}, "
                  f"coll {record['collectives']['total_bytes']:.3e} B", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((tag, repr(e)))
            print(f"    FAIL: {e!r}", flush=True)
    if failures:
        print(f"{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        sys.exit(1)
    print(f"dry-run complete: {len(todo)} cells")


if __name__ == "__main__":
    main()
