"""Generate the EXPERIMENTS.md dry-run and roofline tables from
results/dryrun.json and results/roofline.json.

    PYTHONPATH=src python -m repro.launch.report > results/tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table() -> str:
    data = json.loads((ROOT / "results" / "dryrun.json").read_text())
    lines = [
        "| arch | shape | mesh | compile s | flops/dev | HLO bytes/dev | coll bytes/dev | temp mem/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        r = data[key]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {r['flops_per_device']:.2e} | {fmt_bytes(r['bytes_per_device'])} "
            f"| {fmt_bytes(r['collectives']['total_bytes'])} "
            f"| {fmt_bytes(r['memory']['temp_bytes'])} |"
        )
    n = len(data)
    return f"{n} cells, all `.lower().compile()` OK.\n\n" + "\n".join(lines)


def roofline_table() -> str:
    data = json.loads((ROOT / "results" / "roofline.json").read_text())
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful-FLOPs frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        r = data[key]
        uf = r.get("useful_flops_fraction")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['dominant']}** | {uf:.3f} |" if uf else
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['dominant']}** | - |"
        )
    return "\n".join(lines)


def perf_table() -> str:
    data = json.loads((ROOT / "results" / "perf_iterations.json").read_text())
    out = []
    for cell in sorted(data):
        out.append(f"\n### {cell}\n")
        out.append("| step | compute s | memory s | collective s | dominant | useful |")
        out.append("|---|---|---|---|---|---|")
        for e in data[cell]:
            uf = e.get("useful_flops_fraction") or 0
            out.append(
                f"| {e['step']} | {e['compute_s']:.3f} | {e['memory_s']:.3f} "
                f"| {e['collective_s']:.3f} | {e['dominant']} | {uf:.3f} |"
            )
    return "\n".join(out)


def main():
    print("## Dry-run table\n")
    try:
        print(dryrun_table())
    except FileNotFoundError:
        print("(results/dryrun.json missing — run repro.launch.dryrun)")
    print("\n## Roofline table\n")
    try:
        print(roofline_table())
    except FileNotFoundError:
        print("(results/roofline.json missing — run repro.launch.roofline_run)")
    print("\n## Perf iterations (hillclimb)\n")
    try:
        print(perf_table())
    except FileNotFoundError:
        print("(results/perf_iterations.json missing — run repro.launch.hillclimb)")


if __name__ == "__main__":
    main()
