"""Distributed training launcher: mesh + StepBundle + sharded pipeline.

On real hardware this is the per-host entry point (`python -m
repro.launch.train --arch gemma2-27b --multi-pod`); on this container it
runs the same code path end-to-end on the degenerate local mesh with a
reduced config (--smoke), exercising sharded params, the policy
constraints, checkpointing and the data pipeline together.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke --steps 10
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..data import DataCursor, TokenPipeline
from ..models import init_params
from ..optim import adamw_init, cosine_schedule
from ..parallel import use_policy
from .mesh import make_local_mesh, make_production_mesh
from .steps import StepBundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU end-to-end)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default="results/ckpt_launch_train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    bundle = StepBundle(cfg, mesh, lr=args.lr)
    pol = bundle.policy
    schedule = cosine_schedule(args.lr, warmup=5, total=args.steps)

    with use_policy(pol):
        params = init_params(cfg, jax.random.PRNGKey(0), tp_size=pol.tp_size)
        opt = adamw_init(params)

    ckpt = CheckpointManager(Path(args.ckpt))
    pipeline = TokenPipeline(cfg.vocab, args.seq, args.batch, seed=0)
    state = {"params": params, "opt": opt}
    restored = ckpt.restore_latest(state)
    start = 0
    if restored is not None:
        state, extras = restored
        pipeline.seek(DataCursor.from_dict(extras["cursor"]))
        start = int(extras["step"]) + 1
        print(f"resumed at step {start}")

    def step_fn(params, opt, inputs, labels):
        return bundle.train_step(params, opt, inputs, labels)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    with use_policy(pol):
        for step in range(start, args.steps):
            inputs, labels = pipeline.next_batch()
            t0 = time.perf_counter()
            params, opt, m = jit_step(
                state["params"], state["opt"],
                jnp.asarray(inputs), jnp.asarray(labels),
            )
            state = {"params": params, "opt": opt}
            dt = (time.perf_counter() - t0) * 1e3
            print(f"step {step}: loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['gnorm']):.2f} {dt:.0f}ms", flush=True)
    ckpt.save(args.steps - 1, state,
              extras={"cursor": pipeline.cursor.as_dict()})
    print("done; checkpoint saved")


if __name__ == "__main__":
    main()
