"""Step builders shared by the dry-run, the trainer, and the server:
given (arch config, mesh) produce the jittable step functions plus the
ShapeDtypeStruct input stand-ins and sharding trees for every assigned
input shape. No device allocation happens here (dry-run requirement)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import (
    decode_step as model_decode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill as model_prefill,
)
from ..models.config import ArchConfig
from ..models.layers import DTYPES
from ..models.transformer import FRONTEND_DIMS
from ..optim import adamw_init, adamw_update, clip_by_global_norm, opt_specs
from ..parallel import batch_specs, cache_specs, param_specs, policy_for, use_policy

__all__ = ["StepBundle", "build_bundle", "input_specs"]


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def input_specs(cfg: ArchConfig, shape_name: str, shapes: Dict[str, Tuple[int, int, str]],
                *, batch_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    seq, batch, kind = shapes[shape_name]
    if batch_override:
        batch = batch_override
    dtype = DTYPES[cfg.dtype]
    if cfg.frontend:
        tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)  # labels/audio ids
        inp = jax.ShapeDtypeStruct(
            (batch, seq, FRONTEND_DIMS[cfg.frontend]), dtype
        )
    else:
        inp = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        tok = inp
    if kind == "train":
        return {"inputs": inp, "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if kind == "prefill":
        cache = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
        return {"inputs": inp, "cache": cache}
    if kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
        one = (
            jax.ShapeDtypeStruct((batch, 1, FRONTEND_DIMS[cfg.frontend]), dtype)
            if cfg.frontend else jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        )
        return {"inputs": one, "cache": cache,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(kind)


class StepBundle:
    """Jittable steps + sharding trees for one (arch, mesh)."""

    def __init__(self, cfg: ArchConfig, mesh: jax.sharding.Mesh,
                 lr: float = 3e-4, clip: float = 1.0):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy_for(cfg, mesh)
        tp = self.policy.tp_size
        self.param_shapes = jax.eval_shape(
            lambda k: init_params(cfg, k, tp_size=tp), jax.random.PRNGKey(0)
        )
        self.pspecs = param_specs(self.param_shapes, self.policy)
        self.opt_shapes = jax.eval_shape(adamw_init, self.param_shapes)
        dp_size = int(np.prod([
            mesh.devices.shape[i] for i, a in enumerate(mesh.axis_names)
            if a != "model"
        ]))
        self.ospecs = opt_specs(self.pspecs, self.policy.dp, dp_size,
                                self.opt_shapes["master"])
        self.lr = lr
        self.clip = clip

    def sharding(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    # -- steps ---------------------------------------------------------------
    def train_step(self, params, opt_state, inputs, labels):
        cfg = self.cfg
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, inputs, labels)
        )(params)
        grads, gnorm = clip_by_global_norm(grads, self.clip)
        new_params, new_opt = adamw_update(
            params, grads, opt_state, jnp.asarray(self.lr, jnp.float32)
        )
        return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

    def prefill_step(self, params, inputs, cache):
        return model_prefill(params, self.cfg, inputs, cache)

    def decode_step(self, params, inputs, cache, pos):
        return model_decode(params, self.cfg, inputs, cache, pos)

    # -- lowering ------------------------------------------------------------
    def lower(self, shape_name: str, shapes, *, batch_override=None,
              donate: bool = True):
        """Lower the cell's step with full sharding trees. Returns Lowered."""
        cfg = self.cfg
        specs = input_specs(cfg, shape_name, shapes, batch_override=batch_override)
        kind = shapes[shape_name][2]
        batch = specs["inputs"].shape[0]
        # per-cell policy: long_500k's batch=1 cannot shard over dp
        pol = policy_for(cfg, self.mesh, batch=batch)
        dp = pol.dp if pol.batch_shardable else ()
        in_spec = P(dp, None, None) if cfg.frontend else P(dp, None)

        with use_policy(pol):
            if kind == "train":
                fn = jax.jit(
                    self.train_step,
                    in_shardings=(
                        self.sharding(self.pspecs), self.sharding(self.ospecs),
                        NamedSharding(self.mesh, in_spec),
                        NamedSharding(self.mesh, P(dp, None)),
                    ),
                    out_shardings=(
                        self.sharding(self.pspecs), self.sharding(self.ospecs),
                        None,
                    ),
                    donate_argnums=(0, 1) if donate else (),
                )
                return fn.lower(self.param_shapes, self.opt_shapes,
                                specs["inputs"], specs["labels"])
            cspecs = cache_specs(cfg, pol)
            if kind == "prefill":
                fn = jax.jit(
                    self.prefill_step,
                    in_shardings=(
                        self.sharding(self.pspecs),
                        NamedSharding(self.mesh, in_spec),
                        self.sharding(cspecs),
                    ),
                    out_shardings=(None, self.sharding(cspecs)),
                    donate_argnums=(2,) if donate else (),
                )
                return fn.lower(self.param_shapes, specs["inputs"], specs["cache"])
            fn = jax.jit(
                self.decode_step,
                in_shardings=(
                    self.sharding(self.pspecs),
                    NamedSharding(self.mesh, in_spec),
                    self.sharding(cspecs),
                    NamedSharding(self.mesh, P()),
                ),
                out_shardings=(None, self.sharding(cspecs)),
                donate_argnums=(2,) if donate else (),
            )
            return fn.lower(self.param_shapes, specs["inputs"],
                            specs["cache"], specs["pos"])
