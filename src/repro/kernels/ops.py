"""Public jit'd dispatchers for the Pallas kernels.

Each op chooses between the Pallas kernel (TPU, or interpret-mode for
validation) and the pure-jnp oracle in ``ref.py`` (the XLA path used by
the CPU dry-run lowering and any backend without Pallas support).
Set ``use_pallas=False`` to force the reference path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .grouped_matmul import grouped_matmul as _gmm
from .lru_scan import lru_scan as _lru
from .wave_elementwise import apply_wave, wave_elementwise as _wave

__all__ = ["attention", "grouped_matmul", "lru_scan", "wave_step",
           "register_device_ops", "LOOP_BRANCHES", "register_loop_branches"]


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
              q_offset=0, prefix_len=0, use_pallas: Optional[bool] = None,
              **block_kw):
    """Multi-head attention with GQA/causal/local/prefix/softcap (see ref)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      scale=scale, q_offset=q_offset, prefix_len=prefix_len,
                      **block_kw)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, q_offset=q_offset,
                             prefix_len=prefix_len)


def grouped_matmul(x, w, tile_groups, *, block_m=128,
                   use_pallas: Optional[bool] = None, **block_kw):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _gmm(x, w, tile_groups, block_m=block_m, **block_kw)
    return ref.grouped_matmul_ref(x, w, tile_groups, block_m=block_m)


def lru_scan(a, b, h0, *, use_pallas: Optional[bool] = None, **block_kw):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _lru(a, b, h0, **block_kw)
    return ref.lru_scan_ref(a, b, h0)


def register_device_ops(registry) -> dict:
    """Register the Pallas-backed kernel dispatchers as device opcodes so
    streams built from :class:`~repro.core.AcsKernel`s named after them
    lower through the slab arena (fn-less entries: the arena path runs the
    wrapper-resolved callable, which already routes Pallas vs the jnp
    oracle via ``use_pallas``). Returns name -> opcode."""
    return {
        name: registry.register(name)
        for name in ("attention", "grouped_matmul", "lru_scan")
    }


def _axpy_row(x, y):
    return 1.5 * x + y + 1.0


def _mul_row(x, y):
    return x * y - 0.5


# The device ready-queue's fixed kernel table (kernels/ready_queue.py):
# elementwise row-shape-preserving branches the on-device lax.switch may
# dispatch. These ARE the fns the benchmark/test mixed-tag streams launch
# — fast-path eligibility checks fn identity against this table, so the
# switch can never silently diverge from the host execution.
LOOP_BRANCHES = {"axpy": _axpy_row, "mul": _mul_row}


def register_loop_branches(registry) -> dict:
    """Admit :data:`LOOP_BRANCHES` to a device registry's switch table
    (the ready-queue Pallas fast path). Returns name -> opcode."""
    return {name: registry.register_switch_branch(name, fn)
            for name, fn in LOOP_BRANCHES.items()}


def wave_step(slab, desc, *, branches, use_pallas: Optional[bool] = None):
    """Execute one ACS wave of elementwise tasks over the row slab and
    scatter the results back (see wave_elementwise.py)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        rows = _wave(slab, desc, branches=branches)
    else:
        rows = jnp.stack([
            jax.lax.switch(desc[i, 0], branches, slab[desc[i, 1]], slab[desc[i, 2]])
            for i in range(desc.shape[0])
        ])
    return apply_wave(slab, desc, rows)
