"""Blocked online-softmax attention (flash attention) for TPU.

One Pallas program per (batch, head, q-block); the k-dimension is the
innermost grid axis so the VMEM scratch accumulators (m, l, acc) persist
across k-blocks — the standard TPU flash pattern. Supports:

* GQA (kv-head sharing) via the k/v index map (h -> h // group),
* causal masking with a global ``q_offset`` (decode: Sq=1 against a long
  KV cache),
* local / sliding-window masking (recurrentgemma, h2o-danube, gemma2
  local layers),
* gemma2-style logit softcapping,
* ragged kv length (``kv_len``) so callers can pad Sk to the block size.

Block shapes are MXU-aligned by default (128 x 128 tiles; D is the lane
dimension). VMEM working set per program:
``bq*D (q) + bk*D (k) + bk*D (v) + bq*D (acc) + 2*bq`` floats — with
bq=bk=128, D=128 that is ~0.26 MB, far under the ~16 MB VMEM budget,
leaving headroom for double buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, q_offset, prefix_len, kv_len,
    block_q, block_k, nk,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)  # [bk, D]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qi = pl.program_id(2)
    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if prefix_len:
        mask |= kpos < prefix_len
    mask &= kpos < kv_len
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [bq, bk]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = jnp.where(l == 0.0, 0.0, acc_scr[...] / safe)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "q_offset", "prefix_len",
        "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    prefix_len: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    nq, nk = sq_pad // block_q, sk_pad // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, prefix_len=prefix_len, kv_len=sk,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
