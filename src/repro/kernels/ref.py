"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel's interpret-mode output is
``assert_allclose``'d against these across shape/dtype sweeps (tests/).
They are also the XLA fallback path used on non-TPU backends (and thus the
path the dry-run lowers — DESIGN.md §7).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "attention_ref",
    "grouped_matmul_ref",
    "lru_scan_ref",
    "wave_elementwise_ref",
]


def attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, Dv]
    *,
    causal: bool = True,
    window: Optional[int] = None,   # local/sliding window size (keys kept)
    softcap: Optional[float] = None,  # gemma2-style logit soft capping
    scale: Optional[float] = None,
    q_offset: int = 0,  # global position of q[0] (decode: Sk - Sq)
    prefix_len: int = 0,  # prefix-LM: first N keys visible to everyone (vlm)
) -> jax.Array:
    """Masked softmax attention with GQA, causal/local/prefix masks, softcap.

    GQA is computed via a grouped einsum (q reshaped to [B, Hkv, G, Sq, D])
    — no K/V repeat materialization, so a decode step's HLO bytes reflect
    the true KV-cache traffic.
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = (1.0 / np.sqrt(d)) if scale is None else scale

    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkld->bkgql", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    rows = q_offset + jnp.arange(sq)[:, None]  # global q positions
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    if prefix_len:
        mask |= cols < prefix_len
    s = jnp.where(mask[None, None, None], s, -jnp.inf)

    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> zeros
    out = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def grouped_matmul_ref(
    x: jax.Array,          # [M, K] rows sorted by group, padded per group
    w: jax.Array,          # [G, K, N]
    tile_groups: jax.Array,  # [M // bm] int32: group id of each m-tile
    *,
    block_m: int,
) -> jax.Array:
    """Ragged grouped GEMM oracle: out[t] = x[t] @ w[tile_groups[t]]."""
    m, k = x.shape
    g, _, n = w.shape
    n_tiles = m // block_m
    xt = x.reshape(n_tiles, block_m, k)
    wt = w[tile_groups]  # [T, K, N]
    out = jnp.einsum("tmk,tkn->tmn", xt.astype(jnp.float32), wt.astype(jnp.float32))
    return out.reshape(m, n).astype(x.dtype)


def lru_scan_ref(
    a: jax.Array,   # [B, S, D] decay
    b: jax.Array,   # [B, S, D] input
    h0: jax.Array,  # [B, D]
) -> jax.Array:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t (RG-LRU/SSM)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    def per_batch(a1, b1, h01):
        _, hs = jax.lax.scan(step, h01.astype(jnp.float32), (a1.astype(jnp.float32), b1.astype(jnp.float32)))
        return hs

    out = jax.vmap(per_batch)(a, b, h0)
    return out.astype(b.dtype)


def wave_elementwise_ref(slab, opcodes, in_ids, out_ids, branches):
    """One ACS wave of elementwise tasks over a row slab (python loop oracle)."""
    new = slab
    src = slab
    for i in range(opcodes.shape[0]):
        op = int(opcodes[i])
        x = src[int(in_ids[i, 0])]
        y = src[int(in_ids[i, 1])]
        res = branches[op](x, y)
        new = new.at[int(out_ids[i])].set(res)
    return new
