"""Pallas TPU kernels for the compute hot-spots (+ jnp oracles in ref.py).

flash_attention  — blocked online-softmax attention (GQA/causal/local/softcap)
grouped_matmul   — ragged grouped GEMM: a wave of small GEMMs in one launch
lru_scan         — chunked linear recurrence (RG-LRU / Mamba SSM)
wave_elementwise — descriptor-table megakernel for ACS-HW elementwise waves
"""

from . import ops, ref
from .flash_attention import flash_attention
from .grouped_matmul import grouped_matmul
from .lru_scan import lru_scan
from .wave_elementwise import apply_wave, wave_elementwise

__all__ = [
    "ops", "ref", "flash_attention", "grouped_matmul", "lru_scan",
    "wave_elementwise", "apply_wave",
]
