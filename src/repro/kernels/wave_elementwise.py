"""ACS-HW wave megakernel: one launch executes a whole wave of small
heterogeneous elementwise tasks from a descriptor table.

This is the Pallas analogue of the paper's hardware scheduling window
dispatching ready kernels without host round-trips (Fig 20): the grid
iterates over wave *slots*; each program reads its descriptor (opcode +
operand row ids, scalar-prefetched so the input index maps are data-
dependent), applies the opcode branch, and writes its own output row.
Rows in a slab are VMEM-block sized; tasks in a wave are independent by
construction (the window guarantees it), so slot programs can run in any
order.

The kernel returns the S written rows; ``ops.apply_wave`` scatters them
back into the slab (out-of-place, keeping the functional JAX style).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wave_elementwise", "apply_wave"]


def _wave_kernel(desc_ref, x_ref, y_ref, o_ref, *, branches):
    si = pl.program_id(0)
    op = desc_ref[si, 0]
    x = x_ref[0]
    y = y_ref[0]
    o_ref[0, :] = jax.lax.switch(op, branches, x, y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("branches", "interpret"))
def wave_elementwise(
    slab: jax.Array,      # [R, D] buffer rows
    desc: jax.Array,      # [S, 4] int32: (opcode, in0_row, in1_row, out_row)
    *,
    branches: tuple,      # tuple of fn(x, y) -> [D]
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Returns [S, D]: the result row of each wave slot."""
    s = desc.shape[0]
    r, d = slab.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = pl.pallas_call(
        functools.partial(_wave_kernel, branches=branches),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(s,),
            in_specs=[
                pl.BlockSpec((1, d), lambda si, desc: (desc[si, 1], 0)),
                pl.BlockSpec((1, d), lambda si, desc: (desc[si, 2], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda si, desc: (si, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, d), slab.dtype),
        interpret=interpret,
    )(desc.astype(jnp.int32), slab, slab)
    return out


def apply_wave(slab, desc, out_rows):
    """Scatter wave results back into the slab (out rows are unique within a
    wave — WAW hazards would have serialized the tasks into different waves)."""
    return slab.at[desc[:, 3]].set(out_rows)
