"""Chunked diagonal linear-recurrence scan: h_t = a_t * h_{t-1} + b_t.

Serves RG-LRU (recurrentgemma) and the Mamba SSM's per-channel recurrence
(falcon-mamba). The sequence axis is the innermost grid dimension so the
carry ``h`` lives in VMEM scratch across chunks; within a chunk the
recurrence is an in-register fori_loop over time steps — HBM traffic is
exactly one read of (a, b) and one write of h per element, the memory-
bound optimum for a recurrence (arithmetic intensity ~2 flops/6 bytes).

Shapes: a, b: [B, S, D]; h0: [B, D] -> out h: [B, S, D].
Block: (1, chunk, D) — D is lane-aligned (multiple of 128 for real TPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lru_scan"]


def _lru_kernel(h0_ref, a_ref, b_ref, o_ref, h_scr, *, chunk):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)[None]

    a = a_ref[0].astype(jnp.float32)  # [chunk, D]
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[0, :])
    h_scr[...] = h[None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def lru_scan(
    a: jax.Array,   # [B, S, D] decay
    b: jax.Array,   # [B, S, D] input
    h0: jax.Array,  # [B, D] initial state
    *,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    bsz, s, d = a.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    chunk = min(chunk, s)
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        # pad with a=1, b=0 (identity recurrence) so the carry is unaffected
        a = jnp.pad(a, ((0, 0), (0, s_pad - s), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, s_pad - s), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_lru_kernel, chunk=chunk),
        grid=(bsz, s_pad // chunk),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, si: (bi, 0)),
            pl.BlockSpec((1, chunk, d), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, chunk, d), lambda bi, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda bi, si: (bi, si, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s_pad, d), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(h0, a, b)
    return out[:, :s, :]
