"""Device-resident ready-queue executor — the ACS-HW fast path (DESIGN §2 A3).

The paper's ACS-HW window dispatches a kernel the moment its upstream
count hits zero, entirely inside the accelerator; Atos keeps the same
structure as device-resident task-parallel queues and Jangda et al. key
waits on producer completion flags. This kernel is that loop as ONE
Pallas program:

* the **task table** ``[n, 5] int32`` holds each task's switch branch and
  slab addresses ``(branch, in0, in1, in2, out_row)`` — the SRAM dispatch
  table of Fig 20;
* ``dep_tbl [n, m] int32`` holds forward edges (positions that depend on
  each task, sentinel-padded with ``n``);
* ``remaining`` (the per-task upstream counters), the **ready ring** and
  the per-task **completion flags** live beside the slab; retiring a task
  decrements its dependents' counters and pushes zero-crossings onto the
  ring — no host involvement anywhere in the loop.

A grid-based dispatch (``wave_elementwise``-style prefetched index maps)
cannot express this: index maps are fixed at launch, but the ring's
contents *are* the schedule and only exist as the loop runs. So the whole
epoch executes as a single program (``grid=(1,)``) whose ``fori_loop``
pops exactly ``n`` tasks: program order is topological, so every edge
points forward and the ring can never starve — the i-th iteration always
has a task to pop (property-tested against the serial baseline).

Eligibility is narrow by design — one shape class, padding-free 2-D rows,
arity <= 3, one output, and every kernel fn registered in the device
registry's **switch-branch table** (the fixed HW kernel set). Everything
else runs through the structurally identical ``lax.while_loop``
interpreter in ``core/device_dispatch.py``; both advance the frontier in
one dispatch.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ready_queue_call"]


def _ready_queue_kernel(task_ref, dep_ref, ring0_ref, rem0_ref, tail_ref,
                        slab_in_ref, slab_ref, ring_ref, rem_ref, done_ref,
                        *, branches):
    # Copy inputs into the mutable outputs once; the loop then runs
    # entirely over output refs (no input_output_aliases dependency).
    slab_ref[...] = slab_in_ref[...]
    ring_ref[...] = ring0_ref[...]
    rem_ref[...] = rem0_ref[...]
    done_ref[...] = jnp.zeros_like(done_ref)
    n, m = dep_ref.shape
    one = jnp.ones((1,), done_ref.dtype)

    def body(i, tail):
        # head == i: one pop per iteration; edges point forward in program
        # order, so the ring holds at least i+1 entries by iteration i.
        t = pl.load(ring_ref, (pl.dslice(i, 1),))[0]
        task = pl.load(task_ref, (pl.dslice(t, 1), slice(None)))[0]
        x = pl.load(slab_ref, (pl.dslice(task[1], 1), slice(None)))[0]
        y = pl.load(slab_ref, (pl.dslice(task[2], 1), slice(None)))[0]
        z = pl.load(slab_ref, (pl.dslice(task[3], 1), slice(None)))[0]
        res = jax.lax.switch(task[0], branches, x, y, z)
        pl.store(slab_ref, (pl.dslice(task[4], 1), slice(None)),
                 res.astype(slab_ref.dtype)[None])
        pl.store(done_ref, (pl.dslice(t, 1),), one)
        deps = pl.load(dep_ref, (pl.dslice(t, 1), slice(None)))[0]
        # Retire: decrement each dependent's counter; zero-crossings join
        # the ring at the tail. Sentinel edges (== n) hit the trash slot of
        # `remaining`/`ring` (both sized n+1), never a live counter.
        for j in range(m):
            d = deps[j]
            rem = pl.load(rem_ref, (pl.dslice(d, 1),))[0] - 1
            pl.store(rem_ref, (pl.dslice(d, 1),), rem[None])
            ready = (d < n) & (rem == 0)
            slot = jnp.where(ready, tail, n)
            pl.store(ring_ref, (pl.dslice(slot, 1),), d[None])
            tail = tail + ready.astype(jnp.int32)
        return tail

    jax.lax.fori_loop(0, n, body, tail_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("branches", "interpret"))
def ready_queue_call(
    slab: jax.Array,       # [rows, d] the single shape class's slab
    task_tbl: jax.Array,   # [n, 5] int32 (branch, in0, in1, in2, out_row)
    dep_tbl: jax.Array,    # [n, m] int32 forward edges, sentinel n
    ring0: jax.Array,      # [n+1] int32: initially-ready positions, pad n
    rem0: jax.Array,       # [n+1] int32: in-degrees + one trash slot
    tail0: jax.Array,      # [1] int32: count of initially-ready tasks
    *,
    branches: Tuple[Callable, ...],  # fn(x, y, z) -> [d], arity-normalized
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run one epoch's ready-queue program; returns ``(slab', done)``
    where ``done`` is the ``[n] int32`` per-task completion-flag array
    (all ones iff the queue drained — the lowering guarantees it)."""
    n = task_tbl.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    slab_out, _ring, _rem, done = pl.pallas_call(
        functools.partial(_ready_queue_kernel, branches=branches),
        out_shape=(
            jax.ShapeDtypeStruct(slab.shape, slab.dtype),
            jax.ShapeDtypeStruct((n + 1,), jnp.int32),
            jax.ShapeDtypeStruct((n + 1,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=interpret,
    )(task_tbl.astype(jnp.int32), dep_tbl.astype(jnp.int32),
      ring0.astype(jnp.int32), rem0.astype(jnp.int32),
      tail0.astype(jnp.int32), slab)
    return slab_out, done
