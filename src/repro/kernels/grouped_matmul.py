"""Ragged grouped GEMM — N small matmuls in ONE kernel launch.

This is the TPU realization of ACS's "concurrent execution of independent
small kernels": a wave of homogeneous GEMM tasks (MoE experts after
routing, or same-signature ACS tasks) is laid out as row-groups of one
[M, K] operand, and a single Pallas launch computes every group against
its own weight ``w[g]``. The per-m-tile group id is a *scalar-prefetch*
operand (megablocks-style), so the weight block index map is
data-dependent — the kernel equivalent of the window's runtime dispatch.

Grid: (M/bm, N/bn), K kept whole per program (experts' K is small for the
assigned MoE archs: deepseek d=5120 -> bm*K + K*bn + bm*bn fits VMEM with
bm=bn=128 up to K≈24k).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul"]


def _gmm_kernel(tile_groups_ref, x_ref, w_ref, o_ref):
    # x_ref: [bm, K]; w_ref: [1, K, bn] (the tile's group weights); o_ref: [bm, bn]
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def grouped_matmul(
    x: jax.Array,            # [M, K] rows grouped (padded per group to block_m)
    w: jax.Array,            # [G, K, N]
    tile_groups: jax.Array,  # [M // block_m] int32 group id per m-tile
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    m, k = x.shape
    g, _, n = w.shape
    assert m % block_m == 0, (m, block_m)
    block_n = min(block_n, n)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, n_pad - n)))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (m // block_m, n_pad // block_n)
    out = pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, k), lambda mi, ni, tg: (mi, 0)),
                pl.BlockSpec((1, k, block_n), lambda mi, ni, tg: (tg[mi], 0, ni)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, tg: (mi, ni)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), x.dtype),
        interpret=interpret,
    )(tile_groups.astype(jnp.int32), x, w)
    return out[:, :n]
