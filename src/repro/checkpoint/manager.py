"""Sharded checkpointing with atomic manifests and elastic restore.

Layout (one directory per step)::

    ckpt_dir/
      step_000123/
        manifest.json        # tree structure, dtypes, shapes, data cursor
        arrays/<leaf>.npy    # one file per pytree leaf
      LATEST                 # atomically updated pointer

Fault-tolerance properties:
* **Atomicity** — a step directory is written under ``.tmp`` and renamed;
  ``LATEST`` is only updated after the rename, so a crash mid-save leaves
  the previous checkpoint intact.
* **Restart** — ``manager.restore_latest()`` returns (tree, extras) or
  None; the trainer resumes from (params, opt_state, data cursor).
* **Elastic remesh** — arrays are saved UNSHARDED (gathered); on restore
  the trainer re-applies whatever sharding the *new* mesh prescribes, so
  restarting on a different topology (e.g. 256 -> 512 chips) needs no
  conversion step. At real scale the np.save writer is replaced by a
  tensorstore/OCDBT driver behind the same manifest contract.
* **Retention** — ``keep`` most recent steps are retained, older ones
  garbage-collected after a successful save.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_tree", "restore_tree", "CheckpointManager"]


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        names.append("/".join(parts) or "root")
    return [(n, v) for n, (_, v) in zip(names, flat)], treedef


def save_tree(tree: Any, directory: Path, extras: Optional[Dict] = None) -> None:
    directory = Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)
    leaves, _ = _flatten_with_names(tree)
    manifest = {"leaves": [], "extras": extras or {}, "time": time.time()}
    for i, (name, val) in enumerate(leaves):
        arr = np.asarray(val)
        fname = f"{i:05d}.npy"
        np.save(tmp / "arrays" / fname, arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if directory.exists():
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_tree(tree_like: Any, directory: Path) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like`` (shapes must match;
    dtypes are cast — bf16 params round-trip through fp32 files)."""
    directory = Path(directory)
    manifest = json.loads((directory / "manifest.json").read_text())
    leaves, treedef = _flatten_with_names(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target tree has {len(leaves)}"
        )
    vals = []
    for (name, like), meta in zip(leaves, manifest["leaves"]):
        if list(np.shape(like)) != meta["shape"]:
            raise ValueError(
                f"leaf {name}: checkpoint shape {meta['shape']} != {np.shape(like)}"
            )
        arr = np.load(directory / "arrays" / meta["file"])
        vals.append(arr.astype(np.asarray(like).dtype if hasattr(like, "dtype") else arr.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, vals)
    return restored, manifest["extras"]


class CheckpointManager:
    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None) -> None:
        save_tree(tree, self._step_dir(step), extras={**(extras or {}), "step": step})
        (self.root / "LATEST.tmp").write_text(str(step))
        os.replace(self.root / "LATEST.tmp", self.root / "LATEST")
        self._gc()

    def latest_step(self) -> Optional[int]:
        p = self.root / "LATEST"
        if not p.exists():
            return None
        return int(p.read_text().strip())

    def restore_latest(self, tree_like: Any) -> Optional[Tuple[Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        return restore_tree(tree_like, self._step_dir(step))

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.root.glob("step_*")
            if p.is_dir()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
