"""Recurrent mixers: RG-LRU (recurrentgemma) and Mamba-1 selective SSM
(falcon-mamba). Both reduce to the diagonal linear recurrence
``h_t = a_t * h_{t-1} + b_t`` served by ``kernels.lru_scan`` (RG-LRU
directly; Mamba's per-(channel, state) recurrence via a compact lax.scan
whose carry never materializes [B, S, d_inner, N] — DESIGN.md §7).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..parallel import shard
from .config import ArchConfig
from .layers import dense_init

__all__ = ["init_rglru", "apply_rglru", "init_mamba", "apply_mamba"]


# ---------------------------------------------------------------------------
# RG-LRU block (recurrentgemma): conv1d + gated diagonal LRU
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),       # x branch
        "w_gate_in": dense_init(ks[1], (d, w), dtype),  # multiplicative branch
        "conv_w": dense_init(ks[2], (cfg.d_conv, w), dtype, scale=0.5),
        "wr": dense_init(ks[3], (w, w), dtype),         # recurrence gate
        "wi": dense_init(ks[4], (w, w), dtype),         # input gate
        "a_log": (-0.5 * jnp.ones((w,), jnp.float32)).astype(jnp.float32),
        "w_out": dense_init(ks[5], (w, d), dtype),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array]):
    """x [B, S, W]; w [K, W] depthwise causal conv. Returns (y, new_state)
    where state is the trailing K-1 inputs (for decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, W]
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return y.astype(x.dtype), new_state


def apply_rglru(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    *,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (h [B,W], conv [B,K-1,W])
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, s, d = x.shape
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]))
    u = shard(u, "channels")

    conv_state = state[1] if state is not None else None
    u, new_conv = _causal_conv1d(u, p["conv_w"], conv_state)

    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wr"]))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wi"]))
    log_a = -8.0 * r * jax.nn.softplus(p["a_log"])[None, None, :]
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (i * u).astype(jnp.float32)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * gated

    h0 = state[0].astype(jnp.float32) if state is not None else jnp.zeros((b, u.shape[-1]), jnp.float32)
    hs = ops.lru_scan(a, bterm, h0)  # [B, S, W]
    hs = shard(hs.astype(x.dtype), "channels")

    y = jnp.einsum("bsw,wd->bsd", hs * g, p["w_out"])
    new_state = (hs[:, -1].astype(jnp.float32), new_conv) if state is not None else None
    return shard(y, "act_btd"), new_state


# ---------------------------------------------------------------------------
# Mamba-1 block (falcon-mamba)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, di), dtype, scale=0.5),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * n), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), dtype),
    }


def apply_mamba(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    *,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (h [B,di,N], conv [B,K-1,di])
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, s, d = x.shape
    di = cfg.expand * d
    n = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = xz[..., :di], xz[..., di:]
    xi = shard(xi, "channels")

    conv_state = state[1] if state is not None else None
    xi, new_conv = _causal_conv1d(xi, p["conv_w"], conv_state)
    xi = jax.nn.silu(xi)

    proj = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", proj[..., :dt_rank], p["dt_proj"])
        + p["dt_bias"][None, None]
    ).astype(jnp.float32)                                  # [B, S, di]
    bmat = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)   # [B, S, N]
    cmat = proj[..., dt_rank + n :].astype(jnp.float32)           # [B, S, N]
    a = -jnp.exp(p["A_log"])                                # [di, N]

    h0 = state[0].astype(jnp.float32) if state is not None else jnp.zeros((b, di, n), jnp.float32)
    xf = xi.astype(jnp.float32)

    def step(h, inputs):
        dt_t, b_t, c_t, x_t = inputs  # [B,di], [B,N], [B,N], [B,di]
        da = jnp.exp(dt_t[..., None] * a[None])             # [B, di, N]
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, c_t)                # [B, di]
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (dt.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
         cmat.transpose(1, 0, 2), xf.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2) + p["D"][None, None] * xf     # [B, S, di]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, "channels")
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_state = (hT, new_conv) if state is not None else None
    return shard(out, "act_btd"), new_state
