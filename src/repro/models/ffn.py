"""FFN sublayers: gated dense MLP and Mixture-of-Experts.

MoE uses capacity-based expert-choice dispatch over the token-choice top-k
assignment (DESIGN.md §6): router computes top-k per token; each expert
then takes its top-C assigned rows (C = tokens*k/E * capacity_factor).
This keeps every shape static, vectorizes over the (TP-sharded) expert
axis, and its FLOPs equal the true active compute x capacity_factor — no
dense-over-experts blowup. Overflowed assignments are dropped (standard
capacity semantics); the expert axis is padded so E % TP == 0 (padded
experts get -inf router logits and thus no real tokens).

This is also where ACS meets the LM stack: each (expert, token-group) GEMM
is a paper-style small kernel; the wave executor path (`moe_task_stream`)
emits them as ACS tasks so the scheduling benchmarks can run real MoE
streams, while the jit path below is the production train/serve compute.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel import shard
from .config import ArchConfig
from .layers import dense_init

__all__ = ["init_ffn", "apply_ffn", "init_moe", "apply_moe", "padded_experts"]


def init_ffn(key, d: int, ff: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dtype),
        "w_up": dense_init(ks[1], (d, ff), dtype),
        "w_down": dense_init(ks[2], (ff, d), dtype),
    }


def apply_ffn(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = shard(h, "ffn_hidden")
    return shard(jnp.einsum("bsf,fd->bsd", h, p["w_down"]), "act_btd")


def padded_experts(cfg: ArchConfig, tp_size: int = 16) -> int:
    e = cfg.moe.n_experts
    return -(-e // tp_size) * tp_size


def init_moe(key, cfg: ArchConfig, dtype, tp_size: int = 16) -> Dict[str, Any]:
    m = cfg.moe
    d, de = cfg.d_model, m.d_expert
    e_pad = padded_experts(cfg, tp_size)
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (e_pad, d, de), dtype),
        "w_up": dense_init(ks[2], (e_pad, d, de), dtype),
        "w_down": dense_init(ks[3], (e_pad, de, d), dtype),
    }
    if m.n_shared:
        params["shared"] = init_ffn(ks[4], d, m.n_shared * de, dtype)
    return params


def apply_moe(p: Dict[str, Any], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x [B, S, D] -> [B, S, D].

    Dispatch happens within ``g = moe.dispatch_groups`` batch-aligned token
    groups (g=1 -> one global group). With g = DP degree, the group axis
    aligns with the batch sharding, so routing/gather/expert-compute/
    combine are all shard-local and only the final combine psum crosses
    the TP axis (EXPERIMENTS.md §Perf, profile-driven).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = m.n_experts
    e_pad = p["w_gate"].shape[0]
    k = m.top_k
    g = max(1, min(m.dispatch_groups, b))
    tg = t // g
    cap = max(int(tg * k / e * m.capacity_factor), 1)
    cap = min(cap, tg)

    cdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[m.combine_dtype]
    xg = x.reshape(g, tg, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # [G, Tg, E]
    top_p, top_e = jax.lax.top_k(probs, k)   # [G, Tg, k]
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    def one_group(xf, tp_g, te_g):
        """Dispatch/compute/combine for one token group [Tg, D] — vmapped
        over G so every gather/scatter carries an explicit batch dim
        (GSPMD shards those; raw multi-index gathers it does not)."""
        assign = jnp.zeros((tg, e_pad), jnp.float32)
        assign = assign.at[jnp.arange(tg)[:, None], te_g].set(tp_g)
        scores_et = assign.T                                  # [E_pad, Tg]
        top_scores, token_idx = jax.lax.top_k(scores_et, cap)  # [E_pad, C]
        valid = top_scores > 0.0
        xe = xf[token_idx]                                    # [E_pad, C, D]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # [E_pad, C, D]
        ye = (ye * (top_scores * valid)[..., None].astype(ye.dtype)).astype(cdt)
        out_g = jnp.zeros((tg, d), cdt)
        return out_g.at[token_idx.reshape(-1)].add(ye.reshape(-1, d))

    out = jax.vmap(one_group)(xg, top_p, top_e)              # [G, Tg, D]
    out = shard(out.reshape(b, s, d), "act_btd").astype(x.dtype)

    if "shared" in p:
        out = out + apply_ffn(p["shared"], x)
    return out
