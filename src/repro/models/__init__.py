"""LM model stack for the 10 assigned architectures (DESIGN.md §3)."""

from .config import ArchConfig, MLAConfig, MoEConfig
from .transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    pad_vocab,
    prefill,
    split_pattern,
)

__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig",
    "decode_step", "forward", "init_cache", "init_params", "loss_fn",
    "pad_vocab", "prefill", "split_pattern",
]
