"""Shared layer primitives: RMSNorm, rotary embeddings, initializers."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope", "apply_rope", "dense_init", "DTYPES"]

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(positions: jax.Array, dim: int, theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding. positions [S] -> [S, dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, D] rotated pairwise (split-halves convention)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    shape = (1,) * (x.ndim - 2) + cos.shape  # broadcast over leading axes
    c, s = cos.reshape(shape), sin.reshape(shape)
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (scale * jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)
