"""Model assembly: embedding/frontend -> (prefix layers + scanned stages)
-> final norm -> LM head. One code path serves all 10 assigned archs.

Layer layout: ``cfg.pattern`` (length n_layers) is split into an unscanned
*prefix* (pattern remainder + MoE ``first_dense`` layers) and a body of
``n_stages`` repetitions of ``pattern_unit`` executed with ``lax.scan``
over stacked params — this keeps the HLO compact for 46-88 layer configs
(compile time and dry-run tractability) while supporting heterogeneous
units (gemma2 local/global pairs, recurrentgemma's 2:1 RG-LRU:attn).

Modes:
* ``forward``      — training forward (no cache) -> logits [B, S, V_pad]
* ``prefill``      — forward + cache population -> (last logits, cache)
* ``decode_step``  — one token against the cache -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import shard
from .attention import apply_attn, apply_mla, init_attn, init_mla
from .config import ATTN_GLOBAL, ATTN_LOCAL, MAMBA, MLA, RGLRU, ArchConfig
from .ffn import apply_ffn, apply_moe, init_ffn, init_moe
from .layers import DTYPES, dense_init, rms_norm
from .recurrent import apply_mamba, apply_rglru, init_mamba, init_rglru

__all__ = [
    "FRONTEND_DIMS", "pad_vocab", "split_pattern", "init_params",
    "forward", "loss_fn", "prefill", "decode_step", "init_cache",
    "unrolled_stages",
]

FRONTEND_DIMS = {"audio_stub": 512, "vision_stub": 1152}

# When True, the stage loop is a python loop instead of lax.scan. Used by
# the roofline analyzer: XLA's cost analysis counts a while body ONCE
# (verified empirically), so exact per-stage FLOPs/bytes/collective counts
# come from unrolled 1-stage vs 2-stage lowerings (launch/roofline.py).
_UNROLL = False


import contextlib


@contextlib.contextmanager
def unrolled_stages():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


# Remat policy for the per-stage checkpoint (§Perf knob): "nothing" (full
# recompute, minimum memory) or "dots" (save matmul outputs — skips the
# recompute of the big GEMMs *and their surrounding collectives* in bwd).
_REMAT_POLICY = "nothing"


@contextlib.contextmanager
def remat_policy(name: str):
    global _REMAT_POLICY
    prev = _REMAT_POLICY
    _REMAT_POLICY = name
    try:
        yield
    finally:
        _REMAT_POLICY = prev


def _checkpoint_policy():
    if _REMAT_POLICY == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


def split_pattern(cfg: ArchConfig) -> Tuple[Tuple[str, ...], int]:
    """Returns (prefix_kinds, n_stages). Body = n_stages x pattern_unit."""
    unit = cfg.pattern_unit
    n_prefix = cfg.n_layers % len(unit)
    if cfg.moe is not None and cfg.moe.first_dense:
        fd = cfg.moe.first_dense
        # prefix must absorb the dense-FFN layers and keep body divisible
        while (cfg.n_layers - max(n_prefix, fd)) % len(unit):
            fd += 1
        n_prefix = max(n_prefix, fd)
    prefix = cfg.pattern[:n_prefix]
    n_stages = (cfg.n_layers - n_prefix) // len(unit)
    return prefix, n_stages


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, kind: str, cfg: ArchConfig, dtype, layer_has_moe: bool,
                tp_size: int) -> Dict[str, Any]:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm": jnp.zeros((d,), jnp.float32)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["mixer"] = init_attn(k1, cfg, dtype)
    elif kind == MLA:
        p["mixer"] = init_mla(k1, cfg, dtype)
    elif kind == RGLRU:
        p["mixer"] = init_rglru(k1, cfg, dtype)
    elif kind == MAMBA:
        p["mixer"] = init_mamba(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != MAMBA:
        p["ffn_norm"] = jnp.zeros((d,), jnp.float32)
        if layer_has_moe:
            p["ffn"] = init_moe(k2, cfg, dtype, tp_size)
        else:
            p["ffn"] = init_ffn(k2, d, cfg.d_ff, dtype)
    return p


def init_params(cfg: ArchConfig, key, tp_size: int = 16) -> Dict[str, Any]:
    dtype = DTYPES[cfg.dtype]
    d = cfg.d_model
    v_pad = pad_vocab(cfg.vocab)
    prefix, n_stages = split_pattern(cfg)
    ks = jax.random.split(key, 4 + len(prefix))

    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (v_pad, d), dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if cfg.frontend:
        params["frontend_proj"] = dense_init(
            ks[1], (FRONTEND_DIMS[cfg.frontend], d), dtype
        )
    if not cfg.tied_embeddings:
        params["head"] = dense_init(ks[2], (d, v_pad), dtype)

    moe_layer = cfg.moe is not None
    params["prefix"] = [
        _init_layer(ks[4 + i], kind, cfg, dtype, layer_has_moe=False, tp_size=tp_size)
        for i, kind in enumerate(prefix)
    ]

    unit = cfg.pattern_unit
    stage_keys = jax.random.split(ks[3], max(n_stages, 1))

    def init_stage(sk):
        uks = jax.random.split(sk, len(unit))
        return tuple(
            _init_layer(uks[i], kind, cfg, dtype, layer_has_moe=moe_layer,
                        tp_size=tp_size)
            for i, kind in enumerate(unit)
        )

    if n_stages > 0:
        params["stages"] = jax.vmap(init_stage)(stage_keys)
    else:
        params["stages"] = None
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int, dtype):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        rows = max_len
        if kind == ATTN_LOCAL and cfg.window is not None:
            rows = min(cfg.window, max_len)
        shape = (batch, cfg.eff_kv_heads, rows, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == MLA:
        m = cfg.mla
        return (
            jnp.zeros((batch, max_len, m.kv_lora), dtype),
            jnp.zeros((batch, max_len, m.rope_dim), dtype),
        )
    if kind == RGLRU:
        w = cfg.rglru_width or cfg.d_model
        return (
            jnp.zeros((batch, w), jnp.float32),
            jnp.zeros((batch, cfg.d_conv - 1, w), dtype),
        )
    if kind == MAMBA:
        di = cfg.expand * cfg.d_model
        return (
            jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
            jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        )
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    dtype = DTYPES[cfg.dtype]
    prefix, n_stages = split_pattern(cfg)
    pre = [_layer_cache(k, cfg, batch, max_len, dtype) for k in prefix]
    if n_stages > 0:
        def one_stage(_):
            return tuple(
                _layer_cache(k, cfg, batch, max_len, dtype) for k in cfg.pattern_unit
            )
        stages = jax.vmap(one_stage)(jnp.arange(n_stages))
    else:
        stages = None
    return {"prefix": pre, "stages": stages}


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------

def _apply_layer(kind, lp, x, cfg, positions, cache_entry, pos, prefill_mode):
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        y, new_c = apply_attn(
            lp["mixer"], h, cfg, local=(kind == ATTN_LOCAL),
            positions=positions, cache=cache_entry, pos=pos,
            prefill=prefill_mode,
        )
    elif kind == MLA:
        y, new_c = apply_mla(lp["mixer"], h, cfg, positions=positions,
                             cache=cache_entry, pos=pos, prefill=prefill_mode)
    elif kind == RGLRU:
        y, new_c = apply_rglru(lp["mixer"], h, cfg, state=cache_entry)
    elif kind == MAMBA:
        y, new_c = apply_mamba(lp["mixer"], h, cfg, state=cache_entry)
    else:
        raise ValueError(kind)
    x = x + y
    if "ffn" in lp:
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None and "router" in lp["ffn"]:
            x = x + apply_moe(lp["ffn"], h, cfg)
        else:
            x = x + apply_ffn(lp["ffn"], h)
    return x, new_c


def _embed(params, cfg: ArchConfig, inputs):
    if cfg.frontend:
        x = jnp.einsum("bsf,fd->bsd", inputs, params["frontend_proj"])
    else:
        x = params["embed"][inputs]
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return shard(x.astype(DTYPES[cfg.dtype]), "act_btd")


def _head(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tied_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return shard(logits, "logits")


def _run_layers(params, cfg, x, positions, cache, pos, prefill_mode, remat):
    prefix, n_stages = split_pattern(cfg)
    unit = cfg.pattern_unit
    new_prefix_cache = []
    for i, kind in enumerate(prefix):
        entry = cache["prefix"][i] if cache is not None else None
        x, nc = _apply_layer(kind, params["prefix"][i], x, cfg, positions,
                             entry, pos, prefill_mode)
        new_prefix_cache.append(nc)

    new_stage_cache = None
    if n_stages > 0:
        def stage_body(carry, xs):
            xx = carry
            stage_params, stage_cache = xs
            new_entries = []
            for ui, kind in enumerate(unit):
                entry = stage_cache[ui] if stage_cache is not None else None
                xx, nc = _apply_layer(kind, stage_params[ui], xx, cfg,
                                      positions, entry, pos, prefill_mode)
                new_entries.append(nc)
            out_cache = tuple(new_entries) if stage_cache is not None else None
            return xx, out_cache

        body = stage_body
        if remat:
            body = jax.checkpoint(stage_body, policy=_checkpoint_policy())
        stage_cache = cache["stages"] if cache is not None else None
        xs = (params["stages"], stage_cache)
        if _UNROLL:
            outs = []
            for si in range(n_stages):
                xsi = jax.tree.map(lambda a: a[si], xs)
                x, oc = body(x, xsi)
                outs.append(oc)
            new_stage_cache = (
                jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
                if outs and outs[0] is not None else None
            )
        else:
            x, new_stage_cache = jax.lax.scan(body, x, xs)

    new_cache = None
    if cache is not None:
        new_cache = {"prefix": new_prefix_cache, "stages": new_stage_cache}
    return x, new_cache


def forward(params, cfg: ArchConfig, inputs, *, remat: bool = True):
    """Training/eval forward. inputs: tokens [B,S] int32 (or embeddings
    [B,S,F] for frontend archs). Returns logits [B, S, V_pad] (f32)."""
    b, s = inputs.shape[:2]
    x = _embed(params, cfg, inputs)
    positions = jnp.arange(s)
    x, _ = _run_layers(params, cfg, x, positions, None, None, False, remat)
    return _head(params, cfg, x)


def loss_fn(params, cfg: ArchConfig, inputs, labels, *, remat: bool = True):
    """Mean next-token cross entropy; padded vocab columns masked out."""
    logits = forward(params, cfg, inputs, remat=remat)
    v_pad = logits.shape[-1]
    col = jnp.arange(v_pad)
    logits = jnp.where(col[None, None] < cfg.vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def prefill(params, cfg: ArchConfig, inputs, cache):
    """Populate the cache from a prompt; returns (last-token logits, cache)."""
    b, s = inputs.shape[:2]
    x = _embed(params, cfg, inputs)
    positions = jnp.arange(s)
    x, cache = _run_layers(params, cfg, x, positions, cache,
                           jnp.asarray(0, jnp.int32), True, False)
    return _head(params, cfg, x[:, -1:]), cache


def decode_step(params, cfg: ArchConfig, inputs, cache, pos):
    """One decode step at (traced) position ``pos``. inputs [B, 1]."""
    x = _embed(params, cfg, inputs)
    positions = pos + jnp.arange(inputs.shape[1])
    x, cache = _run_layers(params, cfg, x, positions, cache, pos, False, False)
    return _head(params, cfg, x), cache
